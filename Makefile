# Tier-1 verify: the exact command from ROADMAP.md.
.PHONY: test test-full bench-serve example-serve

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

test-full:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -q

bench-serve:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/serve_bench.py

example-serve:
	python examples/serve_ess.py
