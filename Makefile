# Tier-1 verify: the exact command from ROADMAP.md.
.PHONY: test test-full bench-serve bench-smoke example-serve \
	example-stream-abort example-cluster examples-smoke lint-ess \
	lint-ess-fast

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

test-full:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -q

bench-serve:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/serve_bench.py

# CI smoke: append one 2-slot/5-request interleaved-prefill tokens/s point
# to BENCH_serve.json (accumulates the perf trajectory across runs)
bench-smoke:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/serve_bench.py --smoke

example-serve:
	python examples/serve_ess.py

# request-lifecycle front-end demo: stream()/abort()/stop tokens/priority
example-stream-abort:
	python examples/stream_abort.py

# PD-disaggregated cluster demo: 1 prefill + 2 decode workers, bitwise
# stream parity across the page-granular handoff
example-cluster:
	python examples/serve_cluster.py

# CI examples smoke job: all demos end to end
examples-smoke: example-serve example-stream-abort example-cluster

# esslint: AST rules + jaxpr contract audit vs the checked-in baseline
# (see ANALYSIS.md).  CI runs the full check; the fast variant is the
# AST layer only (milliseconds) for pre-commit use.
lint-ess:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m repro.analysis --check

lint-ess-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m repro.analysis --check --skip-audit
