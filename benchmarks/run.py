"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Simulator-backed benches
reproduce the paper's tables/figures (the paper's own evaluation is
simulation); kernel benches time the Pallas kernels (interpret mode on
CPU — wall times are *not* TPU times, the derived column carries the
modelled numbers that matter).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, n: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def _row(name: str, us: float, derived) -> None:
    if isinstance(derived, (dict, list)):
        derived = json.dumps(derived, separators=(",", ":"))
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
# Paper tables / figures (simulator)
# ---------------------------------------------------------------------------

def bench_table2_throughput() -> None:
    from repro.simulator import experiments as E
    t0 = time.perf_counter()
    rows = E.table2()
    us = (time.perf_counter() - t0) * 1e6
    devs = [abs(r["dev_pct"]) for r in rows]
    _row("table2_throughput", us,
         {"rows": len(rows), "median_abs_dev_pct": round(float(np.median(devs)), 2),
          "max_abs_dev_pct": round(float(np.max(devs)), 2)})
    h = E.headline_improvements()
    _row("table2_headline", 0.0,
         {k: round(v, 1) for k, v in h.items()})


def bench_fig1_throughput_vs_batch() -> None:
    from repro.simulator import experiments as E
    t0 = time.perf_counter()
    rows = E.fig1_throughput_vs_batch()
    us = (time.perf_counter() - t0) * 1e6
    cap = max(r["batch"] for r in rows if r["feasible_on_gpu"])
    _row("fig1_throughput_vs_batch", us,
         {"gpu_batch_ceiling": cap,
          "thr_at_8": rows[0]["throughput"],
          "thr_at_160": rows[-1]["throughput"]})


def bench_fig2_similarity() -> None:
    from repro.simulator import experiments as E
    t0 = time.perf_counter()
    rows = E.fig2_similarity()
    us = (time.perf_counter() - t0) * 1e6
    sims = [r["similarity_mean"] for r in rows]
    _row("fig2_intra_layer_similarity", us,
         {"min": min(sims), "mean": round(float(np.mean(sims)), 4),
          "max": max(sims)})


def bench_fig4_lru_warmup() -> None:
    from repro.simulator import experiments as E
    t0 = time.perf_counter()
    w = E.fig4_warmup()
    us = (time.perf_counter() - t0) * 1e6
    _row("fig4_lru_warmup", us,
         {"first_step_cold": w["before_warmup"][0],
          "first_step_warm": w["after_warmup"][0],
          "steady_cold": round(float(np.mean(w["before_warmup"][8:])), 1),
          "steady_warm": round(float(np.mean(w["after_warmup"][8:])), 1)})


def bench_fig5_miss_by_layer() -> None:
    from repro.simulator import experiments as E
    t0 = time.perf_counter()
    rows = E.fig5_miss_by_layer()
    us = (time.perf_counter() - t0) * 1e6
    _row("fig5_miss_by_layer", us, rows)


def bench_fig7_overlap_strategies() -> None:
    from repro.simulator import experiments as E
    t0 = time.perf_counter()
    rows = E.fig7_overlap_comparison()
    us = (time.perf_counter() - t0) * 1e6
    cross = next((r["miss"] for r in rows if r["dba_ms"] < r["da_ms"]), None)
    _row("fig7_overlap_strategies", us,
         {"dba_beats_da_at_miss": cross,
          "at512": {k: rows[5][k] for k in ("none_ms", "da_ms", "dba_ms")}})


def bench_fig8_9_miss_vs_context() -> None:
    from repro.simulator import experiments as E
    t0 = time.perf_counter()
    rows = E.fig8_9_miss_vs_context()
    us = (time.perf_counter() - t0) * 1e6
    _row("fig8_9_miss_vs_context", us, rows[:6])


def bench_v5e_projection() -> None:
    from repro.simulator import experiments as E
    t0 = time.perf_counter()
    rows = E.v5e_projection()
    us = (time.perf_counter() - t0) * 1e6
    _row("v5e_ess_projection", us, rows)


def bench_flashtrans_bandwidth() -> None:
    from repro.simulator import experiments as E
    t0 = time.perf_counter()
    f = E.flashtrans_comparison()
    us = (time.perf_counter() - t0) * 1e6
    _row("flashtrans_vs_naive", us,
         {k: round(v, 3) for k, v in f.items()})


# ---------------------------------------------------------------------------
# Live-system microbenches (CPU wall time; structural)
# ---------------------------------------------------------------------------

def bench_kernel_sparse_mla() -> None:
    from repro.kernels.sparse_mla.sparse_mla import sparse_mla_partial_kernel
    H, D, K, R = 128, 576, 2048, 512
    q = jax.random.normal(jax.random.key(0), (H, D), jnp.float32)
    rows = jax.random.normal(jax.random.key(1), (K, D), jnp.float32)
    valid = jnp.ones((K,), bool)
    fn = jax.jit(lambda a, b, c: sparse_mla_partial_kernel(a, b, c, 0.043, R))
    us = _timeit(fn, q, rows, valid)
    flops = 2 * H * K * (D + R)
    _row("kernel_sparse_mla_2048", us,
         {"flops": flops, "v5e_us_at_60pct": round(
             flops / (197e12 * 0.6) * 1e6, 2)})


def bench_kernel_indexer() -> None:
    from repro.kernels.indexer.indexer import indexer_scores_kernel
    Hi, Di, S = 64, 128, 32768
    q = jax.random.normal(jax.random.key(0), (Hi, Di), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (Hi,), jnp.float32)
    keys = jax.random.normal(jax.random.key(2), (S, Di), jnp.float32)
    valid = jnp.ones((S,), bool)
    fn = jax.jit(lambda a, b, c, d: indexer_scores_kernel(a, b, c, d))
    us = _timeit(fn, q, w, keys, valid, n=3, warmup=1)
    flops = 2 * S * Hi * Di
    _row("kernel_indexer_32k", us,
         {"flops": flops, "v5e_us_at_75pct": round(
             flops / (197e12 * 0.75) * 1e6, 2)})


def bench_kernel_gather() -> None:
    from repro.kernels.gather_cache import ops as gops
    cache = jax.random.normal(jax.random.key(0), (32768, 576), jnp.bfloat16)
    ids = jax.random.randint(jax.random.key(1), (512,), 0, 32768)
    us = _timeit(gops.gather_rows, cache, ids, n=3, warmup=1)
    bytes_moved = 512 * 576 * 2
    _row("kernel_gather_512rows", us,
         {"bytes": bytes_moved,
          "v5e_us_at_hbm": round(bytes_moved / 819e9 * 1e6, 3)})


def bench_ess_decode_step() -> None:
    import dataclasses
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving import engine as E

    cfg = get_config("deepseek-v32-exp-ess-smoke")
    cfg = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 24, 48
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, caches = E.ess_prefill(params, cfg, toks, pos, Smax, do_warmup=False)
    nxt = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)

    step = jax.jit(lambda p, t, po, c: E.ess_decode(p, cfg, t, po, c))
    out = step(params, nxt, caches.lens[:, None], caches)
    us = _timeit(lambda: step(params, nxt, caches.lens[:, None], caches),
                 n=3, warmup=1)
    _row("ess_decode_step_smoke", us,
         {"misses_step1": int(np.array(out.caches and out.stats["misses"]).sum())})


def bench_lru_pool_ops() -> None:
    from repro.core import lru_pool as LP
    B, P, S, K, M = 8, 6400, 32768, 2048, 512
    pool = LP.init_pool(B, P, S, 576, jnp.bfloat16)
    ids = jax.random.randint(jax.random.key(0), (B, K), 0, S)

    @jax.jit
    def step(pool, ids):
        # dedup=False pins the historical single-query lookup cost (the
        # Q>1 dedup path would add an O(K^2) compare to this row)
        pool, lk, stats = LP.lookup(pool, ids, ids >= 0, M, slot_mask=None,
                                    dedup=False)
        rows = jnp.zeros((B, M, 576), jnp.bfloat16)
        pool = LP.admit(pool, lk.miss_ids, rows, slot_mask=None)
        return LP.tick(pool), stats

    us = _timeit(step, pool, ids, n=3, warmup=1)
    _row("lru_lookup_admit_b8_k2048", us, {"pool_entries": P})


def bench_roofline_summary() -> None:
    """Condensed §Roofline terms from the dry-run artifacts (if present)."""
    import glob
    import os
    rows = []
    for f in sorted(glob.glob("results/dryrun_*.json")):
        try:
            rows += json.load(open(f))
        except Exception:
            continue
    ok = [r for r in rows if r.get("status") == "ok"]
    _row("roofline_cells_compiled", 0.0,
         {"ok": len(ok),
          "skipped": sum(r.get("status") == "skipped" for r in rows),
          "error": sum(r.get("status") == "error" for r in rows)})


BENCHES = [
    bench_table2_throughput,
    bench_fig1_throughput_vs_batch,
    bench_fig2_similarity,
    bench_fig4_lru_warmup,
    bench_fig5_miss_by_layer,
    bench_fig7_overlap_strategies,
    bench_fig8_9_miss_vs_context,
    bench_flashtrans_bandwidth,
    bench_v5e_projection,
    bench_kernel_sparse_mla,
    bench_kernel_indexer,
    bench_kernel_gather,
    bench_lru_pool_ops,
    bench_ess_decode_step,
    bench_roofline_summary,
]


def main() -> None:
    print("name,us_per_call,derived")
    for b in BENCHES:
        try:
            b()
        except Exception as e:  # pragma: no cover
            _row(b.__name__, -1.0, f"ERROR {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
