"""§Roofline: derive compute / memory / collective terms per (arch × shape
× mesh) cell from the dry-run artifacts.

Terms (seconds per step, per chip — the lowered HLO is already the
per-device SPMD program, so no further division by chip count):

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_bw

Caveats handled explicitly:

* XLA cost_analysis counts ``while``-loop (scan) bodies ONCE.  Cells whose
  step function scans over layers therefore undercount; the dry-run can be
  re-run with ``--unrolled`` (scan_layers=False, accum=1) for exact
  counting, and this module also reports the analytic MODEL_FLOPS and the
  MODEL/HLO ratio — when the ratio is far above the remat-expected ~1.3x,
  the undercount (or sharding-induced redundancy) is visible, which is the
  point of the column.
* On the CPU dry-run backend memory_analysis does not separate the host
  memory space; host-tier bytes are derived from the input spec shardings
  instead (ESS cells).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ICI 3 links x ~50 GB/s
(we charge the busiest-link bound: total collective bytes / (1 link)).
"""

from __future__ import annotations

import glob
import json
import math
import os
import sys
from typing import Any

PEAK = 197e12
HBM = 819e9
ICI_LINK = 50e9

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def active_param_count(cfg) -> float:
    """Active params/token: experts scaled by top_k/num_experts."""
    from repro.models import transformer as T
    from repro.models.params import ParamDef, is_def
    import jax
    import numpy as np
    defs = T.model_def(cfg)
    total = 0.0
    flat, _ = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)
    for path, d in flat:
        n = float(np.prod(d.shape))
        if d.axes and "experts" in d.axes:
            n *= cfg.moe.top_k / cfg.moe.num_experts
        total += n
    return total


def model_flops(arch: str, shape: str) -> float:
    """Analytic MODEL_FLOPS per step: 6·N·D train, 2·N·D inference."""
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import cell_config
    cfg, cell = cell_config(arch, shape)
    n = active_param_count(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    tokens = cell.global_batch * 1
    return 2.0 * n * tokens


def chips_of(mesh: str) -> int:
    return 512 if mesh.startswith("2x") else 256


def analyze(rows: list[dict]) -> list[dict]:
    out = []
    for r in rows:
        if r.get("status") != "ok":
            continue
        chips = chips_of(r["mesh"])
        t_c = r["flops"] / PEAK
        t_m = r["bytes_accessed"] / HBM
        t_x = r["collectives"]["total_bytes"] / ICI_LINK
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        try:
            mf = model_flops(r["arch"], r["shape"])
        except Exception:
            mf = float("nan")
        ratio = mf / max(r["flops"] * chips, 1.0)
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom,
            "model_flops": mf,
            "hlo_flops_global": r["flops"] * chips,
            "model_over_hlo": ratio,
            "roofline_frac": max(t_c, 1e-30) / max(t_c, t_m, t_x),
            "memory": r.get("memory", {}),
            "coll_detail": r["collectives"],
        })
    return out


def load_all(pattern: str = "results/dryrun_*.json") -> list[dict]:
    rows: list[dict] = []
    for f in sorted(glob.glob(pattern)):
        try:
            rows += json.load(open(f))
        except Exception:
            pass
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def markdown_table(an: list[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(an, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['model_over_hlo']:.2f} | "
            f"{r['roofline_frac']:.2f} |")
    return "\n".join(lines)


def perf_comparison() -> str:
    """§Perf before/after table: baseline dryrun_* vs optimized perf_*."""
    base = {(r["arch"], r["shape"], r["mesh"]): r
            for r in load_all("results/dryrun_*.json")
            if r.get("status") == "ok"}
    opt = {(r["arch"], r["shape"], r["mesh"]): r
           for r in load_all("results/perf_*.json")
           if r.get("status") == "ok"}
    lines = ["| cell | mesh | coll before → after | Δ | temp before → after |",
             "|---|---|---|---|---|"]
    for key in sorted(opt):
        if key not in base:
            continue
        b, o = base[key], opt[key]
        cb = b["collectives"]["total_bytes"]
        co = o["collectives"]["total_bytes"]
        tb = b["memory"]["temp_bytes"] / 2 ** 30
        to = o["memory"]["temp_bytes"] / 2 ** 30
        d = 100.0 * (co / max(cb, 1) - 1)
        lines.append(
            f"| {key[0]} × {key[1]} | {key[2]} | {cb:.2e} → {co:.2e} B | "
            f"{d:+.0f} % | {tb:.1f} → {to:.1f} GiB |")
    return "\n".join(lines)


def main() -> None:
    rows = load_all("results/dryrun_*.json")
    an = analyze(rows)
    print(markdown_table(an))
    print()
    print(markdown_table(an, mesh="2x16x16"))
    with open("results/roofline.json", "w") as f:
        json.dump(an, f, indent=1, default=str)
    print("\nwrote results/roofline.json")
    print("\n## §Perf optimized vs baseline\n")
    print(perf_comparison())


if __name__ == "__main__":
    main()
