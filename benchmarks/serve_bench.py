"""Macro serve benchmark — throughput trajectory through the paged serve loop.

Emits ``BENCH_serve.json`` with tokens/s vs. batch:

* ``simulated_32k`` — DeepSeek-V3.2-Exp at 32K context on the calibrated
  H800 profile: the batch sweep of the paper's Figure 1, with the ESS rows
  run through the **paged-transfer model** (page-granular writeback DMA +
  page-granular host reservations) and the host-side admission ceilings
  (dense per-slot pin vs. free-page accounting) alongside.
* ``live_smoke`` — the real ``ServeSession`` continuous-batching loop on
  the smoke arch at >= 2 batch sizes (CPU wall times; structural numbers,
  the modelled column carries the 32K-equivalent projection), now with
  chunked decode-interleaved prefill (TTFT + chunk counts per point).
* ``smoke_trajectory`` (``--smoke``) — appends one 2-slot/5-request
  interleaved-prefill tokens/s point per run, so the perf trajectory
  accumulates across CI runs instead of being overwritten.  Each point
  now carries an ``mtp`` sub-point (Q=1 tokens/s vs MTP depth-2
  accepted-tokens/s on the same config and params; zero-init, so every
  draft matches the model's argmax — ideal acceptance isolates the
  engine's round mechanics and keeps the point deterministic), a
  ``dispatch`` sub-point (compiled StepProgram vs eager op-by-op
  ``rounds_per_s`` on the same workload; asserts compiled >= eager and
  that the two modes' streams match) and a ``latency`` sub-point
  (p50/p95 TTFT and inter-token gap derived from ``TokenEvent``
  timestamps through the public ``EssEngine`` API).  A ``pd`` sub-point
  drives the PD-disaggregated ``EssCluster`` (1 prefill + 2 decode
  workers, same total decode slots) against the single engine: streams
  must be bitwise identical across the handoff and decode goodput no
  worse.  The simulated sweeps carry ``ess_pd``/``ess_pd_q8`` columns —
  the ESS rows with the per-sequence inter-node migration cost
  amortized over each sequence's decode rounds.

All live rows drive the serve loop through ``EssEngine.generate``
(``repro.serving.api``) — the same front-end real clients use.

    PYTHONPATH=src python benchmarks/serve_bench.py [--out BENCH_serve.json]
    PYTHONPATH=src python benchmarks/serve_bench.py --smoke
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax


def simulated_trajectory(context: int = 32768) -> dict:
    import dataclasses

    from repro.simulator.costmodel import (LATENT_Q8_BYTES, ServeConfig,
                                           max_feasible_batch,
                                           max_host_admission_batch,
                                           pd_migration_time_per_seq)
    from repro.simulator.hardware import H800_EP32
    from repro.simulator.pipeline import simulate_step, throughput_node

    hw = H800_EP32
    base = ServeConfig(batch_per_gpu=52, context=context, mtp=2,
                       accept_ratio=1.7, sparse_memory_ratio=1.0,
                       offload=False, overlap="layerwise")
    ess = dataclasses.replace(base, sparse_memory_ratio=0.21, offload=True,
                              paged_host=True)
    # async-offload pipeline: indexer-driven prefetch stages most misses
    # a round ahead, so only the residual misses pay a synchronous fetch
    essa = dataclasses.replace(ess, async_offload=True)
    # quantized host tier: int8 pages + f16 row scales shrink the host
    # reservation and every PCIe transfer from 656 to 578 B/row; compute
    # terms are untouched (the device pool stays bf16)
    essq = dataclasses.replace(ess, cache_bytes_per_row=LATENT_Q8_BYTES)
    essqa = dataclasses.replace(essq, async_offload=True)
    gpu_cap = max_feasible_batch(hw, base)

    # PD-disaggregated columns: decode nodes run the same ESS round, plus
    # one inter-node handoff per sequence lifetime (prompt pages + ikeys
    # across the EP fabric, storage dtype = wire format), amortized over
    # the sequence's decode rounds.  The quantized tier's smaller pages
    # shrink the handoff by the same 578/656 row-byte factor.
    AVG_NEW = 256            # mean generated tokens per sequence

    def pd_throughput(sc) -> float:
        t_round = simulate_step(hw, sc)
        rounds_per_seq = AVG_NEW / sc.accept_ratio
        t_mig = pd_migration_time_per_seq(hw, sc)
        t_eff = t_round + t_mig / rounds_per_seq
        return sc.gpus_per_node * sc.batch_per_gpu * sc.accept_ratio / t_eff

    rows = []
    for bs in [8, 16, 32, 52, 64, 96, 128, 160]:
        sc_b = dataclasses.replace(base, batch_per_gpu=bs)
        sc_e = dataclasses.replace(ess, batch_per_gpu=bs)
        sc_a = dataclasses.replace(essa, batch_per_gpu=bs)
        sc_q = dataclasses.replace(essq, batch_per_gpu=bs)
        sc_qa = dataclasses.replace(essqa, batch_per_gpu=bs)
        rows.append({
            "batch": bs,
            "baseline_tokens_per_s": round(throughput_node(hw, sc_b), 1),
            "baseline_feasible_on_gpu": bs <= gpu_cap,
            "ess_paged_tokens_per_s": round(throughput_node(hw, sc_e), 1),
            "ess_async_tokens_per_s": round(throughput_node(hw, sc_a), 1),
            "ess_q8_tokens_per_s": round(throughput_node(hw, sc_q), 1),
            "ess_q8_async_tokens_per_s": round(throughput_node(hw, sc_qa),
                                               1),
            "ess_pd_tokens_per_s": round(pd_throughput(sc_e), 1),
            "ess_pd_q8_tokens_per_s": round(pd_throughput(sc_q), 1),
        })
    return {
        "hardware": hw.name,
        "context": context,
        "prefetch_hit_rate": essa.prefetch_hit_rate,
        "q8_row_bytes": LATENT_Q8_BYTES,
        "gpu_batch_ceiling_dense": gpu_cap,
        "host_admission_ceiling_dense": max_host_admission_batch(
            hw, dataclasses.replace(ess, paged_host=False)),
        "host_admission_ceiling_paged": max_host_admission_batch(hw, ess),
        "host_admission_ceiling_paged_q8": max_host_admission_batch(
            hw, essq),
        "pd_avg_new_tokens": AVG_NEW,
        "pd_migration_s_per_seq": round(
            pd_migration_time_per_seq(hw, ess), 6),
        "pd_migration_s_per_seq_q8": round(
            pd_migration_time_per_seq(hw, essq), 6),
        "trajectory": rows,
    }


def live_smoke_trajectory(batches=(2, 4)) -> list[dict]:
    from repro.cache import latent_cache as LC
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import EssEngine, SamplingParams

    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    PROMPT, NEW, SMAX = 12, 4, 32
    rows = []
    for bs in batches:
        engine = EssEngine(params, cfg, num_slots=bs, max_seq=SMAX)
        outs = engine.generate([PROMPT] * (2 * bs),     # 2x slots stream
                               SamplingParams(max_tokens=NEW),
                               max_rounds=100)
        assert all(o.finish_reason == "length" for o in outs)
        report = engine.session.report
        rows.append({
            "batch": bs,
            "requests": len(outs),
            "rounds": report.rounds,
            "decode_tokens": report.decode_tokens,
            "tokens_per_s": round(report.tokens_per_s, 2),
            "prefill_chunks": report.prefill_chunks,
            "prefill_tokens": report.prefill_tokens,
            "mean_ttft_s": round(report.mean_ttft_s, 4),
            "pages": report.num_pages,
            "peak_pages_in_use": report.peak_pages_in_use,
            "page_rows": cfg.ess.host_page_rows,
            # measured capacity/transfer accounting (dtype-aware): the
            # host-tier pin of one fully mapped slot, and the round's
            # actual PCIe traffic from the ServeReport byte counters
            "host_bytes_per_row": report.host_bytes_per_row,
            "host_bytes_per_slot": (LC.num_blocks(cfg, SMAX)
                                    * LC.host_page_bytes(cfg,
                                                         cfg.param_dtype)),
            "h2d_bytes": report.h2d_bytes,
            "d2h_bytes": report.d2h_bytes,
            "transfer_bytes_per_round":
                round(report.transfer_bytes_per_round, 1),
            "context_equiv_note":
                f"smoke arch, max_seq={SMAX}; pool/context and page/context "
                f"ratios match the 32K cell "
                f"(sparse_memory_ratio={cfg.ess.sparse_memory_ratio})",
        })
        # pipelined variant of the same workload: stream parity is the
        # correctness bar, the prefetch counters the live hit-rate signal
        eng_o = EssEngine(params, cfg, num_slots=bs, max_seq=SMAX,
                          overlap=True)
        outs_o = eng_o.generate([PROMPT] * (2 * bs),
                                SamplingParams(max_tokens=NEW),
                                max_rounds=100)
        assert [o.tokens for o in outs_o] == [o.tokens for o in outs]
        m_o = eng_o.metrics()
        rows[-1]["overlap"] = {
            "rounds_per_s": round(eng_o.session.report.rounds_per_s, 2),
            "prefetch_hits": m_o["prefetch_hits"],
            "prefetch_misses": m_o["prefetch_misses"],
            "prefetch_wasted_rows": m_o["prefetch_wasted_rows"],
            "prefetch_hit_rate": round(m_o["prefetch_hit_rate"], 3),
        }
    return rows


_SMOKE_WORKLOAD = [(40, 6),   # long prompt streams in chunks...
                   (8, 8), (8, 8), (12, 6), (12, 6)]   # ...others decode


def smoke_point(prefill_chunk: int = 8) -> dict:
    """One 2-slot/5-request interleaved-prefill point (CI smoke): a long
    prompt streams in chunks while short requests keep decoding —
    driven through the public ``EssEngine`` front-end."""
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import EssEngine, SamplingParams

    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    prompts = [p for p, _ in _SMOKE_WORKLOAD]
    sp = [SamplingParams(max_tokens=n) for _, n in _SMOKE_WORKLOAD]

    # first pass warms the StepProgram caches (a cold session is
    # compile-dominated); the second measures the steady state
    for _ in range(2):
        engine = EssEngine(params, cfg, num_slots=2, max_seq=64,
                           prefill_chunk=prefill_chunk)
        outs = engine.generate(prompts, sp, max_rounds=120)
        assert all(o.finish_reason == "length" for o in outs)
        report = engine.session.report
    assert report.prefill_chunks > len(prompts)    # chunking engaged
    return {
        "slots": 2,
        "requests": len(prompts),
        "prefill_chunk": prefill_chunk,
        "rounds": report.rounds,
        "decode_tokens": report.decode_tokens,
        "prefill_chunks": report.prefill_chunks,
        "prefill_tokens": report.prefill_tokens,
        "tokens_per_s": round(report.tokens_per_s, 2),
        "mean_ttft_s": round(report.mean_ttft_s, 4),
        "wall_s": round(report.wall_s, 2),
        "host_bytes_per_row": report.host_bytes_per_row,
        "h2d_bytes": report.h2d_bytes,
        "d2h_bytes": report.d2h_bytes,
        "transfer_bytes_per_round":
            round(report.transfer_bytes_per_round, 1),
    }


def latency_smoke_point(prefill_chunk: int = 8) -> dict:
    """p50/p95 TTFT and inter-token gap from ``TokenEvent`` timestamps on
    the standard smoke workload (warm second pass — the cold pass is
    compile-dominated and would report multi-second TTFT)."""
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import EssEngine, SamplingParams

    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    prompts = [p for p, _ in _SMOKE_WORKLOAD]
    sp = [SamplingParams(max_tokens=n) for _, n in _SMOKE_WORKLOAD]
    for _ in range(2):
        engine = EssEngine(params, cfg, num_slots=2, max_seq=64,
                           prefill_chunk=prefill_chunk)
        outs = engine.generate(prompts, sp, max_rounds=120)
        assert all(o.finish_reason == "length" for o in outs)
    m = engine.metrics()
    assert m["ttft_p50_s"] > 0 and m["itl_p50_s"] >= 0
    return {
        "ttft_p50_s": round(m["ttft_p50_s"], 4),
        "ttft_p95_s": round(m["ttft_p95_s"], 4),
        "itl_p50_s": round(m["itl_p50_s"], 5),
        "itl_p95_s": round(m["itl_p95_s"], 5),
        "n_token_events": m["n_token_events"],
        "note": "warm engine, 2-slot/5-request interleaved-prefill "
                "workload; stamps from TokenEvent deliveries",
    }


def mtp_smoke_point(depth: int = 2) -> dict:
    """Q=1 vs MTP speculative accepted-tokens/s on the *same* config,
    params and request set.

    Zero-init params make every MTP draft match the model's greedy
    prediction (all logits tie at zero, argmax 0), so acceptance is
    deterministically 1.0 and the point measures the engine's
    verify-round mechanics: depth+1 tokens emitted per round vs one.
    ``accepted_tokens_per_s`` counts emitted (accepted + bonus) tokens
    over wall time — the ServeReport's tokens/s semantics at Q>1."""
    import dataclasses

    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import EssEngine, SamplingParams

    cfg = dataclasses.replace(get_config("deepseek-v32-exp-ess-smoke"),
                              mtp_depth=depth)
    params = jax.tree.map(jnp.zeros_like,
                          init_params(jax.random.key(0), T.model_def(cfg)))

    def run(md):
        # first pass warms the per-shape dispatch caches (the smoke model
        # is compile-dominated otherwise); the second measures steady state
        for _ in range(2):
            eng = EssEngine(params, cfg, num_slots=2, max_seq=32,
                            mtp_depth=md)
            outs = eng.generate([8] * 4, SamplingParams(max_tokens=9),
                                max_rounds=200)
            assert all(o.finish_reason == "length" for o in outs)
        return outs, eng.session.report

    base_o, base_r = run(0)
    spec_o, spec_r = run(depth)
    # greedy streams identical across modes
    assert [o.tokens for o in base_o] == [o.tokens for o in spec_o]
    point = {
        "mtp_depth": depth,
        "accept_rate": round(spec_r.accept_rate, 3),
        "q1_tokens_per_s": round(base_r.tokens_per_s, 2),
        "accepted_tokens_per_s": round(spec_r.accepted_tokens_per_s, 2),
        "q1_rounds": base_r.rounds,
        "spec_rounds": spec_r.spec_rounds,
        "decode_tokens": spec_r.decode_tokens,
        "note": "zero-init params (ideal acceptance); same config/params "
                "for both columns",
    }
    assert point["accepted_tokens_per_s"] >= point["q1_tokens_per_s"], point
    return point


def dispatch_smoke_point() -> dict:
    """Compiled vs eager ``rounds_per_s`` on the same workload — the
    per-round dispatch-overhead comparison the donated StepPrograms
    exist for.  Both modes run the identical round functions (jitted vs
    op-by-op), so the streams must match and compiled must win: each
    eager round re-dispatches the whole unrolled layer stack op by op,
    the compiled round is one executable launch + one packed fetch."""
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import EssEngine, SamplingParams

    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))

    def run(compiled):
        best = 0.0
        outs = r = None
        for _ in range(2):     # first pass warms the jit/dispatch caches
            eng = EssEngine(params, cfg, num_slots=2, max_seq=32,
                            compiled=compiled)
            outs = eng.generate([8] * 4, SamplingParams(max_tokens=12),
                                max_rounds=200)
            assert all(o.finish_reason == "length" for o in outs)
            r = eng.session.report
            best = max(best, r.rounds_per_s)
        return outs, r, best

    oc, rc, comp = run(True)
    oe, _, eag = run(False)
    # mode parity on the bench workload
    assert [o.tokens for o in oc] == [o.tokens for o in oe]
    point = {
        "compiled_rounds_per_s": round(comp, 2),
        "eager_rounds_per_s": round(eag, 2),
        "speedup": round(comp / eag, 2) if eag else None,
        "rounds": rc.rounds,
        "note": "same params/workload, best-of-2 (first run warms the jit "
                "cache); compiled = donated StepPrograms + one fetch/round, "
                "eager = op-by-op debugging path",
    }
    assert point["compiled_rounds_per_s"] >= point["eager_rounds_per_s"], \
        point
    return point


def overlap_smoke_point() -> dict:
    """Pipelined (async-offload) vs synchronous ``rounds_per_s`` on the
    same workload/params — the plan/compute/commit pipeline's
    round-mechanics comparison.  Zero-init params keep the point
    deterministic; bit-exact stream parity between the modes is the
    pipeline's correctness bar (the staged rows must be byte-identical
    to what a synchronous host round trip would have served)."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import EssEngine, SamplingParams

    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = jax.tree.map(jnp.zeros_like,
                          init_params(jax.random.key(0), T.model_def(cfg)))

    def run(overlap):
        eng = EssEngine(params, cfg, num_slots=2, max_seq=512,
                        overlap=overlap)
        outs = eng.generate([8] * 4, SamplingParams(max_tokens=60),
                            max_rounds=500)
        assert all(o.finish_reason == "length" for o in outs)
        return outs, eng.session.report, eng.metrics()

    # max_seq=512 sizes the host tier like a real deployment (relative
    # to the smoke arch): the synchronous path's always-on per-layer
    # miss gathers scale with it, which is exactly the work the
    # pipelined path skips on zero-miss steady-state rounds.  Warm both
    # modes' jit caches first, then take *interleaved* best-of-3 trials:
    # alternating sync/overlap within one loop cancels machine drift
    # (thermal / scheduler) that an AAA/BBB ordering folds straight into
    # the comparison.  rounds_per_s already excludes each slot's
    # pipeline-fill rounds (identically in both modes), so the point
    # compares steady-state cadence.
    o_sync, _, _ = run(False)
    o_over, r_over, m_over = run(True)
    # pipeline parity: overlapped streams bitwise match synchronous ones
    assert [o.tokens for o in o_sync] == [o.tokens for o in o_over]
    sync = over = 0.0
    for _ in range(3):
        _, r_s, _ = run(False)
        _, r_over, m_over = run(True)
        sync = max(sync, r_s.rounds_per_s)
        over = max(over, r_over.rounds_per_s)
    point = {
        "sync_rounds_per_s": round(sync, 2),
        "overlap_rounds_per_s": round(over, 2),
        "speedup": round(over / sync, 3) if sync else None,
        "rounds": r_over.rounds,
        "fill_rounds": r_over.fill_rounds,
        "prefetch_hits": m_over["prefetch_hits"],
        "prefetch_misses": m_over["prefetch_misses"],
        "prefetch_wasted_rows": m_over["prefetch_wasted_rows"],
        "prefetch_hit_rate": round(m_over["prefetch_hit_rate"], 3),
        "note": "zero-init params, same workload, interleaved best-of-3; "
                "overlap = plan/compute/commit pipeline with "
                "double-buffered staging slab; streams must match "
                "bitwise; fill rounds excluded from cadence in both modes",
    }
    assert point["overlap_rounds_per_s"] >= point["sync_rounds_per_s"], point
    return point


def quant_smoke_point() -> dict:
    """Quantized (int8) host tier vs bf16 on the same workload/params —
    the capacity-and-bandwidth point the compressed tier exists for.

    Two sub-measurements:

    * **admission** — both modes get the *same* host-byte budget (sized
      to four int8 pages); the page pool floors it to whole pages of its
      storage dtype, so the quantized tier must admit >= 2x the
      concurrent batch.
    * **transfer** — an unbudgeted run of the identical workload at the
      same concurrency; H2D rows (useful misses) and D2H rows (decode
      writebacks) match row-for-row, so bytes/round must shrink by the
      row-byte ratio (42/80 = 0.525 on the smoke arch, <= 0.55 bound).
      Greedy streams are compared token-for-token: drift is the parity
      cost of quantization and must stay within the documented bound
      (exact match on this workload — the int8 roundtrip error is far
      below the smoke model's greedy decision margins).
    """
    import dataclasses

    from repro.cache import latent_cache as LC
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import EssEngine, SamplingParams
    from repro.serving.engine import ServeSession
    from repro.serving.scheduler import Request

    cfg = get_config("deepseek-v32-exp-ess-smoke")
    qcfg = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, host_cache_dtype="int8"))
    params = init_params(jax.random.key(0), T.model_def(cfg))

    # --- admission at a fixed host-byte budget --------------------------
    budget = 4 * LC.host_page_bytes(qcfg, qcfg.param_dtype)
    admitted = {}
    for name, c in (("bf16", cfg), ("q8", qcfg)):
        s = ServeSession(params, c, num_slots=4, max_seq=32,
                         host_byte_budget=budget)
        for rid in range(4):     # one page each (prompt 6 + 4 new <= 16)
            s.submit(Request(rid=rid, prompt_len=6, max_new_tokens=4))
        s.step_round()           # admission pass
        admitted[name] = len(s.sched.running)
        s.run(max_rounds=100)    # everyone still finishes (serialized)
        assert not s.sched.running and not s.sched.queue
    assert admitted["q8"] >= 2 * admitted["bf16"], admitted

    # --- transfer bytes/round + greedy drift at equal concurrency -------
    PROMPT, NEW = 10, 6
    runs = {}
    for name, c in (("bf16", cfg), ("q8", qcfg)):
        eng = EssEngine(params, c, num_slots=2, max_seq=32)
        outs = eng.generate([PROMPT] * 4, SamplingParams(max_tokens=NEW),
                            max_rounds=200)
        assert all(o.finish_reason == "length" for o in outs)
        runs[name] = ([o.tokens for o in outs], eng.session.report)
    toks_b, rep_b = runs["bf16"]
    toks_q, rep_q = runs["q8"]
    flat_b = [t for s in toks_b for t in s]
    flat_q = [t for s in toks_q for t in s]
    match = sum(a == b for a, b in zip(flat_b, flat_q)) / len(flat_b)
    ratio = rep_q.transfer_bytes_per_round / rep_b.transfer_bytes_per_round
    point = {
        "host_byte_budget": budget,
        "admitted_bf16": admitted["bf16"],
        "admitted_q8": admitted["q8"],
        "bytes_per_row_bf16": rep_b.host_bytes_per_row,
        "bytes_per_row_q8": rep_q.host_bytes_per_row,
        "h2d_bytes_bf16": rep_b.h2d_bytes,
        "h2d_bytes_q8": rep_q.h2d_bytes,
        "d2h_bytes_bf16": rep_b.d2h_bytes,
        "d2h_bytes_q8": rep_q.d2h_bytes,
        "transfer_bytes_per_round_bf16":
            round(rep_b.transfer_bytes_per_round, 1),
        "transfer_bytes_per_round_q8":
            round(rep_q.transfer_bytes_per_round, 1),
        "transfer_ratio": round(ratio, 3),
        "greedy_token_match": round(match, 3),
        "note": "same params/workload; admission at a 4-int8-page byte "
                "budget; transfer ratio bound 0.55 (nominal 42/80); "
                "greedy drift bound: exact stream match on this workload",
    }
    assert ratio <= 0.55, point
    assert match == 1.0, point
    return point


def pd_smoke_point() -> dict:
    """PD-disaggregated cluster (1 prefill + 2 decode workers) vs a
    single engine with the same total decode slots, on the same params
    and workload.

    Correctness bar: every stream is bitwise identical to the single
    engine's — the migration moves the complete per-request state.
    Perf bar: decode goodput per *slot-round* (decode tokens / rounds /
    decode slots) is no worse than the single engine's.  That is the
    structural claim of disaggregation: the single engine's slots spend
    rounds holding prompts through chunked prefill, a PD decode slot
    only ever holds a decoding request."""
    from repro.cluster import EssCluster
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import EssEngine, SamplingParams

    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    N, PROMPT, NEW = 8, 12, 6
    sp = SamplingParams(max_tokens=NEW)

    for _ in range(2):       # first pass warms the StepProgram caches
        eng = EssEngine(params, cfg, num_slots=4, max_seq=32,
                        prefill_chunk=8)
        outs = eng.generate([PROMPT] * N, sp, max_rounds=300)
        assert all(o.finish_reason == "length" for o in outs)
    rep = eng.session.report

    for _ in range(2):
        clu = EssCluster(params, cfg, num_prefill=1, num_decode=2,
                         num_slots=4, decode_slots=2, max_seq=32,
                         prefill_chunk=8)
        pouts = clu.generate([PROMPT] * N, sp, max_rounds=300)
        assert all(o.finish_reason == "length" for o in pouts)
    # bitwise stream parity across the PD split
    assert [o.tokens for o in pouts] == [o.tokens for o in outs]
    m = clu.metrics()
    assert m["migrations"] == N == m["installed"]

    pd_rounds = sum(w.session.report.rounds for w in clu.decode)
    single_goodput = rep.decode_tokens / (rep.rounds * 4)
    pd_goodput = m["decode_tokens"] / (pd_rounds * 2)
    point = {
        "requests": N,
        "topology": "1P(4 slots)+2D(2 slots each)",
        "single_slots": 4,
        "single_rounds": rep.rounds,
        "single_decode_tokens": rep.decode_tokens,
        "single_goodput_tokens_per_slot_round": round(single_goodput, 3),
        "cluster_steps": m["cluster_steps"],
        "pd_decode_rounds": pd_rounds,
        "pd_decode_tokens": m["decode_tokens"],
        "pd_goodput_tokens_per_slot_round": round(pd_goodput, 3),
        "migrations": m["migrations"],
        "wire_bytes": m["wire_bytes"],
        "stream_parity": True,
        "note": "same params/workload; streams bitwise identical across "
                "the PD handoff; goodput = decode tokens per decode "
                "slot-round — single-engine slots lose rounds to "
                "chunked prefill, PD decode slots never do",
    }
    assert pd_goodput >= single_goodput, point
    return point


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--skip-live", action="store_true",
                    help="simulator trajectory only")
    ap.add_argument("--smoke", action="store_true",
                    help="append one 2-slot/5-request interleaved-prefill "
                         "point to --out (keeps prior runs)")
    args = ap.parse_args(argv)

    if args.smoke:
        t0 = time.time()
        point = smoke_point()
        point["mtp"] = mtp_smoke_point()
        point["dispatch"] = dispatch_smoke_point()
        point["latency"] = latency_smoke_point()
        point["overlap"] = overlap_smoke_point()
        point["quant"] = quant_smoke_point()
        point["pd"] = pd_smoke_point()
        prev = {}
        if os.path.exists(args.out):
            try:
                with open(args.out) as f:
                    prev = json.load(f)
            except Exception:
                prev = {}              # corrupt file: restart the history
        prev.setdefault("smoke_trajectory", []).append(point)
        with open(args.out, "w") as f:
            json.dump(prev, f, indent=2)
        m = point["mtp"]
        d = point["dispatch"]
        lt = point["latency"]
        ov = point["overlap"]
        qt = point["quant"]
        pd = point["pd"]
        print(f"appended smoke point #{len(prev['smoke_trajectory'])} to "
              f"{args.out} ({round(time.time() - t0, 1)}s): "
              f"{point['tokens_per_s']} tok/s, "
              f"ttft {point['mean_ttft_s']}s, "
              f"{point['prefill_chunks']} prefill chunks; "
              f"mtp{m['mtp_depth']} {m['accepted_tokens_per_s']} "
              f"accepted-tok/s vs {m['q1_tokens_per_s']} q1-tok/s "
              f"(accept rate {m['accept_rate']}); "
              f"dispatch: compiled {d['compiled_rounds_per_s']} vs eager "
              f"{d['eager_rounds_per_s']} rounds/s "
              f"({d['speedup']}x); "
              f"latency: ttft p50/p95 {lt['ttft_p50_s']}/"
              f"{lt['ttft_p95_s']}s, itl p50/p95 {lt['itl_p50_s']}/"
              f"{lt['itl_p95_s']}s; "
              f"overlap: {ov['overlap_rounds_per_s']} vs sync "
              f"{ov['sync_rounds_per_s']} rounds/s ({ov['speedup']}x, "
              f"pf hit rate {ov['prefetch_hit_rate']}); "
              f"quant: {qt['admitted_q8']}/{qt['admitted_bf16']} admitted "
              f"at {qt['host_byte_budget']} B, transfer ratio "
              f"{qt['transfer_ratio']}, greedy match "
              f"{qt['greedy_token_match']}; "
              f"pd: {pd['pd_goodput_tokens_per_slot_round']} vs single "
              f"{pd['single_goodput_tokens_per_slot_round']} "
              f"tok/slot-round "
              f"({pd['migrations']} migrations, {pd['wire_bytes']} B wire, "
              f"streams bitwise equal)")
        return 0

    t0 = time.time()
    prev_smoke = None
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                prev_smoke = json.load(f).get("smoke_trajectory")
        except Exception:
            prev_smoke = None
    out = {"simulated_32k": simulated_trajectory(),
           "simulated_128k": simulated_trajectory(context=131072)}
    if not args.skip_live:
        out["live_smoke"] = live_smoke_trajectory()
    if prev_smoke:
        out["smoke_trajectory"] = prev_smoke   # full runs keep the history
    out["wall_s"] = round(time.time() - t0, 1)

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    sim = out["simulated_32k"]
    print(f"wrote {args.out} ({out['wall_s']}s)")
    print(f"  gpu ceiling (dense): {sim['gpu_batch_ceiling_dense']}; "
          f"host admission ceiling dense/paged: "
          f"{sim['host_admission_ceiling_dense']}/"
          f"{sim['host_admission_ceiling_paged']}")
    for r in sim["trajectory"]:
        print(f"  bs={r['batch']:4d}  base={r['baseline_tokens_per_s']:9.1f}"
              f"{'' if r['baseline_feasible_on_gpu'] else ' (infeasible)':13s}"
              f" ess_paged={r['ess_paged_tokens_per_s']:9.1f} tok/s")
    for r in out.get("live_smoke", []):
        ov = r.get("overlap", {})
        print(f"  live bs={r['batch']}: {r['tokens_per_s']} tok/s "
              f"({r['requests']} reqs, {r['rounds']} rounds, "
              f"peak pages {r['peak_pages_in_use']}/{r['pages']}; "
              f"overlap pf hits/misses/wasted "
              f"{ov.get('prefetch_hits')}/{ov.get('prefetch_misses')}/"
              f"{ov.get('prefetch_wasted_rows')})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
