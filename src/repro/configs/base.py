"""Architecture config dataclasses + registry.

Every assigned architecture is a frozen :class:`ArchConfig`; reduced ("smoke")
variants derive from the same constructor so tests exercise the identical
code path at laptop scale.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def latent_dim(self) -> int:           # cached per token: c_kv ++ k_rope
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class DSAConfig:
    """DeepSeek Sparse Attention (V3.2-Exp lightning indexer)."""
    index_heads: int = 64
    index_dim: int = 128
    index_topk: int = 2048


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 4
    d_expert: int = 2048            # per-expert intermediate dim
    num_shared: int = 0             # shared (always-on) experts
    first_dense_layers: int = 0     # leading dense layers (deepseek: 3)
    dense_d_ff: int = 0             # d_ff of those dense layers
    capacity_factor: float = 1.25   # train-time fixed-capacity dispatch
    router_bias: bool = False       # aux-loss-free bias routing (deepseek)
    routed_scale: float = 1.0       # deepseek routed_scaling_factor (2.5 v3)
    norm_topk: bool = True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD."""
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    ngroups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: SSM backbone + shared attention block every N layers."""
    attn_every: int = 6
    num_shared_attn: int = 2        # alternating shared transformer blocks


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 32
    encoder_seq: int = 1500         # whisper frame count after conv stub
    cross_kv_heads: int = 20


@dataclasses.dataclass(frozen=True)
class ESSOptions:
    """Paper technique switches (see repro.core)."""
    enabled: bool = False
    sparse_memory_ratio: float = 0.3   # pool entries / context entries
    max_miss_ratio: float = 0.25       # miss buffer size / top-k
    warmup_windows: int = 32
    overlap: str = "da"                # none | da | dba | layerwise
    offload_kv: bool = True            # host tier for the full cache
    pool_min_entries: int = 6400       # paper: ">= 6.4K" recommendation
    # paged host tier (KVDrive-style): the offloaded Total Memory Pool is a
    # global page pool + per-slot block tables instead of a dense
    # [B, max_seq] allotment, so host bytes track actual sequence lengths
    # and serve-loop admission is gated on free pages.
    paged_host: bool = True
    host_page_rows: int = 16           # latent rows per host page
    # storage dtype of the offloaded host latent tier: "bf16" (raw) or a
    # key of repro.distributed.compression.CACHE_QUANT_DTYPES ("int8" /
    # "fp8").  Quantized tiers carry one SCALE_DTYPE scale per row (a
    # per-page scale vector) and dequantize at miss width inside the
    # gather path; parity vs bf16 is bounds-based, not bitwise.
    host_cache_dtype: str = "bf16"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    attn_kind: str = "gqa"             # gqa | mla | none
    # attention details
    rope_theta: float = 10000.0
    rope_interleaved: bool = False
    qk_norm: bool = False
    qkv_bias: bool = False
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    query_scale: Optional[float] = None   # override head_dim**-0.5 (gemma2)
    # pattern: block kinds repeated; e.g. ("local","global") gemma2,
    # ("local",)*5+("global",) gemma3. None => all "global".
    layer_pattern: Optional[tuple[str, ...]] = None
    post_block_norm: bool = False      # gemma2/3 post-norms
    tie_embeddings: bool = True
    scale_embeddings: bool = False     # gemma: x *= sqrt(d_model)
    act: str = "silu"
    norm_eps: float = 1e-6
    local_rope_theta: Optional[float] = None   # gemma3 local layers
    # substructures
    mla: Optional[MLAConfig] = None
    dsa: Optional[DSAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    mrope_sections: Optional[tuple[int, ...]] = None   # qwen2-vl
    # system
    ess: ESSOptions = ESSOptions()
    sharding_profile: str = "tp"       # tp | 2d  (see distributed.sharding)
    scan_layers: bool = True
    remat: str = "dots"                # none | full | dots  (train-time)
    param_dtype: Any = jnp.bfloat16
    # frontends (stubbed): inputs are precomputed embeddings, not token ids
    embedding_inputs: bool = False
    mtp_depth: int = 0                 # deepseek multi-token-prediction modules

    @property
    def uses_attention(self) -> bool:
        return self.attn_kind != "none"

    def pattern_at(self, layer: int) -> str:
        if self.layer_pattern is None:
            return "global"
        return self.layer_pattern[layer % len(self.layer_pattern)]


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch carries the same 4 shape cells.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict[str, Any] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides) -> ArchConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
