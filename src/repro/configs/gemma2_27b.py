"""gemma2-27b [dense] — local+global alternating, logit softcap.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
[arXiv:2408.00118; hf]
"""

from repro.configs.base import ArchConfig, register


@register("gemma2-27b")
def gemma2_27b() -> ArchConfig:
    return ArchConfig(
        name="gemma2-27b",
        family="dense",
        num_layers=46,
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        attn_kind="gqa",
        sliding_window=4096,
        layer_pattern=("local", "global"),
        logit_softcap=30.0,
        attn_softcap=50.0,
        query_scale=(4608 // 32) ** -0.5,      # query_pre_attn_scalar=144
        post_block_norm=True,
        scale_embeddings=True,
        act="gelu_tanh",
        sharding_profile="tp",
    )


@register("gemma2-27b-smoke")
def gemma2_27b_smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma2-27b-smoke",
        family="dense",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
        attn_kind="gqa",
        sliding_window=16,
        layer_pattern=("local", "global"),
        logit_softcap=30.0,
        attn_softcap=50.0,
        query_scale=16.0 ** -0.5,
        post_block_norm=True,
        scale_embeddings=True,
        act="gelu_tanh",
        sharding_profile="tp",
    )
