"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H (kv=128 — MLA latent is head-shared) d_ff=2048
(per routed expert) vocab=129280, MoE 256e top-8.  [arXiv:2412.19437; hf]

Two registered variants:

* ``deepseek-v3-671b``       — V3: dense MLA over the latent cache.
* ``deepseek-v32-exp-ess``   — V3.2-Exp: + DSA lightning indexer (top-2048)
  and the paper's ESS offload-centric latent-cache management enabled.
"""

from repro.configs.base import (ArchConfig, DSAConfig, ESSOptions, MLAConfig,
                                MoEConfig, register)


def _base(name: str, dsa, ess) -> ArchConfig:
    return ArchConfig(
        name=name,
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=18432,                    # dense-layer d_ff
        vocab_size=129280,
        attn_kind="mla",
        rope_theta=10_000.0,
        tie_embeddings=False,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        dsa=dsa,
        moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                      num_shared=1, first_dense_layers=3, dense_d_ff=18432,
                      capacity_factor=1.25, router_bias=True,
                      routed_scale=2.5, norm_topk=True),
        mtp_depth=1,
        ess=ess,
        sharding_profile="2d",
    )


@register("deepseek-v3-671b")
def deepseek_v3_671b() -> ArchConfig:
    return _base("deepseek-v3-671b", dsa=None, ess=ESSOptions(enabled=False))


@register("deepseek-v32-exp-ess")
def deepseek_v32_exp_ess() -> ArchConfig:
    return _base("deepseek-v32-exp-ess",
                 dsa=DSAConfig(index_heads=64, index_dim=128, index_topk=2048),
                 # ratio/envelope from §Perf: -33 % collective bytes vs
                 # (0.3, 0.25); pool stays >= the paper's 6.4K floor
                 ess=ESSOptions(enabled=True, sparse_memory_ratio=0.25,
                                max_miss_ratio=0.125, warmup_windows=32,
                                overlap="layerwise", offload_kv=True,
                                host_page_rows=64))


@register("deepseek-v3-671b-smoke")
def deepseek_v3_671b_smoke() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b-smoke",
        family="moe",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="mla",
        tie_embeddings=False,
        mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        dsa=None,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, num_shared=1,
                      first_dense_layers=1, dense_d_ff=128,
                      capacity_factor=2.0, router_bias=True,
                      routed_scale=1.0),
        mtp_depth=1,
        sharding_profile="2d",
    )


@register("deepseek-v32-exp-ess-smoke")
def deepseek_v32_exp_ess_smoke() -> ArchConfig:
    import dataclasses
    cfg = deepseek_v3_671b_smoke()
    return dataclasses.replace(
        cfg, name="deepseek-v32-exp-ess-smoke",
        dsa=DSAConfig(index_heads=2, index_dim=16, index_topk=8),
        ess=ESSOptions(enabled=True, sparse_memory_ratio=0.5,
                       max_miss_ratio=0.5, warmup_windows=4, overlap="da",
                       pool_min_entries=8))
