"""gemma3-27b [dense] — 5:1 local:global, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.configs.base import ArchConfig, register


@register("gemma3-27b")
def gemma3_27b() -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b",
        family="dense",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        attn_kind="gqa",
        qk_norm=True,
        sliding_window=1024,
        layer_pattern=("local",) * 5 + ("global",),
        rope_theta=1_000_000.0,            # global layers
        local_rope_theta=10_000.0,         # local layers
        query_scale=(5376 // 32) ** -0.5,
        post_block_norm=True,
        scale_embeddings=True,
        act="gelu_tanh",
        sharding_profile="tp",
    )


@register("gemma3-27b-smoke")
def gemma3_27b_smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b-smoke",
        family="dense",
        num_layers=6,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="gqa",
        qk_norm=True,
        sliding_window=8,
        layer_pattern=("local",) * 5 + ("global",),
        rope_theta=1_000_000.0,
        local_rope_theta=10_000.0,
        query_scale=16.0 ** -0.5,
        post_block_norm=True,
        scale_embeddings=True,
        act="gelu_tanh",
        sharding_profile="tp",
    )
