"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32, MHA shared blocks) d_ff=14336 vocab=32000,
ssm_state=64.  [arXiv:2411.15242; unverified]
"""

from repro.configs.base import (ArchConfig, HybridConfig, SSMConfig, register)


@register("zamba2-7b")
def zamba2_7b() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=3584 // 32,
        d_ff=14336,
        vocab_size=32000,
        attn_kind="gqa",
        # chunk=128 from §Perf iteration 3: -7 % HLO bytes, -11 % temp vs 256
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                      ngroups=1, chunk=128),
        hybrid=HybridConfig(attn_every=6, num_shared_attn=2),
        sharding_profile="tp",
    )


@register("zamba2-7b-smoke")
def zamba2_7b_smoke() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b-smoke",
        family="hybrid",
        num_layers=7,               # 1 full group of 3 + remainder
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="gqa",
        ssm=SSMConfig(state_dim=8, head_dim=8, expand=2, conv_width=4,
                      ngroups=1, chunk=16),
        hybrid=HybridConfig(attn_every=3, num_shared_attn=2),
        sharding_profile="tp",
    )
