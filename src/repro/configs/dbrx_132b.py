"""dbrx-132b [moe] — 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
[hf:databricks/dbrx-base; unverified]
"""

from repro.configs.base import ArchConfig, MoEConfig, register


@register("dbrx-132b")
def dbrx_132b() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b",
        family="moe",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab_size=100352,
        attn_kind="gqa",
        rope_theta=500_000.0,
        tie_embeddings=False,
        moe=MoEConfig(num_experts=16, top_k=4, d_expert=10752,
                      num_shared=0, capacity_factor=1.25, norm_topk=True),
        sharding_profile="2d",
    )


@register("dbrx-132b-smoke")
def dbrx_132b_smoke() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        attn_kind="gqa",
        rope_theta=500_000.0,
        tie_embeddings=False,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=96,
                      capacity_factor=2.0, norm_topk=True),
        sharding_profile="2d",
    )
