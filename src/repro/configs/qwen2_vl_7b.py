"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (vision frontend STUB).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
[arXiv:2409.12191; hf]  input_specs() provides precomputed patch embeddings.
"""

from repro.configs.base import ArchConfig, register


@register("qwen2-vl-7b")
def qwen2_vl_7b() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-7b",
        family="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        attn_kind="gqa",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),       # t/h/w frequency pairs, sum=64
        embedding_inputs=True,             # patch/text embeds precomputed
        tie_embeddings=False,
        sharding_profile="tp",
    )


@register("qwen2-vl-7b-smoke")
def qwen2_vl_7b_smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-7b-smoke",
        family="vlm",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="gqa",
        qkv_bias=True,
        mrope_sections=(2, 3, 3),
        embedding_inputs=True,
        tie_embeddings=False,
        sharding_profile="tp",
    )
