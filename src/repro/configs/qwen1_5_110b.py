"""qwen1.5-110b [dense] — QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
[hf:Qwen/Qwen1.5-0.5B; hf]
"""

from repro.configs.base import ArchConfig, register


@register("qwen1.5-110b")
def qwen1_5_110b() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-110b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=49152,
        vocab_size=152064,
        attn_kind="gqa",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        sharding_profile="2d",             # 110B params need 2D weight sharding
    )


@register("qwen1.5-110b-smoke")
def qwen1_5_110b_smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-110b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        head_dim=8,
        d_ff=192,
        vocab_size=256,
        attn_kind="gqa",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        sharding_profile="2d",
    )
