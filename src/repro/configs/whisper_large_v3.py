"""whisper-large-v3 [audio] — encoder-decoder backbone, conv frontend STUB.

32L (enc) + 32L (dec) d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
input_specs() provides precomputed frame embeddings. [arXiv:2212.04356]
"""

from repro.configs.base import ArchConfig, EncDecConfig, register


@register("whisper-large-v3")
def whisper_large_v3() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,                      # decoder layers
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        head_dim=1280 // 20,
        d_ff=5120,
        vocab_size=51866,
        attn_kind="gqa",
        qkv_bias=True,
        act="gelu",
        encdec=EncDecConfig(encoder_layers=32, encoder_seq=1500,
                            cross_kv_heads=20),
        tie_embeddings=True,
        sharding_profile="tp",
    )


@register("whisper-large-v3-smoke")
def whisper_large_v3_smoke() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="gqa",
        qkv_bias=True,
        act="gelu",
        encdec=EncDecConfig(encoder_layers=2, encoder_seq=32,
                            cross_kv_heads=4),
        sharding_profile="tp",
    )
