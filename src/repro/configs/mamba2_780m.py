"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128.  [arXiv:2405.21060]
"""

from repro.configs.base import ArchConfig, SSMConfig, register


@register("mamba2-780m")
def mamba2_780m() -> ArchConfig:
    return ArchConfig(
        name="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        attn_kind="none",
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                      ngroups=1, chunk=256),
        sharding_profile="tp",
    )


@register("mamba2-780m-smoke")
def mamba2_780m_smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2-780m-smoke",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=256,
        attn_kind="none",
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      ngroups=1, chunk=16),
        sharding_profile="tp",
    )
