"""Architecture registry — importing this package registers all configs."""

from repro.configs.base import (ArchConfig, DSAConfig, ESSOptions, MoEConfig,
                                SHAPES, ShapeCell, SSMConfig, get_config,
                                list_archs)

# side-effect registration
from repro.configs import (dbrx_132b, deepseek_v3_671b, gemma2_27b,   # noqa
                           gemma3_27b, mamba2_780m, qwen1_5_110b,     # noqa
                           qwen2_vl_7b, qwen3_0_6b, whisper_large_v3, # noqa
                           zamba2_7b)                                 # noqa

ASSIGNED = [
    "zamba2-7b", "whisper-large-v3", "gemma2-27b", "gemma3-27b",
    "qwen3-0.6b", "qwen1.5-110b", "dbrx-132b", "deepseek-v3-671b",
    "qwen2-vl-7b", "mamba2-780m",
]

__all__ = ["ArchConfig", "DSAConfig", "ESSOptions", "MoEConfig", "SHAPES",
           "ShapeCell", "SSMConfig", "get_config", "list_archs", "ASSIGNED"]
