"""qwen3-0.6b [dense] — qk_norm, GQA.

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
[hf:Qwen/Qwen3-8B; hf]
"""

from repro.configs.base import ArchConfig, register


@register("qwen3-0.6b")
def qwen3_0_6b() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b",
        family="dense",
        num_layers=28,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab_size=151936,
        attn_kind="gqa",
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        sharding_profile="tp",
    )


@register("qwen3-0.6b-smoke")
def qwen3_0_6b_smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="gqa",
        qk_norm=True,
        rope_theta=1_000_000.0,
        sharding_profile="tp",
    )
