"""ESS core: the paper's offload-centric latent-cache management.

* ``lru_pool``   — GPU-side Sparse Memory Pool (LRU eviction/admission)
* ``warmup``     — LRU-Warmup from the last prefill windows
* ``offload``    — host-tier placement + FlashTrans-analogue transfers
* ``overlap``    — DA / DBA compute-communication overlap step builders
* ``policy``     — layer-wise overlap strategy selection
* ``similarity`` — Intra-Layer Similarity (Eq. 1)
"""

from repro.core import lru_pool, offload, overlap, policy, similarity, warmup

__all__ = ["lru_pool", "offload", "overlap", "policy", "similarity", "warmup"]
