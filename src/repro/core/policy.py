"""Layer-wise overlap strategy selection (paper §3.3, Figure 7/8).

The paper's decision rule: per-layer expected Cache-Miss count (stable
across context lengths per Figure 8, so obtainable by offline profiling)
and the context length determine whether DA fully hides the transfer or
DBA's split-indexer compute is needed.

The crossover is computed from the same cost model the simulator uses:

    DA  exposed  = max(0, t_fetch(miss) - t_attn0 - t_preattn)
    DBA exposed  = max(0, t_fetch(miss) - t_attn0 - t_preattn
                        - 0.5 * t_indexer) + t_split_overhead

choose DBA when its exposed+overhead is lower; below ``da_floor`` misses DA
is always chosen (paper: DA favourable at low miss counts — no splitting
overhead)."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class OverlapCosts:
    """Per-layer decode timings (seconds) from offline profiling / simulator."""
    t_attn0: float          # sparse attention over pool hits
    t_preattn: float        # q projections etc. (independent of fetch)
    t_indexer: float        # full indexer compute (scales with context)
    t_split_overhead: float # DBA batch-split loss
    fetch_bw: float         # effective H2D bytes/s (FlashTrans-grade)
    block_bytes: int        # latent entry size


def exposed_da(c: OverlapCosts, miss: float) -> float:
    t_fetch = miss * c.block_bytes / c.fetch_bw
    return max(0.0, t_fetch - c.t_attn0 - c.t_preattn)


def exposed_dba(c: OverlapCosts, miss: float) -> float:
    t_fetch = miss * c.block_bytes / c.fetch_bw
    hidden = c.t_attn0 + c.t_preattn + 0.5 * c.t_indexer
    return max(0.0, t_fetch - hidden) + c.t_split_overhead


def dba_threshold(c: OverlapCosts, max_miss: int = 4096) -> int:
    """Smallest miss count at which DBA beats DA (paper's empirical switch)."""
    for m in range(0, max_miss + 1, 8):
        if exposed_dba(c, m) < exposed_da(c, m):
            return m
    return max_miss + 1


def choose_layerwise(miss_profile: np.ndarray, costs: OverlapCosts
                     ) -> list[str]:
    """miss_profile [L]: offline expected misses per layer -> strategy/layer."""
    thr = dba_threshold(costs)
    return ["dba" if m >= thr else "da" for m in np.asarray(miss_profile)]
