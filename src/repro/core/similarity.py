"""Intra-Layer Similarity (paper Eq. 1):

    r_t^l = |K_{t-1}^l ∩ K_t^l| / |K_t^l|

the temporal-locality metric that justifies the whole offload design
(paper §2.2, Figure 2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def intra_layer_similarity(prev_ids: jax.Array, cur_ids: jax.Array,
                           prev_valid: jax.Array | None = None,
                           cur_valid: jax.Array | None = None) -> jax.Array:
    """prev_ids/cur_ids [..., K] int32 -> similarity [...] in [0,1].

    Membership via broadcast compare (K x K): exact set semantics as long
    as ids within a row are unique (top-k output is)."""
    eq = cur_ids[..., :, None] == prev_ids[..., None, :]
    if prev_valid is not None:
        eq &= prev_valid[..., None, :]
    member = eq.any(axis=-1)
    if cur_valid is not None:
        member &= cur_valid
        denom = jnp.maximum(cur_valid.sum(axis=-1), 1)
    else:
        denom = cur_ids.shape[-1]
    return member.sum(axis=-1) / denom


def similarity_trace(ids_by_step: jax.Array) -> jax.Array:
    """ids_by_step [T, ..., K] -> r_t [T-1, ...] consecutive-step similarity."""
    return jax.vmap(intra_layer_similarity)(ids_by_step[:-1], ids_by_step[1:])
