"""The GPU-side **Sparse Memory Pool** with LRU eviction/admission (paper §3.2).

Fully functional, fixed-shape JAX so the whole decode step stays inside one
jit program.  Per (layer, sequence) the pool holds ``P`` latent rows; an
inverse map ``slot_of`` makes lookup O(K) gathers instead of O(K·P)
compares.

State (leading batch dim B everywhere):

* ``data     [B, P, D]``  resident latent rows
* ``ids      [B, P]``     token position occupying each slot (-1 empty)
* ``last_use [B, P]``     LRU step stamp (-1 empty)
* ``slot_of  [B, S]``     inverse map: position -> slot (-1 not resident)
* ``step     []``         monotone step counter

Fixed-shape miss handling: each step fetches at most ``M`` rows (the
provisioned H2D envelope).  ``lax.top_k`` returns ids in descending indexer
score order, so when misses overflow M the *lowest-scoring* entries are the
ones dropped (masked out of attention, softmax renormalizes exactly over
the attended set).  ``stats.overflow`` counts them; sizing M per the paper's
miss profiles (16–605/batch at ratio 0.2) makes overflow rare.

Jit contract: every state transition here (``lookup`` / ``admit`` /
``tick`` / ``invalidate_beyond``) is fixed-shape and host-sync-free, so
the whole per-round sequence — including the speculative rollback —
traces into the serve loop's donated StepProgram
(:mod:`repro.serving.step`); only ``check_consistent`` is host-side
(tests/debugging).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PoolState(NamedTuple):
    data: jax.Array        # [B, P, D]
    ids: jax.Array         # [B, P] int32
    last_use: jax.Array    # [B, P] int32
    slot_of: jax.Array     # [B, S] int32
    step: jax.Array        # [] int32


class Lookup(NamedTuple):
    slot: jax.Array        # [B, K] pool slot of each requested id (-1 miss)
    hit: jax.Array         # [B, K] bool
    miss_ids: jax.Array    # [B, M] requested-but-absent ids (-1 padding)
    miss_rank: jax.Array   # [B, K] rank of each miss among misses (or big)
    n_miss: jax.Array      # [B] int32 true miss count (incl. overflow)


class PoolStats(NamedTuple):
    hits: jax.Array        # [B]
    misses: jax.Array      # [B]
    overflow: jax.Array    # [B] misses beyond the M envelope (dropped)


def init_pool(batch: int, pool_entries: int, max_seq: int, dim: int,
              dtype=jnp.bfloat16) -> PoolState:
    return PoolState(
        data=jnp.zeros((batch, pool_entries, dim), dtype),
        ids=jnp.full((batch, pool_entries), -1, jnp.int32),
        last_use=jnp.full((batch, pool_entries), -1, jnp.int32),
        slot_of=jnp.full((batch, max_seq), -1, jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


def lookup(pool: PoolState, req_ids: jax.Array, req_valid: jax.Array,
           max_misses: int, *, slot_mask: jax.Array | None,
           dedup: bool = True) -> tuple[PoolState, Lookup, PoolStats]:
    """Resolve requested cache ids against the pool.

    req_ids [B,K] (score-descending), req_valid [B,K].  Touches hit slots
    (LRU stamp).  Returns miss buffer of fixed width ``max_misses``.

    ``slot_mask`` is **required, keyword-only** (ESS001 — see ANALYSIS.md):
    a ``[B]`` bool mask ANDs into ``req_valid`` so a frozen/freed batch row
    neither touches LRU stamps nor requests fetches; ``None`` states
    explicitly that every row is live (or that ``req_valid`` already
    encodes the gating).

    With ``dedup`` the request list may contain **duplicates** (a Q>1
    speculative-verify step flattens every draft's top-k into one list,
    and drafts routinely select the same positions).  Duplicate misses
    share the first occurrence's miss-buffer rank, so the buffer holds
    *unique* positions: each row is fetched once, and :func:`admit` never
    installs the same position into two pool slots — a duplicate admit
    left a zombie entry (forward map without inverse link) that wasted
    capacity and, on its eventual eviction, clobbered the live
    duplicate's ``slot_of`` link.  Dedup costs an O(K^2) compare; callers
    whose requests are distinct by construction (one query's top-k, a
    warmup window) pass ``dedup=False`` for the linear-rank path — the
    two are bit-identical on duplicate-free input.
    """
    B, K = req_ids.shape
    if slot_mask is not None:
        req_valid = req_valid & slot_mask[:, None]
    bi = jnp.arange(B)[:, None]
    safe_ids = jnp.clip(req_ids, 0, pool.slot_of.shape[1] - 1)
    slot = jnp.take_along_axis(pool.slot_of, safe_ids, axis=1)   # [B,K]
    hit = (slot >= 0) & req_valid
    miss = (~hit) & req_valid

    # touch hits
    touch_slot = jnp.where(hit, slot, pool.ids.shape[1])         # OOB -> drop
    last_use = pool.last_use.at[bi, touch_slot].max(
        pool.step, mode="drop")

    # pack misses (score order preserved): unique misses get consecutive
    # ranks; a duplicate miss inherits its first occurrence's rank
    if dedup:
        eq = req_ids[:, :, None] == req_ids[:, None, :]          # [B,K,K]
        earlier = jnp.tril(jnp.ones((K, K), bool), k=-1)[None]   # i < j
        dup = miss & (eq & earlier & miss[:, None, :]).any(-1)
        unique_miss = miss & ~dup
        rank_u = jnp.cumsum(unique_miss.astype(jnp.int32), axis=1) - 1
        # rank of request j = rank of the unique miss sharing its id
        # (itself when unique); at most one unique miss per id, so the
        # sum selects it
        rank = jnp.einsum("bji,bi->bj", (eq & unique_miss[:, None, :])
                          .astype(jnp.int32),
                          jnp.where(unique_miss, rank_u, 0))
    else:
        unique_miss = miss
        rank = jnp.cumsum(miss.astype(jnp.int32), axis=1) - 1
    rank = jnp.where(miss, rank, K + max_misses)                 # invalid big
    scat = jnp.where(rank < max_misses, rank, max_misses)        # OOB -> drop
    miss_ids = jnp.full((B, max_misses + 1), -1, jnp.int32)
    miss_ids = miss_ids.at[bi, scat].set(req_ids, mode="drop")[:, :max_misses]

    n_miss = unique_miss.sum(axis=1)                 # rows actually fetched
    stats = PoolStats(hits=hit.sum(axis=1), misses=n_miss,
                      overflow=jnp.maximum(n_miss - max_misses, 0))
    return (pool._replace(last_use=last_use),
            Lookup(slot, hit, miss_ids, rank, n_miss), stats)


def admit(pool: PoolState, miss_ids: jax.Array, rows: jax.Array, *,
          slot_mask: jax.Array | None,
          protect_slots: jax.Array | None = None) -> PoolState:
    """LRU-evict |M| coldest slots and write the fetched rows into them.

    miss_ids [B,M] (-1 padding rows are ignored), rows [B,M,D].
    ``slot_mask`` is **required, keyword-only** (ESS001): a ``[B]`` bool
    mask voids the admissions of masked batch rows (their pool state is
    frozen in-step); ``None`` states explicitly that every row is live or
    that masked rows' ``miss_ids`` are already all ``-1``.
    protect_slots [B,Kp]: slots that must not be evicted this step (current
    hits are protected automatically by their fresh LRU stamp as long as
    P >= K; pass explicit slots for extra safety with tiny pools).

    A Q>1 step's miss envelope can exceed the pool size (``M = ratio*K*Q``
    vs ``P`` entries); admission is then capped at the ``P``
    highest-scoring misses — the fetch itself still serves attention at
    full width, only residency is capacity-clipped.
    """
    B, M = miss_ids.shape
    if slot_mask is not None:
        miss_ids = jnp.where(slot_mask[:, None], miss_ids, -1)
    P = pool.ids.shape[1]
    if M > P:
        miss_ids, rows = miss_ids[:, :P], rows[:, :P]
        M = P
    bi = jnp.arange(B)[:, None]
    valid = miss_ids >= 0

    score = pool.last_use                                        # [B,P]
    if protect_slots is not None:
        ps = jnp.where(protect_slots >= 0, protect_slots, P)
        score = score.at[bi, ps].set(jnp.iinfo(jnp.int32).max, mode="drop")
    # coldest M slots (empty slots have last_use=-1 -> chosen first)
    _, evict = jax.lax.top_k(-score, M)                          # [B,M]

    tgt = jnp.where(valid, evict, P)                             # OOB -> drop
    old_ids = jnp.take_along_axis(pool.ids, evict, axis=1)       # [B,M]
    old_valid = (old_ids >= 0) & valid
    # clear inverse map of evicted ids
    clear_pos = jnp.where(old_valid, old_ids, pool.slot_of.shape[1])
    slot_of = pool.slot_of.at[bi, clear_pos].set(-1, mode="drop")
    # install new entries
    slot_of = slot_of.at[bi, jnp.where(valid, miss_ids,
                                       pool.slot_of.shape[1])].set(
        evict, mode="drop")
    ids = pool.ids.at[bi, tgt].set(miss_ids, mode="drop")
    last_use = pool.last_use.at[bi, tgt].set(pool.step, mode="drop")
    data = pool.data.at[bi, tgt].set(rows.astype(pool.data.dtype),
                                     mode="drop")
    return PoolState(data, ids, last_use, slot_of, pool.step)


def tick(pool: PoolState) -> PoolState:
    return pool._replace(step=pool.step + 1)


def invalidate_beyond(pool: PoolState, lens: jax.Array) -> PoolState:
    """Drop pool entries for positions >= lens[b] (speculative-decode
    rollback: rejected draft positions will be re-written with different
    content, so stale pool rows must not survive).

    Ordering contract (speculative rollback): call this **after** the
    verify step's :func:`admit` + :func:`tick`.  A Q>1 verify step's
    flattened lookup may legitimately admit rows *at draft positions*
    (query ``q`` requests positions appended by queries ``< q``); those
    entries must exist when they are invalidated, otherwise a stale
    ``slot_of`` link would survive the rollback and a later occupant of
    the position would take a hit on the rejected draft's latent.  The
    clear is total for the forward map *and* the inverse map — ``ids`` /
    ``last_use`` keyed by resident position, ``slot_of`` keyed by
    position — so it is idempotent and safe to apply to an already-clean
    slot (a frozen ``slot_mask`` row passes its unchanged ``lens``).
    """
    stale = pool.ids >= lens[:, None]                            # [B,P]
    ids = jnp.where(stale, -1, pool.ids)
    last_use = jnp.where(stale, -1, pool.last_use)
    pos = jnp.arange(pool.slot_of.shape[1])[None, :]
    slot_of = jnp.where(pos >= lens[:, None], -1, pool.slot_of)
    return pool._replace(ids=ids, last_use=last_use, slot_of=slot_of)


def check_consistent(pool: PoolState) -> bool:
    """Host-side invariant check (tests / debugging): the forward map
    (``ids``) and inverse map (``slot_of``) must mirror each other exactly
    — every resident id points back at its slot and vice versa, with no
    dangling links after admit/evict/invalidate interleavings."""
    import numpy as np
    ids = np.asarray(pool.ids)
    slot_of = np.asarray(pool.slot_of)
    last_use = np.asarray(pool.last_use)
    B, P = ids.shape
    for b in range(B):
        res = ids[b][ids[b] >= 0]
        if len(res) != len(set(res.tolist())):  # esslint: disable=ESS002 — numpy, host-only helper
            return False                     # duplicate resident position
        for s in range(P):
            if ids[b, s] >= 0 and slot_of[b, ids[b, s]] != s:
                return False                 # forward without inverse
            if ids[b, s] < 0 and last_use[b, s] >= 0:
                return False                 # empty slot with live stamp
        for pos_ in range(slot_of.shape[1]):
            if slot_of[b, pos_] >= 0 and ids[b, slot_of[b, pos_]] != pos_:
                return False                 # inverse without forward
    return True


def gather_resident(pool: PoolState, slot: jax.Array, hit: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """Gather hit rows [B,K,D] from the pool (miss rows zero, masked)."""
    safe = jnp.where(hit, slot, 0)
    rows = jnp.take_along_axis(pool.data, safe[..., None], axis=1)
    return jnp.where(hit[..., None], rows, 0), hit


def pool_entries_for(ratio: float, context_len: int, topk: int,
                     min_entries: int) -> int:
    """Paper's Sparse-Memory-Ratio -> pool size; floor at max(topk, 6.4K-ish
    recommendation scaled)."""
    p = int(ratio * context_len)
    return max(p, topk, min(min_entries, context_len))
