"""LRU-Warmup (paper §3.2): preheat the Sparse Memory Pool from the Top-2K
index sets of the last ``W`` prefill windows, inserted oldest-to-newest so
the LRU ordering matches early-decode access patterns (kills the initial
miss spike of Figure 4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import lru_pool as LP
from repro.core import offload
from repro.models import mla as M


def lru_warmup(pool: LP.PoolState, host_latent: jax.Array,
               x_tail: jax.Array, idx_p: dict, idx_keys: jax.Array,
               lens: jax.Array, cfg: ArchConfig, *,
               slot_mask: jax.Array | None, layer: int = 0,
               batch_offset: int = 0,
               block_table: jax.Array | None = None,
               host_scales: jax.Array | None = None) -> LP.PoolState:
    """Seed the pool.

    x_tail [B, W, d]: post-ln1 hidden states of the last W prefill tokens
    (the "windows"); idx_keys [B, S, Di] full indexer cache; lens [B].
    Sequentially (scan) inserts each window's Top-K set with full LRU
    semantics, so stamps increase window by window.

    ``slot_mask`` is required keyword-only (ESS001): a ``[B]`` bool mask
    freezes masked rows' pool state through the whole warmup scan;
    ``None`` = every row live (e.g. the per-slot replay at admission).

    ``layer`` / ``batch_offset`` / ``block_table`` route the miss fetches
    through a stacked and/or paged host tier (the serve loop replays warmup
    per admitted slot against the slot's mapped pages).  ``host_scales``
    is the quantized tier's per-row scale plane (None = raw bf16): misses
    dequantize at miss width on the way into the pool, which stays bf16.
    """
    B, W, _ = x_tail.shape
    S = idx_keys.shape[1]
    K = min(cfg.dsa.index_topk, S)

    iq = M.indexer_query(idx_p, x_tail)                  # queries for W windows
    sc = M.indexer_scores(iq, idx_keys)                  # [B,W,S]
    valid_s = jnp.arange(S)[None, :] < lens[:, None]
    ids_w = M.topk_ids(sc, K, valid_s[:, None])          # [B,W,K]
    valid_w = jnp.take_along_axis(
        jnp.broadcast_to(valid_s[:, None], (B, W, S)), ids_w, axis=2)

    def body(p, wi):
        ids, vw = wi                                     # [B,K]
        p, lk, _ = LP.lookup(p, ids, vw, K,              # envelope = K (exact)
                             slot_mask=slot_mask,
                             dedup=False)                # per-window top-k
        rows = offload.gather_tier_rows(host_latent, host_scales,
                                        lk.miss_ids, layer=layer,
                                        batch_offset=batch_offset,
                                        block_table=block_table)
        p = LP.admit(p, lk.miss_ids, rows, slot_mask=slot_mask)
        p = LP.tick(p)
        return p, None

    pool, _ = jax.lax.scan(body, pool,
                           (ids_w.transpose(1, 0, 2), valid_w.transpose(1, 0, 2)))
    return pool
