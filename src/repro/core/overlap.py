"""ESS decode attention with DA / DBA overlap (paper §3.3).

On TPU, overlap is decided by XLA's latency-hiding scheduler, so the control
knob is **program structure**: what is *independent* of the H2D fetch can
hide it.  The three strategies lower to three different dependence graphs:

* ``none``  (SGLang default): one attention over the union of hits+misses —
  everything depends on the fetch; fully serial.
* ``da``    (Dual-Attention): fetch is issued first; **Attn0** consumes only
  pool-resident rows (independent of the fetch) and **Attn1** consumes the
  fetched rows; the two partials merge exactly (online-softmax
  renormalization, bit-identical up to fp reassociation).
* ``dba``   (DualBatch-Attention): additionally splits the *indexer* along
  the batch dim; half-2's indexer compute (paged_mqa_logits + top-k — the
  components whose intensity survives batch splitting, §3.3) is independent
  of half-1's fetch and hides it even at long context where Attn0 is tiny.

All shapes fixed; Q>1 (MTP drafts) supported by flattening per-query top-k
requests into the pool lookup.  ``lens`` may be per-query ``[B,Q]`` so a
draft-verification step stays causal *within* the Q window: query ``q``
only selects (and attends to) positions ``< lens[b,q]`` — without the
per-query mask every draft could attend to entries appended by later
drafts, breaking parity with sequential single-token steps.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import lru_pool as LP
from repro.core import offload
from repro.models import mla as M

NEG_INF = -2.0e38


class ESSLayerState(NamedTuple):
    pool: LP.PoolState         # device-resident sparse memory pool
    host_latent: jax.Array     # dense [B,S,D] / [L,B,S,D] or paged page
                               # pool [NP,R,D] / [L,NP,R,D] (pinned_host)
    layer: int = 0             # layer index when host_latent is stacked [L,...]
    # DBA half-batch offset into the host cache.  May be a traced i32
    # scalar: the compiled serve round's prefill program indexes the
    # admitting slot dynamically (offload routes it through
    # dynamic_slice), so no Python-int shape leaks force a retrace.
    batch_offset: int | jax.Array = 0
    block_table: jax.Array | None = None   # [B_total, NB] paged indirection
    # per-row scale plane of a quantized host tier ([L,NP,R,1] paged /
    # [L,B,S,1] dense); None = raw bf16 tier.  Fetches below go through
    # offload.gather_tier_rows, which dequantizes at miss width — bf16
    # rows never materialize at tier width.
    host_scales: jax.Array | None = None


class ESSStats(NamedTuple):
    hits: jax.Array
    misses: jax.Array
    overflow: jax.Array


def _attend_rows(q_comb: jax.Array, rows: jax.Array, valid: jax.Array,
                 cfg: ArchConfig, use_kernel: bool = False) -> M.Partial:
    """q [B,Q,H,D] vs per-query rows [B,Q,K,D] (or shared [B,K,D])."""
    if use_kernel:
        from repro.kernels.sparse_mla import ops as sk
        return sk.partial_attend(q_comb, rows, valid, M.mla_scale(cfg),
                                 cfg.mla.kv_lora_rank)
    rank = cfg.mla.kv_lora_rank
    if rows.ndim == 3:
        rows = rows[:, None]
        valid = valid[:, None]
    s = jnp.einsum("bqhd,bqkd->bqhk", q_comb, rows,
                   preferred_element_type=jnp.float32) * M.mla_scale(cfg)
    s = jnp.where(valid[:, :, None, :], s, NEG_INF)
    mx = s.max(axis=-1)
    p = jnp.exp(s - mx[..., None])
    p = jnp.where(valid[:, :, None, :], p, 0.0)
    o = jnp.einsum("bqhk,bqkv->bqhv", p.astype(rows.dtype),
                   rows[..., :rank], preferred_element_type=jnp.float32)
    l = p.sum(axis=-1)
    return M.Partial(o, mx, l)


def ess_sparse_attention(mla_p: dict, idx_p: dict, cfg: ArchConfig,
                         x_norm: jax.Array, positions: jax.Array,
                         state: ESSLayerState, idx_keys: jax.Array,
                         lens: jax.Array, *, overlap: str = "da",
                         use_kernel: bool = False,
                         slot_mask: jax.Array | None = None
                         ) -> tuple[jax.Array, ESSLayerState, ESSStats]:
    """One layer of ESS decode attention.

    x_norm [B,Q,d] (post-ln1 hidden of the new tokens), positions [B,Q],
    idx_keys [B,S,Di] device-resident Indexer-Cache *already containing the
    new tokens' keys*, lens [B] = cache length *after* appending new tokens
    — or per-query ``[B,Q]`` (causal within the Q window: query ``q`` sees
    positions ``< lens[b,q]``; a slot-masked row passes 0).
    ``slot_mask`` [B] gates the pool mutations (LRU touches / admissions)
    of frozen batch rows in-step; it is forwarded into
    :func:`repro.core.lru_pool.lookup` / :func:`~repro.core.lru_pool.admit`.
    ``state.host_latent`` must already contain the new latent rows (the
    engine performs the D2H writeback — Figure 3's small D2H — before
    calling attention so drafts can attend to themselves).
    """
    if overlap == "dba":
        return _dba(mla_p, idx_p, cfg, x_norm, positions, state, idx_keys,
                    lens, use_kernel, slot_mask)
    return _da_or_none(mla_p, idx_p, cfg, x_norm, positions, state, idx_keys,
                       lens, overlap, use_kernel, slot_mask)


def _fetch_valid(lk, B: int, Q: int, K: int, M_env: int) -> jax.Array:
    """[B,Q,M_env] bool — which fetched rows each query actually requested.

    At Q=1 this is exactly ``miss_ids >= 0``; at Q>1 it keeps the verify
    step per-query causal: without it every draft attends the *union* of
    all drafts' missed rows (and rows it already hit double-count)."""
    bi = jnp.arange(B)[:, None]
    qidx = jnp.broadcast_to((jnp.arange(Q * K) // K)[None], (B, Q * K))
    scat = jnp.minimum(lk.miss_rank, M_env)          # non-miss rank is big
    return jnp.zeros((B, Q, M_env + 1), bool).at[
        bi, qidx, scat].set(True, mode="drop")[:, :, :M_env]


def _topk_and_lookup(idx_p, cfg, x_norm, state, idx_keys, lens, slot_mask):
    B, Q, _ = x_norm.shape
    S = idx_keys.shape[1]
    K = min(cfg.dsa.index_topk, S)
    M_env = max(1, int(cfg.ess.max_miss_ratio * K)) * Q

    iq = M.indexer_query(idx_p, x_norm)
    sc = M.indexer_scores(iq, idx_keys)                          # [B,Q,S]
    qlens = lens[:, None] if lens.ndim == 1 else lens            # [B,Q]
    valid_s = jnp.arange(S)[None, None, :] < qlens[:, :, None]   # [B,Q,S]
    valid_s = jnp.broadcast_to(valid_s, (B, Q, S))
    ids = M.topk_ids(sc, K, valid_s)                             # [B,Q,K]
    req_valid = jnp.take_along_axis(valid_s, ids, axis=2)
    flat_ids = ids.reshape(B, Q * K)
    flat_valid = req_valid.reshape(B, Q * K)
    # one query's top-k is duplicate-free; only the Q>1 flattening can
    # request the same position twice (skip the O(K^2) dedup at Q=1)
    pool, lk, stats = LP.lookup(state.pool, flat_ids, flat_valid, M_env,
                                slot_mask=slot_mask, dedup=Q > 1)
    return pool, lk, stats, ids, req_valid, K, M_env, sc


def _finish_attention(mla_p, cfg, x_norm, positions, pool, lk, ids,
                      req_valid, fetched, K, M_env, overlap, use_kernel,
                      slot_mask):
    """Attention + LRU admission over already-resolved miss rows: Attn0
    on pool-resident rows ∥ Attn1 on ``fetched`` with the exact partial
    merge (or one union attention for ``overlap="none"``).  Shared by the
    synchronous gather path and the staged-slab path — they differ only
    in where ``fetched`` came from, so value-identical sourcing gives
    bit-identical outputs (the async-offload parity bar).  Returns
    ``(out, pool-after-admit)``; the caller ticks the clock."""
    B, Q, _ = x_norm.shape
    q_comb = M.absorbed_query(mla_p, cfg, x_norm, positions)     # [B,Q,H,D]

    hit = lk.hit.reshape(B, Q, K)
    if overlap == "none":
        # single attention over the union: every row depends on the fetch
        rows_hit, _ = LP.gather_resident(pool, lk.slot, lk.hit)
        # misses: place fetched rows back at their request positions
        fr = jnp.where(lk.miss_rank[..., None] < M_env,
                       jnp.take_along_axis(
                           fetched, jnp.clip(lk.miss_rank, 0, M_env - 1)
                           [..., None], axis=1), 0)
        rows = jnp.where(lk.hit[..., None], rows_hit, fr)
        valid = (lk.hit | (lk.miss_rank < M_env)) & \
            (ids.reshape(B, Q * K) >= 0)
        part = _attend_rows(q_comb, rows.reshape(B, Q, K, -1),
                            valid.reshape(B, Q, K), cfg, use_kernel)
    else:
        # Attn0: pool-resident rows only (independent of the fetch)
        rows0, _ = LP.gather_resident(pool, lk.slot, lk.hit)
        p0 = _attend_rows(q_comb, rows0.reshape(B, Q, K, -1),
                          hit & req_valid.reshape(B, Q, K).astype(bool),
                          cfg, use_kernel)
        # Attn1: fetched rows (waits on the H2D copy); at Q>1 each query
        # attends only the rows it requested (at Q=1 that set is exactly
        # the whole miss buffer — skip the scatter)
        mvalid = (lk.miss_ids >= 0)
        fvalid = _fetch_valid(lk, B, Q, K, M_env) & mvalid[:, None] \
            if Q > 1 else jnp.broadcast_to(mvalid[:, None], (B, Q, M_env))
        p1 = _attend_rows(q_comb, fetched[:, None].repeat(Q, 1)
                          if Q > 1 else fetched[:, None],
                          fvalid, cfg, use_kernel)
        part = M.merge_partials(p0, p1)

    out_lat = M.finalize_partial(part, x_norm.dtype)
    out = M.output_proj(mla_p, cfg, out_lat)

    pool = LP.admit(pool, lk.miss_ids, fetched, slot_mask=slot_mask)
    return out, pool


def _da_or_none(mla_p, idx_p, cfg, x_norm, positions, state, idx_keys, lens,
                overlap, use_kernel, slot_mask=None):
    pool, lk, stats, ids, req_valid, K, M_env, _ = _topk_and_lookup(
        idx_p, cfg, x_norm, state, idx_keys, lens, slot_mask)

    # ---- issue the H2D fetch as early as possible (DA overlap) ----
    fetched = offload.gather_tier_rows(state.host_latent, state.host_scales,
                                       lk.miss_ids,
                                       layer=state.layer,
                                       batch_offset=state.batch_offset,
                                       block_table=state.block_table)

    out, pool = _finish_attention(mla_p, cfg, x_norm, positions, pool, lk,
                                  ids, req_valid, fetched, K, M_env,
                                  overlap, use_kernel, slot_mask)
    pool = LP.tick(pool)
    new_state = state._replace(pool=pool)
    return out, new_state, ESSStats(stats.hits, stats.misses, stats.overflow)


def ess_sparse_attention_staged(mla_p: dict, idx_p: dict, cfg: ArchConfig,
                                x_norm: jax.Array, positions: jax.Array,
                                state: ESSLayerState, idx_keys: jax.Array,
                                lens: jax.Array, *, new_rows: jax.Array,
                                widx: jax.Array, staged_ids_l: jax.Array,
                                staged_rows_l: jax.Array,
                                staged_scales_l: jax.Array | None = None,
                                overlap: str = "da",
                                use_kernel: bool = False,
                                slot_mask: jax.Array | None = None):
    """One layer of ESS decode attention sourcing miss rows from the
    async-offload staging slab instead of a synchronous host gather (the
    pipeline's compute stage).

    The *selection* semantics (indexer scores, top-K, pool lookup, miss
    buffer, LRU admission) are exactly :func:`ess_sparse_attention`'s —
    only row *sourcing* changes, resolved in precedence order:

    1. **own-row bypass** — the round's freshly appended latents
       (``new_rows [B,Q,D]`` at positions ``widx [B,Q]``) are still in
       the spill slab (their D2H is deferred to the commit stage), so a
       miss on them is served from the live activations.  Bit-identical
       to the synchronous host round trip: the scatter stores
       ``astype(host dtype)`` and the gather reads it back verbatim.
    2. **staged-slab match** — rows predicted and prefetched during the
       *previous* round (``staged_ids_l/staged_rows_l [B,P(,D)]``).
    3. **synchronous fallback** — mispredicted misses gather from the
       host tier under a nested ``lax.cond``: a fully-predicted round
       keeps the H2D path off the critical graph entirely.

    The whole sourcing block sits under one ``lax.cond`` on the round
    having any valid miss at all: a steady-state round whose top-K is
    fully pool-resident pays a single skipped branch instead of the
    per-layer match machinery (the plan stage rides *every* round, so
    its cost bounds the pipeline's overhead floor — which is also why
    the planning inputs are returned to the round driver and ranked
    once, batched across layers, rather than per layer here).

    Returns ``(out, new_state, stats, plan_sig, (hits, unmatched) [B]
    each)`` — ``plan_sig = (sc_last [B,S], qlens_last [B], slot_of
    [B,S])`` is this layer's plan-stage signal (last query's indexer
    scores, its horizon, post-admit pool residency); the counters are
    gated on ``slot_mask`` so frozen slots contribute zero.
    ``overlap="dba"`` degrades to the DA graph (the slab already
    decouples the fetch the batch-split indexer would have hidden)."""
    from repro.core import transfer as TR
    B, Q, _ = x_norm.shape
    live = jnp.ones((B,), bool) if slot_mask is None else slot_mask
    pool, lk, stats, ids, req_valid, K, M_env, sc = _topk_and_lookup(
        idx_p, cfg, x_norm, state, idx_keys, lens, slot_mask)

    mvalid = lk.miss_ids >= 0
    D = new_rows.shape[-1]

    def _source_rows():
        own_eq = (lk.miss_ids[:, :, None] == widx[:, None, :]) \
            & (widx >= 0)[:, None, :]                            # [B,M,Q]
        own = own_eq.any(-1)
        own_rows = jnp.take_along_axis(
            new_rows, jnp.argmax(own_eq, -1)[:, :, None], axis=1)  # [B,M,D]
        need = mvalid & ~own
        smatch, srows = TR.match_staged(staged_ids_l, staged_rows_l,
                                        lk.miss_ids, need,
                                        staged_scales_l=staged_scales_l,
                                        out_dtype=new_rows.dtype)
        unmatched = need & ~smatch
        fb_ids = jnp.where(unmatched, lk.miss_ids, -1)
        fb = jax.lax.cond(
            jnp.any(unmatched),
            lambda: offload.gather_tier_rows(state.host_latent,
                                             state.host_scales, fb_ids,
                                             layer=state.layer,
                                             batch_offset=state.batch_offset,
                                             block_table=state.block_table,
                                             out_dtype=new_rows.dtype),
            lambda: jnp.zeros((B, M_env, D), new_rows.dtype))
        fetched = jnp.where(own[..., None], own_rows,
                            jnp.where(smatch[..., None], srows, fb))
        return (jnp.where(mvalid[..., None], fetched, 0),
                smatch.sum(-1).astype(jnp.int32),
                unmatched.sum(-1).astype(jnp.int32))

    fetched, s_hits, s_unm = jax.lax.cond(
        jnp.any(mvalid), _source_rows,
        lambda: (jnp.zeros((B, M_env, D), new_rows.dtype),
                 jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32)))

    out, pool = _finish_attention(mla_p, cfg, x_norm, positions, pool, lk,
                                  ids, req_valid, fetched, K, M_env,
                                  "da" if overlap == "dba" else overlap,
                                  use_kernel, slot_mask)
    pool = LP.tick(pool)

    qlast = lens[:, -1] if lens.ndim == 2 else lens
    liv = live.astype(jnp.int32)
    return out, state._replace(pool=pool), \
        ESSStats(stats.hits, stats.misses, stats.overflow), \
        (sc[:, -1], qlast, pool.slot_of), (s_hits * liv, s_unm * liv)


def _dba(mla_p, idx_p, cfg, x_norm, positions, state, idx_keys, lens,
         use_kernel, slot_mask=None):
    """DualBatch-Attention: batch split in two, indexer of half-2 overlaps
    the fetch of half-1."""
    B = x_norm.shape[0]
    h = B // 2
    if h == 0:
        return _da_or_none(mla_p, idx_p, cfg, x_norm, positions, state,
                           idx_keys, lens, "da", use_kernel, slot_mask)
    sm0 = None if slot_mask is None else slot_mask[:h]
    sm1 = None if slot_mask is None else slot_mask[h:]

    def half(sl, off):
        pool = LP.PoolState(*(a[sl] if a.ndim > 0 else a
                              for a in state.pool))
        pool = pool._replace(step=state.pool.step)
        # host cache (and block table) stays whole; the half indexes it
        # via batch_offset
        return ESSLayerState(pool, state.host_latent, state.layer,
                             state.batch_offset + off, state.block_table,
                             state.host_scales)

    s0, s1 = half(slice(0, h), 0), half(slice(h, None), h)
    # half-1 indexer + fetch issue
    p0_pool, lk0, st0, ids0, rv0, K, M_env, _ = _topk_and_lookup(
        idx_p, cfg, x_norm[:h], s0, idx_keys[:h], lens[:h], sm0)
    fetched0 = offload.gather_tier_rows(s0.host_latent, s0.host_scales,
                                        lk0.miss_ids,
                                        layer=s0.layer,
                                        batch_offset=s0.batch_offset,
                                        block_table=s0.block_table)
    # half-2 indexer (independent of fetched0 -> overlaps the copy)
    p1_pool, lk1, st1, ids1, rv1, _, _, _ = _topk_and_lookup(
        idx_p, cfg, x_norm[h:], s1, idx_keys[h:], lens[h:], sm1)
    fetched1 = offload.gather_tier_rows(s1.host_latent, s1.host_scales,
                                        lk1.miss_ids,
                                        layer=s1.layer,
                                        batch_offset=s1.batch_offset,
                                        block_table=s1.block_table)

    out0, ns0 = _finish_half(mla_p, cfg, x_norm[:h], positions[:h], p0_pool,
                             lk0, ids0, rv0, fetched0, s0, K, M_env,
                             use_kernel, sm0)
    out1, ns1 = _finish_half(mla_p, cfg, x_norm[h:], positions[h:], p1_pool,
                             lk1, ids1, rv1, fetched1, s1, K, M_env,
                             use_kernel, sm1)

    pool = LP.PoolState(*(jnp.concatenate([a, b], 0) if a.ndim > 0 else a
                          for a, b in zip(ns0.pool, ns1.pool)))
    pool = pool._replace(step=state.pool.step)
    pool = LP.tick(pool)
    out = jnp.concatenate([out0, out1], 0)
    hits = jnp.concatenate([st0.hits, st1.hits], 0)
    misses = jnp.concatenate([st0.misses, st1.misses], 0)
    ovf = jnp.concatenate([st0.overflow, st1.overflow], 0)
    return out, state._replace(pool=pool), ESSStats(hits, misses, ovf)


def _finish_half(mla_p, cfg, x_norm, positions, pool, lk, ids, req_valid,
                 fetched, st, K, M_env, use_kernel, slot_mask=None):
    B, Q, _ = x_norm.shape
    q_comb = M.absorbed_query(mla_p, cfg, x_norm, positions)
    hit = lk.hit.reshape(B, Q, K)
    rows0, _ = LP.gather_resident(pool, lk.slot, lk.hit)
    p0 = _attend_rows(q_comb, rows0.reshape(B, Q, K, -1),
                      hit & req_valid.astype(bool), cfg, use_kernel)
    mvalid = lk.miss_ids >= 0
    fvalid = _fetch_valid(lk, B, Q, K, M_env) & mvalid[:, None] \
        if Q > 1 else jnp.broadcast_to(mvalid[:, None], (B, Q, M_env))
    p1 = _attend_rows(q_comb, fetched[:, None].repeat(Q, 1) if Q > 1
                      else fetched[:, None],
                      fvalid, cfg, use_kernel)
    part = M.merge_partials(p0, p1)
    out = M.output_proj(mla_p, cfg, M.finalize_partial(part, x_norm.dtype))
    pool = LP.admit(pool, lk.miss_ids, fetched, slot_mask=slot_mask)
    return out, st._replace(pool=pool)
