"""Generalized ESS for GQA architectures (DESIGN.md §5).

The paper's indexer is DSA-specific; for plain-GQA archs (qwen/gemma/dbrx)
the offload architecture ports unchanged if something else picks the hot
cache entries.  We use Quest-style block scoring [arXiv:2406.10774]: per
KV block keep elementwise (min, max) of the keys; a query's upper-bound
attention score for the block is

    ub(q, block) = Σ_d max(q_d·min_d, q_d·max_d)

Select the Top-B blocks per head group, manage them with the *same* LRU
Sparse Memory Pool (block granularity = the paper's PagedAttention pages),
fetch misses from the host tier, attend with the exact softmax over the
selected set.  Selection is approximate (Quest), attention over the
selection is exact — same contract as DSA-ESS.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


class BlockMeta(NamedTuple):
    kmin: jax.Array    # [B, NB, KV, D]
    kmax: jax.Array    # [B, NB, KV, D]


def build_block_meta(k_cache: jax.Array, block: int) -> BlockMeta:
    """k_cache [B, S, KV, D] (S % block == 0) -> per-block min/max."""
    B, S, KV, D = k_cache.shape
    nb = S // block
    kb = k_cache.reshape(B, nb, block, KV, D).astype(jnp.float32)
    return BlockMeta(kb.min(axis=2), kb.max(axis=2))


def update_block_meta(meta: BlockMeta, k_new: jax.Array, pos: jax.Array,
                      block: int) -> BlockMeta:
    """Incremental decode-time update for one new token per sequence.

    k_new [B, KV, D]; pos [B] absolute position of the new entry."""
    bi = jnp.arange(k_new.shape[0])
    blk = pos // block
    kn = k_new.astype(jnp.float32)
    kmin = meta.kmin.at[bi, blk].min(kn)
    kmax = meta.kmax.at[bi, blk].max(kn)
    return BlockMeta(kmin, kmax)


def quest_scores(q: jax.Array, meta: BlockMeta,
                 valid_blocks: jax.Array) -> jax.Array:
    """q [B, H, D] (per q-head; KV broadcast by grouping outside) ->
    upper-bound block scores [B, NB] (max over heads, Quest §3.2)."""
    groups = q.shape[1] // meta.kmin.shape[2]
    kmin = jnp.repeat(meta.kmin, groups, axis=2)        # [B,NB,H,D]
    kmax = jnp.repeat(meta.kmax, groups, axis=2)
    qf = q.astype(jnp.float32)[:, None]                 # [B,1,H,D]
    ub = jnp.maximum(qf * kmin, qf * kmax).sum(-1)      # [B,NB,H]
    sc = ub.max(axis=-1)                                # max over heads
    return jnp.where(valid_blocks, sc, NEG_INF)


def quest_topk_blocks(q: jax.Array, meta: BlockMeta, lens: jax.Array,
                      block: int, topb: int) -> tuple[jax.Array, jax.Array]:
    """-> (block ids [B, topb], valid [B, topb]).  Always includes the
    newest block (local window, Quest keeps recents resident)."""
    B, NB = meta.kmin.shape[:2]
    n_valid = (lens + block - 1) // block
    valid = jnp.arange(NB)[None, :] < n_valid[:, None]
    sc = quest_scores(q, meta, valid)
    cur = jnp.clip((lens - 1) // block, 0, NB - 1)
    sc = sc.at[jnp.arange(B), cur].set(jnp.inf)         # pin newest block
    k = min(topb, NB)
    _, ids = jax.lax.top_k(sc, k)
    bvalid = jnp.take_along_axis(valid, ids, axis=1)
    return ids, bvalid


def gqa_sparse_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         block_ids: jax.Array, bvalid: jax.Array,
                         lens: jax.Array, block: int, scale: float
                         ) -> jax.Array:
    """Exact attention over the selected blocks.

    q [B,H,D]; k/v [B,S,KV,D]; block_ids [B,NBSEL].  Returns [B,H,D]."""
    B, S, KV, D = k_cache.shape
    H = q.shape[1]
    groups = H // KV
    nbsel = block_ids.shape[1]
    # gather selected blocks -> [B, NBSEL*block, KV, D]
    gidx = (block_ids[..., None] * block
            + jnp.arange(block)[None, None, :]).reshape(B, nbsel * block)
    gk = jnp.take_along_axis(k_cache, gidx[..., None, None], axis=1)
    gv = jnp.take_along_axis(v_cache, gidx[..., None, None], axis=1)
    pos_ok = (gidx < lens[:, None]) & jnp.repeat(bvalid, block, axis=1)
    kk = jnp.repeat(gk, groups, axis=2)
    vv = jnp.repeat(gv, groups, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q, kk,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(pos_ok[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w.astype(vv.dtype), vv,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def attention_recall(q, k_cache, lens, block_ids, bvalid, block, scale
                     ) -> jax.Array:
    """Diagnostic: fraction of true softmax mass captured by the selected
    blocks (per sequence, max-head) — the quality metric for Quest-ESS."""
    B, S, KV, D = k_cache.shape
    groups = q.shape[1] // KV
    kk = jnp.repeat(k_cache, groups, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q, kk,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(S)[None, :] < lens[:, None]
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)                       # [B,H,S]
    sel = jnp.zeros((B, S), bool)
    gidx = (block_ids[..., None] * block
            + jnp.arange(block)[None, None, :]).reshape(B, -1)
    sel = sel.at[jnp.arange(B)[:, None],
                 jnp.clip(gidx, 0, S - 1)].set(True)
    mass = jnp.where(sel[:, None], p, 0.0).sum(-1)       # [B,H]
    return mass.min(axis=-1)                             # worst head
