"""The async offload TransferEngine: double-buffered staging slabs +
indexer-driven prefetch planning (NOSA's native-offloadable locality,
KVDrive-style transfer pipelining — see PAPERS.md).

The serve round is a three-stage pipeline — **plan → compute → commit** —
and this module owns the transfer half of it.  Round ``N`` computes
against rows *staged during round ``N-1``*; while it computes, round
``N+1``'s predicted pages are already in flight.  Two slab buffers make
that a two-deep software pipeline:

* ``staged_ids  [L, B, P]``   the sequence positions staged per layer per
  slot (``-1`` = empty / cancelled);
* ``staged_rows [L, B, P, D]`` the host-tier latent rows gathered at those
  positions, resident on device before the round that consumes them.

Both live as **donated EngineState leaves** (:mod:`repro.serving.state`):
XLA's donation aliasing is what implements the double buffering — each
round program consumes slab ``N`` and produces slab ``N+1`` into the same
storage, so the swap is free and the host never touches a row.

Prediction is **indexer-driven** (the tentpole's plan stage): the
Lightning-Indexer scores of a round's last query are a strong proxy for
the next round's scores (top-K selections are stable step over step — the
locality the paper's whole offload story rests on).  The planner stages
the *predicted misses* — the ``P`` highest-scored positions that are
not pool-resident: capacity misses the LRU just evicted out of the
working set plus the margin about to rotate into the top-K — because
those are, to first order, exactly the rows the next round's
synchronous gather would have to fetch.  A wrong
speculation is never wrong-*valued*: rows the compute stage needs but the
slab lacks fall back to the synchronous gather inside the program, so the
overlapped stream is bit-identical to the synchronous one (hit/miss/wasted
accounting records how often speculation paid).

Traced helpers (:func:`empty_slab`, :func:`plan_prefetch`,
:func:`match_staged`) are pure fixed-shape JAX — they compile into the
donated StepPrograms.  :class:`TransferEngine` is the *host-side*
orchestrator the serve session drives at stage boundaries:
``issue_stage`` arms (or re-arms) the slabs on an EngineState,
``await_staged`` hands the compute stage its staged pair, ``commit``
folds a round's fetched prefetch counters into the report, and the
``invalidate_slot`` / ``truncate_slot`` edges cancel staged transfers
whose rows a lifecycle transition (release, abort, stop-token rollback)
just invalidated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def empty_slab(num_layers: int, num_slots: int, prefetch_rows: int,
               dim: int, dtype, scale_dtype=None
               ) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """A disarmed staging slab: no ids staged, zeroed landing rows.

    Returns ``(ids, rows, scales)``.  ``scales`` is ``None`` for a raw
    bf16 tier; with a quantized host tier (``scale_dtype`` given) the slab
    stores the rows exactly as the tier does — one-byte payload plus a
    per-row scale plane ``[L,B,P,1]`` — so staged rows sit on device
    *compressed* and only dequantize at miss width in
    :func:`match_staged`."""
    scales = None if scale_dtype is None else jnp.zeros(
        (num_layers, num_slots, prefetch_rows, 1), scale_dtype)
    return (jnp.full((num_layers, num_slots, prefetch_rows), -1, jnp.int32),
            jnp.zeros((num_layers, num_slots, prefetch_rows, dim), dtype),
            scales)


def plan_prefetch(sc_last: jax.Array, qlens_last: jax.Array,
                  slot_of: jax.Array, live: jax.Array, topk: int,
                  prefetch_rows: int) -> jax.Array:
    """Plan one layer's next-round staging from this round's indexer scores.

    ``sc_last [B,S]`` — the last query's indexer scores (the freshest
    locality signal available before the round commits); ``qlens_last
    [B]`` — that query's attention horizon (positions ``< qlens`` are
    real); ``slot_of [B,S]`` — the *post-admit* pool inverse map;
    ``live [B]`` — the slot gate.

    Returns ``pred [B, P]`` (``-1`` padded): the **predicted top-K
    misses** — the ``P`` highest-scored positions that are in horizon
    and **not pool-resident**.  Those are, in score-rank order, exactly
    the entries the next round's top-K selection would have to fetch
    synchronously: capacity misses the LRU just evicted out of the
    working set, and the margin about to rotate into the top-K.
    Everything resident would be a guaranteed pool hit next round and
    is never staged.  (One masked ``top_k`` of width ``P`` — the plan
    stage rides every round, so it must stay far cheaper than the
    gathers it hides.)
    """
    del topk  # the plan ranks *misses* by score; K never truncates it
    B, S = sc_last.shape
    in_range = jnp.arange(S)[None, :] < qlens_last[:, None]        # [B,S]
    NEG = jnp.finfo(jnp.float32).min
    cand = in_range & (slot_of < 0) & live[:, None]    # predictable misses
    masked = jnp.where(cand, sc_last.astype(jnp.float32), NEG)
    val, top = jax.lax.top_k(masked, min(prefetch_rows, S))        # [B,P]
    pred = jnp.where(val > NEG / 2, top, -1)
    if pred.shape[1] < prefetch_rows:
        pred = jnp.pad(pred, ((0, 0), (0, prefetch_rows - pred.shape[1])),
                       constant_values=-1)
    return pred


def match_staged(staged_ids_l: jax.Array, staged_rows_l: jax.Array,
                 miss_ids: jax.Array, need: jax.Array,
                 staged_scales_l: jax.Array | None = None,
                 out_dtype=jnp.bfloat16) -> tuple[jax.Array, jax.Array]:
    """Serve a round's miss buffer from one layer's staged slab.

    ``staged_ids_l [B,P]`` / ``staged_rows_l [B,P,D]`` — the slab;
    ``miss_ids [B,M]`` — the lookup's (duplicate-free) miss buffer;
    ``need [B,M]`` — which misses actually require host rows (valid and
    not satisfiable from the round's own appended rows).

    Returns ``(matched [B,M], rows [B,M,D])`` — matched rows carry the
    staged values (bit-identical to what the synchronous gather would
    have fetched: the slab was filled from the committed host tier), the
    rest are zero.

    With a quantized tier (``staged_scales_l [B,P,1]`` given) the slab
    holds compressed payloads; the matched rows — and only those, at
    **miss width** — dequantize here, exactly matching what the
    synchronous :func:`repro.core.offload.gather_tier_rows` fallback
    would produce.
    """
    eq = (miss_ids[:, :, None] == staged_ids_l[:, None, :]) \
        & (staged_ids_l >= 0)[:, None, :] & need[:, :, None]       # [B,M,P]
    matched = eq.any(-1)
    idx = jnp.argmax(eq, axis=-1)                                  # [B,M]
    rows = jnp.take_along_axis(staged_rows_l, idx[:, :, None], axis=1)
    if staged_scales_l is not None:
        from repro.distributed import compression as cmp
        scales = jnp.take_along_axis(staged_scales_l, idx[:, :, None],
                                     axis=1)                       # [B,M,1]
        rows = cmp.dequantize_rows(rows, scales, out_dtype)
    return matched, jnp.where(matched[..., None], rows, 0)


class TransferEngine:
    """Host-side orchestrator of the staging slabs across the
    plan → compute → commit pipeline.

    The actual transfers are traced *inside* the donated round programs
    (issuing them from the host would be a second per-round host sync and
    a donation break); this object owns everything that happens at stage
    and slot-lifecycle boundaries:

    * :meth:`issue_stage` — arm fresh (empty) slabs on an EngineState:
      session start, or any edge that must cancel *all* in-flight staging;
    * :meth:`await_staged` — the staged pair the compute stage consumes
      (the name is the pipeline contract: by the time a program reads the
      slab, its H2D copy has already landed — XLA sequences the
      dependency, the host never blocks on it);
    * :meth:`commit` — fold a round's fetched hit/miss/wasted counters
      into the :class:`~repro.serving.engine.ServeReport`;
    * :meth:`invalidate_slot` / :meth:`truncate_slot` — cancel staged
      transfers whose target rows a release / abort / stop-token rollback
      just invalidated (a stale staged id would otherwise serve a
      *different occupant's* row next round).
    """

    def __init__(self, num_layers: int, num_slots: int, prefetch_rows: int,
                 dim: int, dtype, scale_dtype=None):
        self.num_layers = num_layers
        self.num_slots = num_slots
        self.prefetch_rows = prefetch_rows
        self.dim = dim
        self.dtype = dtype
        self.scale_dtype = scale_dtype     # quantized tier: slab holds q+s

    # -- pipeline stages -----------------------------------------------------

    def issue_stage(self, state):
        """Arm the double buffer: install empty slabs (all transfers
        cancelled; the next round stages from scratch)."""
        ids, rows, scales = empty_slab(self.num_layers, self.num_slots,
                                       self.prefetch_rows, self.dim,
                                       self.dtype, self.scale_dtype)
        return state._replace(staged_ids=ids, staged_rows=rows,
                              staged_scales=scales)

    def await_staged(self, state):
        """The (ids, rows, scales) triple staged for the upcoming round
        (``scales`` is ``None`` for a raw bf16 tier)."""
        return state.staged_ids, state.staged_rows, state.staged_scales

    def commit(self, report, pf_hits, pf_misses, pf_wasted) -> None:
        """Commit-stage accounting: the counters ride the round's single
        packed fetch (already host ints/arrays here)."""
        report.prefetch_hits += int(pf_hits)
        report.prefetch_misses += int(pf_misses)
        report.prefetch_wasted_rows += int(pf_wasted)

    # -- slot-lifecycle edges ------------------------------------------------

    def invalidate_slot(self, state, slot: int):
        """Cancel every staged transfer of one slot (release/abort)."""
        if state.staged_ids is None:
            return state
        return state._replace(
            staged_ids=state.staged_ids.at[:, slot].set(-1))

    def truncate_slot(self, state, slot: int, new_len):
        """Cancel staged transfers targeting rolled-back positions
        (``>= new_len``) of one slot — the stop-token / rejection
        rollback edge.  ``new_len`` may be a traced scalar (no host
        sync)."""
        if state.staged_ids is None:
            return state
        col = state.staged_ids[:, slot]                            # [L,P]
        return state._replace(
            staged_ids=state.staged_ids.at[:, slot].set(
                jnp.where(col >= new_len, -1, col)))
