"""Host-tier placement + the FlashTrans-analogue transfer engine (paper §3.1).

GPU version: UVA lets the kernel dereference pinned host memory, coalescing
656 B fragments.  TPU/JAX version: the full Latent-Cache lives in a
``pinned_host`` memory-space buffer; the *gather of scattered rows runs on
the host* (``compute_on('device_host')``) and exactly one dense
``[M, D]``-row DMA crosses PCIe per layer per step — the same
transaction-coalescing effect FlashTrans achieves with UVA.  The naive
baseline (per-row ``dynamic_slice`` + copy, ~0.79 GB/s in the paper's
measurement) is modelled in the simulator for comparison.

Outside a mesh/jit context everything degrades to plain device arrays so
unit tests run on CPU without memory-space plumbing.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.compute_on import compute_on
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import compression as cmp
from repro.distributed import sharding as shd


def host_available() -> bool:
    try:
        kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
        return "pinned_host" in kinds
    except Exception:  # pragma: no cover
        return False


def host_sharding(*axes, fallback_device: bool = False):
    """NamedSharding with pinned_host memory kind under the active ctx."""
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return None
    kind = "pinned_host" if not fallback_device else "device"
    return ctx.sharding(*axes, memory_kind=kind)


def host_sharding_for(shape, axes):
    """Shape-aware host sharding (prunes axes that don't divide — e.g.
    batch=1 long-context cells can't take the data axis)."""
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return None
    return ctx.sharding_for(tuple(shape), axes, memory_kind="pinned_host")


def to_host(x: jax.Array, *axes) -> jax.Array:
    s = host_sharding_for(x.shape, axes)
    if s is None:
        return x
    return jax.device_put(x, s)


def to_device(x: jax.Array, *axes) -> jax.Array:
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return x
    return jax.device_put(x, ctx.sharding(*axes))


def _paged_phys(ids: jax.Array, block_table: jax.Array, page_rows: int,
                num_pages: int, batch_offset
                ) -> tuple[jax.Array, jax.Array]:
    """Translate sequence positions -> physical pool rows via block tables.

    ids [B,M] (sequence positions, -1 padding), block_table [B_total, NB].
    ``batch_offset`` may be a Python int or a traced i32 scalar (the
    compiled serve-round programs pass the admitting slot dynamically so
    one program serves every slot without retracing).
    Returns (phys [B,M] rows into the flat [NP*R, D] pool view,
    valid [B,M] — in-range *and* mapped)."""
    B = ids.shape[0]
    bt = jax.lax.dynamic_slice_in_dim(block_table, batch_offset, B, axis=0)
    cap = bt.shape[1] * page_rows
    safe = jnp.clip(ids, 0, cap - 1)
    page = jnp.take_along_axis(bt, safe // page_rows, axis=1)      # [B,M]
    valid = (ids >= 0) & (ids < cap) & (page >= 0)
    phys = jnp.clip(page, 0, num_pages - 1) * page_rows + safe % page_rows
    return phys, valid


def host_gather_rows(host_cache: jax.Array, ids: jax.Array, *,
                     layer: int = 0, batch_offset: int = 0,
                     block_table: jax.Array | None = None,
                     axes_out=("cache_batch", None, None)) -> jax.Array:
    """FlashTrans fetch: ids [B,M] (-1 padding) -> rows [B,M,D] on device.

    Two host-tier layouts:

    * dense — host_cache [B,S,D] or [L,B,S,D] (pinned_host), positions
      index the slot's own row range;
    * paged (``block_table`` given) — host_cache [NP,R,D] or [L,NP,R,D]
      global page pool; positions route through the slot's block table to
      physical pool rows, unmapped pages read as zero.

    The gather executes in the host memory space; the index pairs are
    packed on the *device* and shipped to the host, so the host computation
    is exactly one ``lax.gather`` — no auxiliary iota or bounds constants
    can land in the wrong memory space, and the SPMD partitioner keeps
    everything batch-sharded (verified: zero host-buffer all-gathers).
    Only the packed [B,M,D] result is DMA'd to the device — one coalesced
    transaction instead of M fragmented ones (the FlashTrans effect).
    """
    ctx = shd.current()
    B, M = ids.shape
    D = host_cache.shape[-1]

    if block_table is not None:
        R = host_cache.shape[-2]
        NP = host_cache.shape[-3]
        phys, valid = _paged_phys(ids, block_table, R, NP, batch_offset)
        if ctx is None or ctx.mesh is None:
            cl = host_cache[layer] if host_cache.ndim == 4 else host_cache
            rows = jnp.take(cl.reshape(NP * R, D), phys, axis=0)
            return jnp.where(valid[..., None], rows, 0)

        idx_h = jax.device_put(phys[..., None], host_sharding_for(
            (B, M, 1), ("cache_batch", None, None)))
        dn = jax.lax.GatherDimensionNumbers(
            offset_dims=(2,), collapsed_slice_dims=(0,),
            start_index_map=(0,))

        @compute_on("device_host")
        @jax.jit
        def _gather_paged(c, i):
            cl = c[layer] if c.ndim == 4 else c
            return jax.lax.gather(
                cl.reshape(NP * R, D), i, dn, (1, D),
                mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS)

        rows = _gather_paged(host_cache, idx_h)
        rows = jax.device_put(rows, ctx.sharding_for((B, M, D), axes_out))
        return jnp.where(valid[..., None], rows, 0)

    S = host_cache.shape[-2]
    safe = jnp.clip(ids, 0, S - 1)
    if ctx is None or ctx.mesh is None:
        cl = host_cache[layer] if host_cache.ndim == 4 else host_cache
        cl = jax.lax.dynamic_slice_in_dim(cl, batch_offset, B, axis=0)
        rows = jnp.take_along_axis(cl, safe[..., None], axis=1)
        return jnp.where((ids >= 0)[..., None], rows, 0)

    bi = jax.lax.broadcasted_iota(jnp.int32, (B, M), 0) + batch_offset
    idx2 = jnp.stack([bi, safe], axis=-1)
    idx2_h = jax.device_put(idx2, host_sharding_for(
        idx2.shape, ("cache_batch", None, None)))
    dn = jax.lax.GatherDimensionNumbers(
        offset_dims=(2,), collapsed_slice_dims=(0, 1),
        start_index_map=(0, 1))

    @compute_on("device_host")
    @jax.jit
    def _gather(c, i):
        cl = c[layer] if c.ndim == 4 else c
        return jax.lax.gather(cl, i, dn, (1, 1, D),
                              mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS)

    rows = _gather(host_cache, idx2_h)
    rows = jax.device_put(rows, ctx.sharding_for((B, M, D), axes_out))
    return jnp.where((ids >= 0)[..., None], rows, 0)


def host_scatter_rows(host_cache: jax.Array, ids: jax.Array,
                      rows: jax.Array, *, slot_mask: jax.Array | None,
                      layer: int = 0, batch_offset: int = 0,
                      block_table: jax.Array | None = None) -> jax.Array:
    """D2H writeback: scatter rows [B,Q,D] into the host cache at ids
    [B,Q] (sequence positions; -1 = masked).  Returns the functionally
    updated full cache (XLA aliases the host buffer in place when the step
    donates its caches).

    ``slot_mask`` is **required, keyword-only** (the serve loop's live-slot
    contract: an un-gated scatter from a freed or mid-prefill slot is
    exactly the page-0 aliasing bug class — see ANALYSIS.md ESS001).
    ``slot_mask=None`` states explicitly that every batch row is live (or
    that the caller already folded the mask into ``ids``); a ``[B]`` bool
    mask drops the writes of masked rows in-step.

    With ``block_table`` the positions route through the paged
    indirection; writes to unmapped pages are dropped.  Masked rows are
    otherwise handled read-modify-write (rewrite the current value), so no
    copy of the huge host buffer is ever materialized."""
    ctx = shd.current()
    if slot_mask is not None:
        ids = jnp.where(slot_mask[:, None], ids, -1)
    B, Q = ids.shape

    if block_table is not None:
        R = host_cache.shape[-2]
        NP = host_cache.shape[-3]
        D = host_cache.shape[-1]
        phys, valid = _paged_phys(ids, block_table, R, NP, batch_offset)
        if ctx is None or ctx.mesh is None:
            cl = host_cache[layer] if host_cache.ndim == 4 else host_cache
            flat = cl.reshape(NP * R, D)
            tgt = jnp.where(valid, phys, NP * R)         # OOB -> drop
            flat2 = flat.at[tgt].set(rows.astype(cl.dtype), mode="drop")
            cl2 = flat2.reshape(NP, R, D)
            return (host_cache.at[layer].set(cl2) if host_cache.ndim == 4
                    else cl2)

        # masked/unmapped rows are routed to an out-of-bounds sentinel and
        # dropped — a clipped target (page 0 row 0) would alias a *live*
        # slot's physical row, and a duplicate-index scatter against that
        # slot's own append leaves the winner unspecified
        tgt = jnp.where(valid, phys, NP * R)
        ax2 = host_sharding_for(tgt.shape, ("cache_batch", None))
        tgt_h = jax.device_put(tgt, ax2)
        rows_h = jax.device_put(rows.astype(host_cache.dtype),
                                host_sharding_for(
                                    rows.shape, ("cache_batch", None, None)))

        @compute_on("device_host")
        @jax.jit
        def _scatter_paged(c, i, r):
            cl = c[layer] if c.ndim == 4 else c
            flat = cl.reshape(NP * R, D)
            flat2 = flat.at[i].set(r, mode="drop")
            cl2 = flat2.reshape(NP, R, D)
            if c.ndim == 4:
                return jax.lax.dynamic_update_slice_in_dim(c, cl2[None],
                                                           layer, axis=0)
            return cl2

        return _scatter_paged(host_cache, tgt_h, rows_h)

    S = host_cache.shape[-2]
    valid = ids >= 0
    safe = jnp.clip(ids, 0, S - 1)
    if ctx is None or ctx.mesh is None:
        cl = host_cache[layer] if host_cache.ndim == 4 else host_cache
        cl_s = jax.lax.dynamic_slice_in_dim(cl, batch_offset, B, axis=0)
        cur = jnp.take_along_axis(cl_s, safe[..., None], axis=1)
        r2 = jnp.where(valid[..., None], rows.astype(cl.dtype), cur)
        bi = jnp.arange(B)[:, None]
        cl2_s = cl_s.at[bi, safe].set(r2)
        cl2 = jax.lax.dynamic_update_slice_in_dim(cl, cl2_s, batch_offset,
                                                  axis=0)
        return (host_cache.at[layer].set(cl2) if host_cache.ndim == 4
                else cl2)

    bi = jax.lax.broadcasted_iota(jnp.int32, (B, Q), 0) + batch_offset
    ax2 = host_sharding_for(bi.shape, ("cache_batch", None))
    bi_h = jax.device_put(bi, ax2)
    ids_h = jax.device_put(safe, ax2)
    valid_h = jax.device_put(valid, ax2)
    rows_h = jax.device_put(rows.astype(host_cache.dtype), host_sharding_for(
        rows.shape, ("cache_batch", None, None)))

    @compute_on("device_host")
    @jax.jit
    def _scatter(c, b2, i, v, r):
        cl = c[layer] if c.ndim == 4 else c
        cur = cl.at[b2, i].get(mode="promise_in_bounds")
        r2 = jnp.where(v[..., None], r, cur)
        cl2 = cl.at[b2, i].set(r2, mode="promise_in_bounds")
        if c.ndim == 4:
            return jax.lax.dynamic_update_slice_in_dim(c, cl2[None], layer,
                                                       axis=0)
        return cl2

    return _scatter(host_cache, bi_h, ids_h, valid_h, rows_h)


def host_scatter_rows_stacked(host_cache: jax.Array, ids: jax.Array,
                              rows: jax.Array, *,
                              slot_mask: jax.Array | None,
                              batch_offset: int = 0,
                              block_table: jax.Array | None = None
                              ) -> jax.Array:
    """Scatter rows [L,B,Q,D] at the *same* positions ids [B,Q] into every
    layer of a stacked host cache in one pass (admission graft: the target
    pages are identical per layer, so L separate per-layer scatters would
    functionally rewrite the full pool L times).

    ``slot_mask`` is required keyword-only, exactly as in
    :func:`host_scatter_rows` (ESS001)."""
    ctx = shd.current()
    if slot_mask is not None:
        ids = jnp.where(slot_mask[:, None], ids, -1)
    Lh = host_cache.shape[0]
    if ctx is not None and ctx.mesh is not None:
        # mesh path: fall back to the per-layer host-compute scatter
        out = host_cache
        for layer in range(Lh):
            out = host_scatter_rows(out, ids, rows[layer], slot_mask=None,
                                    layer=layer, batch_offset=batch_offset,
                                    block_table=block_table)
        return out
    B, Q = ids.shape
    D = host_cache.shape[-1]
    if block_table is not None:
        NP, R = host_cache.shape[1], host_cache.shape[2]
        phys, valid = _paged_phys(ids, block_table, R, NP, batch_offset)
        flat = host_cache.reshape(Lh, NP * R, D)
        tgt = jnp.where(valid, phys, NP * R)             # OOB -> drop
        flat2 = flat.at[:, tgt].set(
            rows.astype(host_cache.dtype), mode="drop")
        return flat2.reshape(Lh, NP, R, D)
    S = host_cache.shape[-2]
    valid = (ids >= 0) & (ids < S)
    bi = jnp.broadcast_to(jnp.arange(B)[:, None] + batch_offset, ids.shape)
    bi = jnp.where(valid, bi, host_cache.shape[1])       # OOB -> drop
    safe = jnp.clip(ids, 0, S - 1)
    return host_cache.at[:, bi, safe].set(
        rows.astype(host_cache.dtype), mode="drop")


def gather_into_slab(host_cache: jax.Array, ids: jax.Array, *,
                     slot_mask: jax.Array | None, batch_offset: int = 0,
                     block_table: jax.Array | None = None) -> jax.Array:
    """Async-offload staging gather: the H2D half of the split transfer.

    ``ids [L,B,P]`` are *per-layer* predicted positions (``-1`` = not
    staged); the result ``[L,B,P,D]`` is the device-resident landing slab
    round ``N+1`` computes against.  Each layer routes through the same
    FlashTrans gather as the synchronous fetch, so a staged row is
    bit-identical to what the fallback would read — speculation can be
    wasted, never wrong.

    ``slot_mask`` is required keyword-only (ESS001): staging rows for a
    frozen slot would land the previous occupant's pages in the slab."""
    if slot_mask is not None:
        ids = jnp.where(slot_mask[None, :, None], ids, -1)
    return jnp.stack([
        host_gather_rows(host_cache, ids[layer], layer=layer,
                         batch_offset=batch_offset,
                         block_table=block_table)
        for layer in range(ids.shape[0])])


def scatter_from_slab(host_cache: jax.Array, ids: jax.Array,
                      rows: jax.Array, *, slot_mask: jax.Array | None,
                      batch_offset: int = 0,
                      block_table: jax.Array | None = None) -> jax.Array:
    """Async-offload spill flush: the D2H half of the split transfer.

    ``rows [L,B,Q,D]`` is the round's spill slab — every layer's freshly
    appended latents, collected during compute and committed in **one**
    stacked scatter at the commit stage (the synchronous round pays L
    per-layer functional pool rewrites instead).  Positions ``ids
    [B,Q]`` are shared across layers; ``-1`` rows drop.

    ``slot_mask`` is required keyword-only (ESS001), exactly as in
    :func:`host_scatter_rows`."""
    return host_scatter_rows_stacked(host_cache, ids, rows,
                                     slot_mask=slot_mask,
                                     batch_offset=batch_offset,
                                     block_table=block_table)


# ---------------------------------------------------------------------------
# Quantized-tier wrappers: dequant-on-gather / quantize-on-scatter
# ---------------------------------------------------------------------------
# The quantized host tier is two pinned-host arrays moved by the *same*
# FlashTrans machinery above: the int8/fp8 payload [.., NP, R, D] and a
# per-page scale vector [.., NP, R, 1] (one SCALE_DTYPE scale per row —
# see repro.distributed.compression).  Every transfer crosses PCIe
# compressed; bf16 rows only ever materialize at miss width, on device,
# after the DMA (the ESS106 audit proves no cache-tier-sized upcast
# survives into any StepProgram).


def gather_tier_rows(host_cache: jax.Array, host_scales: jax.Array | None,
                     ids: jax.Array, *, layer: int = 0,
                     batch_offset: int = 0,
                     block_table: jax.Array | None = None,
                     out_dtype=None) -> jax.Array:
    """Scale-aware fetch: ids [B,M] -> dequantized rows [B,M,D] on device.

    ``host_scales is None`` is the raw bf16 tier (identical to
    :func:`host_gather_rows`).  Quantized tiers gather the payload and the
    scale column through two host-compute gathers — both DMAs move
    compressed data — and dequantize at **miss width** on device.  Masked
    ids return exact zeros either way (payload 0 x scale 0)."""
    rows = host_gather_rows(host_cache, ids, layer=layer,
                            batch_offset=batch_offset,
                            block_table=block_table)
    if host_scales is None:
        return rows if out_dtype is None else rows.astype(out_dtype)
    srows = host_gather_rows(host_scales, ids, layer=layer,
                             batch_offset=batch_offset,
                             block_table=block_table)
    return cmp.dequantize_rows(rows, srows,
                               jnp.bfloat16 if out_dtype is None
                               else out_dtype)


def scatter_tier_rows(host_cache: jax.Array, host_scales: jax.Array | None,
                      ids: jax.Array, rows: jax.Array, *,
                      slot_mask: jax.Array | None, layer: int = 0,
                      batch_offset: int = 0,
                      block_table: jax.Array | None = None
                      ) -> tuple[jax.Array, jax.Array | None]:
    """Quantize-on-scatter writeback; returns ``(cache', scales')``.

    Quantization happens on device at **append width** ([B,Q,D]); only the
    one-byte payload and the scale column cross PCIe.  ``slot_mask`` is
    required keyword-only exactly as in :func:`host_scatter_rows`
    (ESS001)."""
    if host_scales is None:
        return host_scatter_rows(host_cache, ids, rows, slot_mask=slot_mask,
                                 layer=layer, batch_offset=batch_offset,
                                 block_table=block_table), None
    q, s = cmp.quantize_rows(rows, host_cache.dtype)
    cache2 = host_scatter_rows(host_cache, ids, q, slot_mask=slot_mask,
                               layer=layer, batch_offset=batch_offset,
                               block_table=block_table)
    scales2 = host_scatter_rows(host_scales, ids, s, slot_mask=slot_mask,
                                layer=layer, batch_offset=batch_offset,
                                block_table=block_table)
    return cache2, scales2


def scatter_tier_rows_stacked(host_cache: jax.Array,
                              host_scales: jax.Array | None,
                              ids: jax.Array, rows: jax.Array, *,
                              slot_mask: jax.Array | None,
                              batch_offset: int = 0,
                              block_table: jax.Array | None = None
                              ) -> tuple[jax.Array, jax.Array | None]:
    """All-layer quantize-on-scatter (admission graft / prefill flush):
    rows [L,B,Q,D] quantized per row on device, then one stacked payload
    scatter + one stacked scale scatter.  Returns ``(cache', scales')``."""
    if host_scales is None:
        return host_scatter_rows_stacked(
            host_cache, ids, rows, slot_mask=slot_mask,
            batch_offset=batch_offset, block_table=block_table), None
    q, s = cmp.quantize_rows(rows, host_cache.dtype)
    cache2 = host_scatter_rows_stacked(host_cache, ids, q,
                                       slot_mask=slot_mask,
                                       batch_offset=batch_offset,
                                       block_table=block_table)
    scales2 = host_scatter_rows_stacked(host_scales, ids, s,
                                        slot_mask=slot_mask,
                                        batch_offset=batch_offset,
                                        block_table=block_table)
    return cache2, scales2


def abstract_host(shape, dtype, *axes):
    """ShapeDtypeStruct pinned to host for the dry-run."""
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=ctx.sharding_for(shape, axes, memory_kind="pinned_host"))
