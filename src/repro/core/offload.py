"""Host-tier placement + the FlashTrans-analogue transfer engine (paper §3.1).

GPU version: UVA lets the kernel dereference pinned host memory, coalescing
656 B fragments.  TPU/JAX version: the full Latent-Cache lives in a
``pinned_host`` memory-space buffer; the *gather of scattered rows runs on
the host* (``compute_on('device_host')``) and exactly one dense
``[M, D]``-row DMA crosses PCIe per layer per step — the same
transaction-coalescing effect FlashTrans achieves with UVA.  The naive
baseline (per-row ``dynamic_slice`` + copy, ~0.79 GB/s in the paper's
measurement) is modelled in the simulator for comparison.

Outside a mesh/jit context everything degrades to plain device arrays so
unit tests run on CPU without memory-space plumbing.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.compute_on import compute_on
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd


def host_available() -> bool:
    try:
        kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
        return "pinned_host" in kinds
    except Exception:  # pragma: no cover
        return False


def host_sharding(*axes, fallback_device: bool = False):
    """NamedSharding with pinned_host memory kind under the active ctx."""
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return None
    kind = "pinned_host" if not fallback_device else "device"
    return ctx.sharding(*axes, memory_kind=kind)


def host_sharding_for(shape, axes):
    """Shape-aware host sharding (prunes axes that don't divide — e.g.
    batch=1 long-context cells can't take the data axis)."""
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return None
    return ctx.sharding_for(tuple(shape), axes, memory_kind="pinned_host")


def to_host(x: jax.Array, *axes) -> jax.Array:
    s = host_sharding_for(x.shape, axes)
    if s is None:
        return x
    return jax.device_put(x, s)


def to_device(x: jax.Array, *axes) -> jax.Array:
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return x
    return jax.device_put(x, ctx.sharding(*axes))


def host_gather_rows(host_cache: jax.Array, ids: jax.Array, *,
                     layer: int = 0, batch_offset: int = 0,
                     axes_out=("cache_batch", None, None)) -> jax.Array:
    """FlashTrans fetch: host_cache [B,S,D] or [L,B,S,D] (pinned_host),
    ids [B,M] (-1 padding) -> rows [B,M,D] on device.

    The gather executes in the host memory space; the (batch, position)
    index pairs are packed on the *device* and shipped to the host, so the
    host computation is exactly one ``lax.gather`` — no auxiliary iota or
    bounds constants can land in the wrong memory space, and the SPMD
    partitioner keeps everything batch-sharded (verified: zero host-buffer
    all-gathers).  Only the packed [B,M,D] result is DMA'd to the device —
    one coalesced transaction instead of M fragmented ones (the FlashTrans
    effect).
    """
    ctx = shd.current()
    B, M = ids.shape
    S = host_cache.shape[-2]
    D = host_cache.shape[-1]
    safe = jnp.clip(ids, 0, S - 1)
    if ctx is None or ctx.mesh is None:
        cl = host_cache[layer] if host_cache.ndim == 4 else host_cache
        cl = jax.lax.slice_in_dim(cl, batch_offset, batch_offset + B, axis=0)
        rows = jnp.take_along_axis(cl, safe[..., None], axis=1)
        return jnp.where((ids >= 0)[..., None], rows, 0)

    bi = jax.lax.broadcasted_iota(jnp.int32, (B, M), 0) + batch_offset
    idx2 = jnp.stack([bi, safe], axis=-1)
    idx2_h = jax.device_put(idx2, host_sharding_for(
        idx2.shape, ("cache_batch", None, None)))
    dn = jax.lax.GatherDimensionNumbers(
        offset_dims=(2,), collapsed_slice_dims=(0, 1),
        start_index_map=(0, 1))

    @compute_on("device_host")
    @jax.jit
    def _gather(c, i):
        cl = c[layer] if c.ndim == 4 else c
        return jax.lax.gather(cl, i, dn, (1, 1, D),
                              mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS)

    rows = _gather(host_cache, idx2_h)
    rows = jax.device_put(rows, ctx.sharding_for((B, M, D), axes_out))
    return jnp.where((ids >= 0)[..., None], rows, 0)


def host_scatter_rows(host_cache: jax.Array, ids: jax.Array,
                      rows: jax.Array, *, layer: int = 0,
                      batch_offset: int = 0) -> jax.Array:
    """D2H writeback: scatter rows [B,Q,D] into the host cache at ids
    [B,Q] (sequence positions; -1 = masked).  Returns the functionally
    updated full cache (XLA aliases the host buffer in place when the step
    donates its caches).

    Masked rows are handled read-modify-write (rewrite the current value),
    so no copy of the huge host buffer is ever materialized."""
    ctx = shd.current()
    B, Q = ids.shape
    S = host_cache.shape[-2]
    valid = ids >= 0
    safe = jnp.clip(ids, 0, S - 1)
    if ctx is None or ctx.mesh is None:
        cl = host_cache[layer] if host_cache.ndim == 4 else host_cache
        cur = jnp.take_along_axis(cl, safe[..., None], axis=1)
        r2 = jnp.where(valid[..., None], rows.astype(cl.dtype), cur)
        bi = jnp.arange(B)[:, None]
        cl2 = cl.at[bi, safe].set(r2)
        return (host_cache.at[layer].set(cl2) if host_cache.ndim == 4
                else cl2)

    bi = jax.lax.broadcasted_iota(jnp.int32, (B, Q), 0) + batch_offset
    ax2 = host_sharding_for(bi.shape, ("cache_batch", None))
    bi_h = jax.device_put(bi, ax2)
    ids_h = jax.device_put(safe, ax2)
    valid_h = jax.device_put(valid, ax2)
    rows_h = jax.device_put(rows.astype(host_cache.dtype), host_sharding_for(
        rows.shape, ("cache_batch", None, None)))

    @compute_on("device_host")
    @jax.jit
    def _scatter(c, b2, i, v, r):
        cl = c[layer] if c.ndim == 4 else c
        cur = cl.at[b2, i].get(mode="promise_in_bounds")
        r2 = jnp.where(v[..., None], r, cur)
        cl2 = cl.at[b2, i].set(r2, mode="promise_in_bounds")
        if c.ndim == 4:
            return jax.lax.dynamic_update_slice_in_dim(c, cl2[None], layer,
                                                       axis=0)
        return cl2

    return _scatter(host_cache, bi_h, ids_h, valid_h, rows_h)


def abstract_host(shape, dtype, *axes):
    """ShapeDtypeStruct pinned to host for the dry-run."""
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=ctx.sharding_for(shape, axes, memory_kind="pinned_host"))
