"""Shared kernel utilities: interpret-mode selection, padding helpers."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.cache
def default_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode on non-TPU backends
    (this container is CPU-only; TPU v5e is the deployment target)."""
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_dim(x: jax.Array, axis: int, to: int, value=0) -> jax.Array:
    pad = to - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg, constant_values=value)
