"""Jit'd public wrappers for the indexer kernel (batched, + top-k select)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.indexer.indexer import indexer_scores_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def indexer_scores(q: jax.Array, w: jax.Array, keys: jax.Array,
                   valid: jax.Array, interpret: bool | None = None
                   ) -> jax.Array:
    """q [B,Q,Hi,Di], w [B,Q,Hi], keys [B,S,Di], valid [B,S]
    -> scores [B,Q,S] fp32 (-inf at invalid)."""
    def per_q(qq, ww, kk, vv):
        return indexer_scores_kernel(qq, ww, kk, vv, interpret=interpret)
    per_b = jax.vmap(per_q, in_axes=(0, 0, None, None))      # over Q
    return jax.vmap(per_b)(q, w, keys, valid)                # over B


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_select(q: jax.Array, w: jax.Array, keys: jax.Array,
                valid: jax.Array, k: int, interpret: bool | None = None):
    """Scores + Top-K ids in one call — the DSA selection stage."""
    sc = indexer_scores(q, w, keys, valid, interpret=interpret)
    vals, ids = jax.lax.top_k(sc, k)
    return vals, ids
