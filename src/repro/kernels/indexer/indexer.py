"""Lightning-indexer scoring kernel (DSA, DeepSeek-V3.2-Exp).

score[s] = Σ_h w[h] · ReLU(q[h] · k[s])   over index heads h.

This is the "paged_mqa_logits" component the paper moves into the DBA
overlap region (§3.3) because its arithmetic intensity survives batch
splitting: per key block the work is two MXU matmuls

    dots  (SB, Hi) = keys (SB, Di) @ q^T (Di, Hi)     Di=128, Hi=64
    score (SB, 1)  = ReLU(dots) @ w (Hi, 1)

Grid over S key-blocks; embarrassingly parallel (no cross-step state).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret, round_up

NEG_INF = -2.0e38
DEFAULT_SB = 256


def _indexer_kernel(q_ref, w_ref, k_ref, v_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)                  # [Hi, Di]
    w = w_ref[...].astype(jnp.float32)                  # [Hi, 1]
    keys = k_ref[...].astype(jnp.float32)               # [SB, Di]
    valid = v_ref[...].astype(jnp.float32)              # [SB, 1]
    dots = jax.lax.dot_general(keys, q, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    sc = jax.lax.dot_general(jax.nn.relu(dots), w, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [SB,1]
    o_ref[...] = jnp.where(valid > 0.5, sc, NEG_INF)


def indexer_scores_kernel(q: jax.Array, w: jax.Array, keys: jax.Array,
                          valid: jax.Array, sb: int = DEFAULT_SB,
                          interpret: bool | None = None) -> jax.Array:
    """q [Hi,Di], w [Hi], keys [S,Di], valid [S] -> scores [S] fp32
    (invalid slots = -inf, ready for top-k)."""
    if interpret is None:
        interpret = default_interpret()
    Hi, Di = q.shape
    S = keys.shape[0]
    sb = min(sb, max(8, round_up(S, 8)))
    Sp = round_up(S, sb)
    Hp = round_up(max(Hi, 8), 8)

    qp = jnp.pad(q, ((0, Hp - Hi), (0, 0)))
    wp = jnp.pad(w, (0, Hp - Hi))[:, None]
    kp = jnp.pad(keys, ((0, Sp - S), (0, 0)))
    vp = jnp.pad(valid.astype(jnp.float32), (0, Sp - S))[:, None]

    out = pl.pallas_call(
        _indexer_kernel,
        grid=(Sp // sb,),
        in_specs=[
            pl.BlockSpec((Hp, Di), lambda i: (0, 0)),
            pl.BlockSpec((Hp, 1), lambda i: (0, 0)),
            pl.BlockSpec((sb, Di), lambda i: (i, 0)),
            pl.BlockSpec((sb, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((sb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, 1), jnp.float32),
        interpret=interpret,
    )(qp, wp, kp, vp)
    return out[:S, 0]
