"""Pure-jnp oracle for the lightning-indexer kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def indexer_scores_ref(q: jax.Array, w: jax.Array, keys: jax.Array,
                       valid: jax.Array) -> jax.Array:
    dots = keys.astype(jnp.float32) @ q.astype(jnp.float32).T    # [S, Hi]
    sc = jax.nn.relu(dots) @ w.astype(jnp.float32)               # [S]
    return jnp.where(valid, sc, NEG_INF)
