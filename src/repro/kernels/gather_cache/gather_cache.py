"""FlashTrans-analogue gather kernel (paper §3.1, adapted to TPU).

The paper's FlashTrans uses UVA so the GPU coalesces 656 B scattered
Latent-Cache rows out of CPU memory.  The TPU analogue at the *device* tier:
rows are scattered across a big HBM-resident pool and must be packed into a
dense VMEM-friendly buffer for the attention kernel.  Scalar-prefetched
indices drive the BlockSpec ``index_map``, so each grid step DMAs exactly
the requested row — the Pallas pipeline overlaps the row DMAs with the
copy-out, which is the in-kernel version of FlashTrans's transaction
coalescing.

Host→device traffic itself is handled by ``repro.core.offload`` (memory
spaces); this kernel covers the on-device pool→contiguous packing that both
Attn0 (pool hits) and the LRU admission path need.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret, round_up

ROW_BLOCK = 8   # rows gathered per grid step (DMA batching factor)


def _gather_kernel(ids_ref, cache_ref, out_ref):
    # cache_ref block: (ROW_BLOCK, D) rows selected by index_map
    out_ref[...] = cache_ref[...]


def _index_map_cache(i, ids_ref):
    # block index along rows: ids are pre-divided by ROW_BLOCK groups; each
    # grid step copies ROW_BLOCK consecutive *virtual* rows whose physical
    # row ids are ids_ref[i*ROW_BLOCK : (i+1)*ROW_BLOCK]. BlockSpec can only
    # address one block origin per step, so rows are fetched one per step
    # when indices are arbitrary: ROW_BLOCK=1 path. For ROW_BLOCK>1 we rely
    # on the id-sorted fast path (see ops.gather_rows sorted=True).
    return ids_ref[i], 0


def gather_rows_kernel(cache: jax.Array, ids: jax.Array,
                       interpret: bool | None = None) -> jax.Array:
    """cache [S, D], ids [M] int32 (negative -> row 0, masked later)
    -> out [M, D].  One row per grid step, index_map-driven DMA."""
    S, D = cache.shape
    M = ids.shape[0]
    if interpret is None:
        interpret = default_interpret()
    safe = jnp.clip(ids, 0, S - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[pl.BlockSpec((1, D), _index_map_cache)],
        out_specs=pl.BlockSpec((1, D), lambda i, ids_ref: (i, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, D), cache.dtype),
        interpret=interpret,
    )(safe, cache)
    return out


def _gather_dequant_kernel(ids_ref, cache_ref, scales_ref, out_ref):
    # fused dequant at block width: the int8/fp8 payload never becomes a
    # wide tensor outside this (rows, D) tile (contract ESS106)
    out_ref[...] = (cache_ref[...].astype(jnp.float32)
                    * scales_ref[...].astype(jnp.float32)
                    ).astype(out_ref.dtype)


def gather_rows_dequant_kernel(cache: jax.Array, scales: jax.Array,
                               ids: jax.Array, out_dtype=jnp.bfloat16,
                               interpret: bool | None = None) -> jax.Array:
    """Quantized-tier row gather: cache [S, D] int8/fp8, scales [S, 1],
    ids [M] int32 -> out [M, D] ``out_dtype``.  One row per grid step —
    the DMA moves the compressed payload + a scalar scale; dequant runs
    on the gathered tile inside the kernel."""
    S, D = cache.shape
    M = ids.shape[0]
    if interpret is None:
        interpret = default_interpret()
    safe = jnp.clip(ids, 0, S - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[pl.BlockSpec((1, D), _index_map_cache),
                  pl.BlockSpec((1, 1), _index_map_cache)],
        out_specs=pl.BlockSpec((1, D), lambda i, ids_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, D), out_dtype),
        interpret=interpret,
    )(safe, cache, scales)


def _gather_block_kernel(base_ref, cache_ref, out_ref):
    out_ref[...] = cache_ref[...]


def gather_row_blocks_kernel(cache: jax.Array, block_ids: jax.Array,
                             block_rows: int,
                             interpret: bool | None = None) -> jax.Array:
    """Paged variant: gather whole row-blocks (pages).  cache [S, D] with
    S % block_rows == 0, block_ids [NB] -> out [NB*block_rows, D].

    This is the PagedAttention-style page fetch; ESS uses it when the pool
    is managed at page granularity instead of single entries."""
    S, D = cache.shape
    NB = block_ids.shape[0]
    if interpret is None:
        interpret = default_interpret()
    safe = jnp.clip(block_ids, 0, S // block_rows - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(NB,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i, ids: (ids[i], 0))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _gather_block_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((NB * block_rows, D), cache.dtype),
        interpret=interpret,
    )(safe, cache)


def _gather_block_dequant_kernel(base_ref, cache_ref, scales_ref, out_ref):
    out_ref[...] = (cache_ref[...].astype(jnp.float32)
                    * scales_ref[...].astype(jnp.float32)
                    ).astype(out_ref.dtype)


def gather_row_blocks_dequant_kernel(cache: jax.Array, scales: jax.Array,
                                     block_ids: jax.Array, block_rows: int,
                                     out_dtype=jnp.bfloat16,
                                     interpret: bool | None = None
                                     ) -> jax.Array:
    """Quantized paged variant: whole-page fetch + per-row dequant.
    cache [S, D] int8/fp8 with S % block_rows == 0, scales [S, 1],
    block_ids [NB] -> out [NB*block_rows, D] ``out_dtype``.  Each grid
    step DMAs one compressed page and its scale column and widens only
    that (block_rows, D) tile."""
    S, D = cache.shape
    NB = block_ids.shape[0]
    if interpret is None:
        interpret = default_interpret()
    safe = jnp.clip(block_ids, 0, S // block_rows - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(NB,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i, ids: (ids[i], 0)),
                  pl.BlockSpec((block_rows, 1), lambda i, ids: (ids[i], 0))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _gather_block_dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((NB * block_rows, D), out_dtype),
        interpret=interpret,
    )(safe, cache, scales)
