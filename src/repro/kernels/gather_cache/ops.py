"""Jit'd public wrappers for the gather kernels (batched over sequences)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_cache.gather_cache import (gather_row_blocks_kernel,
                                                     gather_rows_kernel)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(cache: jax.Array, ids: jax.Array,
                interpret: bool | None = None) -> jax.Array:
    """cache [B,S,D] (or [S,D]), ids [B,M] (or [M]) -> rows, zero-masked
    where ids < 0."""
    if cache.ndim == 2:
        out = gather_rows_kernel(cache, ids, interpret)
        return jnp.where((ids >= 0)[:, None], out, 0)
    out = jax.vmap(lambda c, i: gather_rows_kernel(c, i, interpret))(cache, ids)
    return jnp.where((ids >= 0)[..., None], out, 0)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gather_pages(cache: jax.Array, block_ids: jax.Array, block_rows: int,
                 interpret: bool | None = None) -> jax.Array:
    if cache.ndim == 2:
        return gather_row_blocks_kernel(cache, block_ids, block_rows, interpret)
    return jax.vmap(lambda c, i: gather_row_blocks_kernel(
        c, i, block_rows, interpret))(cache, block_ids)
