"""Jit'd public wrappers for the gather kernels (batched over sequences)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_cache.gather_cache import (
    gather_row_blocks_dequant_kernel, gather_row_blocks_kernel,
    gather_rows_dequant_kernel, gather_rows_kernel)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(cache: jax.Array, ids: jax.Array,
                interpret: bool | None = None) -> jax.Array:
    """cache [B,S,D] (or [S,D]), ids [B,M] (or [M]) -> rows, zero-masked
    where ids < 0."""
    if cache.ndim == 2:
        out = gather_rows_kernel(cache, ids, interpret)
        return jnp.where((ids >= 0)[:, None], out, 0)
    out = jax.vmap(lambda c, i: gather_rows_kernel(c, i, interpret))(cache, ids)
    return jnp.where((ids >= 0)[..., None], out, 0)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gather_pages(cache: jax.Array, block_ids: jax.Array, block_rows: int,
                 interpret: bool | None = None) -> jax.Array:
    if cache.ndim == 2:
        return gather_row_blocks_kernel(cache, block_ids, block_rows, interpret)
    return jax.vmap(lambda c, i: gather_row_blocks_kernel(
        c, i, block_rows, interpret))(cache, block_ids)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def gather_rows_dequant(cache: jax.Array, scales: jax.Array,
                        ids: jax.Array, out_dtype=jnp.bfloat16,
                        interpret: bool | None = None) -> jax.Array:
    """Fused quantized-tier gather: cache [B,S,D] (or [S,D]) int8/fp8 +
    scales [B,S,1] (or [S,1]) -> ``out_dtype`` rows, zero-masked where
    ids < 0.  The wide representation only exists at gather width."""
    if cache.ndim == 2:
        out = gather_rows_dequant_kernel(cache, scales, ids, out_dtype,
                                         interpret)
        return jnp.where((ids >= 0)[:, None], out, 0)
    out = jax.vmap(lambda c, s, i: gather_rows_dequant_kernel(
        c, s, i, out_dtype, interpret))(cache, scales, ids)
    return jnp.where((ids >= 0)[..., None], out, 0)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "out_dtype", "interpret"))
def gather_pages_dequant(cache: jax.Array, scales: jax.Array,
                         block_ids: jax.Array, block_rows: int,
                         out_dtype=jnp.bfloat16,
                         interpret: bool | None = None) -> jax.Array:
    """Fused quantized page fetch (paged tier): one compressed page +
    scale column DMA'd and widened per grid step."""
    if cache.ndim == 2:
        return gather_row_blocks_dequant_kernel(
            cache, scales, block_ids, block_rows, out_dtype, interpret)
    return jax.vmap(lambda c, s, i: gather_row_blocks_dequant_kernel(
        c, s, i, block_rows, out_dtype, interpret))(cache, scales, block_ids)
