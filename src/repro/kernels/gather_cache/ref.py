"""Pure-jnp oracle for the gather kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows_ref(cache: jax.Array, ids: jax.Array) -> jax.Array:
    safe = jnp.clip(ids, 0, cache.shape[0] - 1)
    return jnp.take(cache, safe, axis=0)


def gather_row_blocks_ref(cache: jax.Array, block_ids: jax.Array,
                          block_rows: int) -> jax.Array:
    S, D = cache.shape
    pages = cache.reshape(S // block_rows, block_rows, D)
    safe = jnp.clip(block_ids, 0, S // block_rows - 1)
    return jnp.take(pages, safe, axis=0).reshape(-1, D)


def gather_rows_dequant_ref(cache: jax.Array, scales: jax.Array,
                            ids: jax.Array,
                            out_dtype=jnp.bfloat16) -> jax.Array:
    q = gather_rows_ref(cache, ids).astype(jnp.float32)
    s = gather_rows_ref(scales, ids).astype(jnp.float32)
    return (q * s).astype(out_dtype)


def gather_row_blocks_dequant_ref(cache: jax.Array, scales: jax.Array,
                                  block_ids: jax.Array, block_rows: int,
                                  out_dtype=jnp.bfloat16) -> jax.Array:
    q = gather_row_blocks_ref(cache, block_ids,
                              block_rows).astype(jnp.float32)
    s = gather_row_blocks_ref(scales, block_ids,
                              block_rows).astype(jnp.float32)
    return (q * s).astype(out_dtype)
