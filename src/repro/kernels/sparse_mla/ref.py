"""Pure-jnp oracle for the sparse-MLA partial kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def sparse_mla_partial_ref(q: jax.Array, rows: jax.Array, valid: jax.Array,
                           scale: float, rank: int):
    """q [H,D], rows [K,D], valid [K] -> (o [H,rank], m [H], l [H]) fp32."""
    s = (q.astype(jnp.float32) @ rows.astype(jnp.float32).T) * scale
    s = jnp.where(valid[None, :], s, NEG_INF)
    m = s.max(axis=1)
    p = jnp.exp(s - m[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    l = p.sum(axis=1)
    o = p @ rows[:, :rank].astype(jnp.float32)
    return o, m, l


def finalize_ref(o, m, l, dtype=jnp.float32):
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)
