"""Sparse-MLA decode kernel — the FlashMLA analogue for ESS (paper Table 1's
"Attention-Engine", adapted to TPU/MXU).

Decode-time MLA in absorbed form is MQA: per-head 576-dim queries attend to
the shared latent rows.  ESS calls this twice per layer (Attn0 over pool
hits, Attn1 over fetched misses) and merges the partials exactly, so the
kernel returns *unnormalized* flash statistics (o, m, l) rather than the
normalized output.

Tiling: grid over K row-blocks; per step one (KB, D) row block is DMA'd
HBM→VMEM while the previous block is on the MXU (Pallas pipelining).  The
online-softmax accumulator lives in VMEM scratch:

    scores (Hp, KB) = q (Hp, D) @ rows^T (D, KB)   — MXU, D=576=4.5×128
    acc    (Hp, R)  += p (Hp, KB) @ rows[:, :R]    — MXU, R=512

Hp (query-head block) is padded to the 128-lane register width; KB defaults
to 128 so both matmuls are 128-aligned.  VMEM working set ≈
q 288 KB + rows 288 KB + acc 256 KB ≪ 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret, round_up

NEG_INF = -2.0e38
DEFAULT_KB = 128


def _sparse_mla_kernel(q_ref, rows_ref, valid_ref, o_ref, m_ref, l_ref,
                       acc, m_sc, l_sc, *, rank: float, scale: float,
                       nblocks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    q = q_ref[...].astype(jnp.float32)                    # [Hp, D]
    rows = rows_ref[...].astype(jnp.float32)              # [KB, D]
    valid = valid_ref[...].astype(jnp.float32)            # [1, KB]

    s = jax.lax.dot_general(q, rows, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid > 0.5, s, NEG_INF)                # [Hp, KB]

    m_prev = m_sc[...]                                    # [Hp, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid > 0.5, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                        # [Hp, 1]
    l_sc[...] = l_sc[...] * corr + p.sum(axis=1, keepdims=True)
    acc[...] = acc[...] * corr + jax.lax.dot_general(
        p, rows[:, :int(rank)], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(i == nblocks - 1)
    def _done():
        o_ref[...] = acc[...]
        m_ref[...] = m_sc[...]
        l_ref[...] = l_sc[...]


def sparse_mla_partial_kernel(q: jax.Array, rows: jax.Array,
                              valid: jax.Array, scale: float, rank: int,
                              kb: int = DEFAULT_KB,
                              interpret: bool | None = None):
    """q [H, D], rows [K, D], valid [K] bool -> (o [H,rank], m [H], l [H]).

    Unnormalized flash partials (fp32)."""
    if interpret is None:
        interpret = default_interpret()
    H, D = q.shape
    K = rows.shape[0]
    Hp = round_up(max(H, 8), 8)
    kb = min(kb, K)
    Kp = round_up(K, kb)
    nb = Kp // kb

    qp = jnp.pad(q, ((0, Hp - H), (0, 0)))
    rowsp = jnp.pad(rows, ((0, Kp - K), (0, 0)))
    vp = jnp.pad(valid.astype(jnp.float32), (0, Kp - K))[None, :]  # [1, Kp]

    kern = functools.partial(_sparse_mla_kernel, rank=rank, scale=scale,
                             nblocks=nb)
    o, m, l = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((Hp, D), lambda i: (0, 0)),
            pl.BlockSpec((kb, D), lambda i: (i, 0)),
            pl.BlockSpec((1, kb), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((Hp, rank), lambda i: (0, 0)),
            pl.BlockSpec((Hp, 1), lambda i: (0, 0)),
            pl.BlockSpec((Hp, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Hp, rank), jnp.float32),
            jax.ShapeDtypeStruct((Hp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Hp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Hp, rank), jnp.float32),
            pltpu.VMEM((Hp, 1), jnp.float32),
            pltpu.VMEM((Hp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, rowsp, vp)
    return o[:H], m[:H, 0], l[:H, 0]
