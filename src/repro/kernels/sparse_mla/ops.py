"""Jit'd public wrappers: batched sparse-MLA partials + fused gather-attend."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sparse_mla.sparse_mla import sparse_mla_partial_kernel


@functools.partial(jax.jit, static_argnames=("scale", "rank", "interpret"))
def partial_attend(q_comb: jax.Array, rows: jax.Array, valid: jax.Array,
                   scale: float, rank: int, interpret: bool | None = None):
    """Batched flash partials.

    q_comb [B,Q,H,D]; rows [B,K,D] (shared over Q) or [B,Q,K,D];
    valid [B,K] / [B,Q,K].  Returns Partial-compatible (o, m, l) with
    o [B,Q,H,rank], m/l [B,Q,H] — consumed by repro.models.mla.merge_partials.
    """
    from repro.models.mla import Partial
    if rows.ndim == 3:
        rows = jnp.broadcast_to(rows[:, None], q_comb.shape[:2] + rows.shape[1:])
        valid = jnp.broadcast_to(valid[:, None], q_comb.shape[:2] + valid.shape[1:])
    fn = functools.partial(sparse_mla_partial_kernel, scale=scale, rank=rank,
                           interpret=interpret)
    o, m, l = jax.vmap(jax.vmap(fn))(q_comb, rows, valid)
    return Partial(o, m, l)


@functools.partial(jax.jit, static_argnames=("scale", "rank", "interpret"))
def sparse_mla_gather_attend(q_comb: jax.Array, latent_cache: jax.Array,
                             ids: jax.Array, valid_s: jax.Array,
                             scale: float, rank: int,
                             interpret: bool | None = None) -> jax.Array:
    """Gather Top-K rows then attend (normalized output).

    q_comb [B,Q,H,D], latent_cache [B,S,D], ids [B,Q,K], valid_s [B,S].
    The gather runs through kernels/gather_cache (row-DMA pipeline) and the
    attention through the flash partial kernel — the two-kernel TPU
    realization of FlashTrans + FlashMLA."""
    from repro.kernels.gather_cache import ops as gops
    B, Q, K = ids.shape
    flat = ids.reshape(B, Q * K)
    rows = gops.gather_rows(latent_cache, flat, interpret=interpret)
    rows = rows.reshape(B, Q, K, -1)
    gvalid = jnp.take_along_axis(
        jnp.broadcast_to(valid_s[:, None], (B, Q, valid_s.shape[1])), ids,
        axis=2)
    p = partial_attend(q_comb, rows, gvalid, scale, rank, interpret)
    return (p.o / jnp.maximum(p.l, 1e-30)[..., None]).astype(q_comb.dtype)
