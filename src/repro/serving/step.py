"""StepProgram builder: the serve round as donated, jitted XLA programs.

One serve round used to be traced op-by-op from Python — the 61-layer
unrolled decode re-dispatched every op every round, ``tok``/``hidden``
were rebuilt with per-slot ``.at[i].set`` loops, and every active slot
forced a host sync (``int(t)``, per-slot host-side sampling).  This
module compiles each round *kind* once per shape bucket:

* **decode**  — one Q=1 ESS step + in-device greedy/sampled token
  selection over the whole slot batch;
* **spec**    — the fused MTP round: ``mtp_draft`` + the Q=depth+1
  verify ``ess_decode`` + accept/rollback + token selection, all under
  one jit (TBO halves traced into the same program when enabled);
* **prefill** — one ``prefill_chunk`` step, shape-bucketed (ragged final
  chunks are zero-padded to the bucket and masked via ``n_valid``, so
  they never retrace), with the first-token draw in-device on the last
  chunk.

Each program takes ``(params, EngineState, ...)`` and **donates the
state** (``donate_argnums``): caches, token/hidden carries and sampling
knobs live on device round over round, and XLA aliases the big host
tier in place instead of keeping two copies.  The host's per-round
traffic collapses to one ``jax.device_get`` of the packed
:class:`~repro.serving.state.RoundOut`.

**Mode parity by construction.**  Every round function is glue around a
small set of *jitted units* — the raw model step, the speculative core
(draft + verify + rollback), the prefill-chunk core, and the samplers.
``compiled=True`` jits the whole round function (the units inline into
one donated program); ``compiled=False`` executes the glue op-by-op but
still calls the *same jitted units*.  The glue is exclusively
bit-exact arithmetic (argmax, sort-free selects, integer updates,
scatter/gather), so the two modes emit bit-identical token streams:
all floating-point math runs under XLA compilation in both, with
identical subgraphs.  (Running the units op-by-op instead would NOT be
bit-stable — XLA's fusion contracts multiply-adds, so fused and
unfused executions of the same einsum chain differ in the last ulp and
long decodes eventually flip an argmax.)  Eager mode remains the
debugging path: per-round logits, caches and emission packing are all
visible at unit boundaries.

Programs are cached process-wide (``get_programs``) so every session
with the same ``(cfg, shape family)`` reuses the same executables.

``TRACE_COUNTS[key]`` increments inside each round-function body, i.e.
at *trace* time under jit — the recompile-count guard test asserts every
program traces exactly once per shape bucket.  (In eager mode the body
runs every round, so the counters are only meaningful for compiled
sessions.)
"""

from __future__ import annotations

import functools
from collections import Counter
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.serving import mtp as MTP
from repro.serving import tbo as TBO
from repro.serving.sampling import greedy, sample_batch, sample_one
from repro.serving.state import EngineState, RoundOut, promote_slot

# program key -> times the round function body was traced (jit) or run
# (eager).  Keys: f"{kind}/{sig}" — see StepPrograms._sig.
TRACE_COUNTS: Counter = Counter()


def chunk_bucket(ck: int, prefill_chunk: int) -> int:
    """Shape bucket for a (possibly ragged) prefill chunk: the smallest
    power of two >= ``ck``, capped at ``prefill_chunk``.  Bounds the
    number of prefill programs at O(log prefill_chunk) while keeping
    short prompts cheap (an 8-token prompt buckets to 8, not to a
    4096-wide padded chunk)."""
    b = 1
    while b < ck:
        b <<= 1
    return min(b, prefill_chunk)


def _make_raw_step(cfg: ArchConfig, use_kernel: bool, tbo: bool) -> Callable:
    """(params, tokens [B,Q], positions [B,Q], caches, slot_mask, staged)
    -> DecodeOut — the TBO-composed model step both round kinds share.
    ``staged`` (default None = synchronous) is the async-offload staging
    slab pair threaded down from the EngineState leaves."""
    from repro.serving import engine as E      # engine imports this module

    def one(p_, c_, t_, po_, ca_, slot_mask=None, staged=None):
        return E.ess_decode(p_, c_, t_, po_, ca_, use_kernel=use_kernel,
                            slot_mask=slot_mask, staged=staged)

    def raw(params, tokens, positions, caches, slot_mask, staged=None):
        if tbo and tokens.shape[0] >= 2:
            logits, merged, stats = TBO.tbo_step(
                one, params, cfg, tokens, positions, caches,
                slot_mask=slot_mask, staged=staged)
            return E.DecodeOut(logits, merged, stats)
        return one(params, cfg, tokens, positions, caches,
                   slot_mask=slot_mask, staged=staged)

    return raw


class _Units(NamedTuple):
    """The jitted floating-point cores both execution modes share."""
    step: Callable          # raw Q=1 model step
    spec: Callable | None   # draft + Q=depth+1 verify + rollback
    maybe_sample: Callable  # cond-gated per-slot draws (see below)
    sample_one: Callable


def _maybe_sample_fn(seed, emit_index, logits, temperature, top_k, top_p,
                     sample_mask, fallback):
    """Per-slot draws, skipped when every slot is greedy: the sampler
    costs two full-vocab sorts + softmax/cumsum per slot per round, and
    ``sample_mask`` is a runtime array XLA cannot DCE through — the cond
    keeps the all-greedy hot path (the default workload) free of it.
    Jitted as a unit (an *eager* ``lax.cond`` would retrace both
    branches every round); both modes share it, so streams stay
    bit-identical."""
    return jax.lax.cond(
        jnp.any(sample_mask),
        lambda: sample_batch(seed, emit_index, logits, temperature,
                             top_k, top_p),
        lambda: fallback)


def _maybe_sample(units: _Units, state: EngineState, logits, fallback):
    # gate on *live* sampling slots: a sampling request still streaming
    # its prefill (admitted, frozen) must not drag the all-greedy fast
    # path into full-vocab sampling for rounds whose draw it discards
    return units.maybe_sample(state.seed, state.emit_index, logits,
                              state.temperature, state.top_k, state.top_p,
                              state.sample_mask & state.slot_mask, fallback)


def _decode_round_fn(units: _Units, key: str) -> Callable:
    """Plain Q=1 round: step the live batch, select each slot's next
    token (greedy or sampled from the per-slot knob arrays)."""

    def fn(params, state: EngineState):
        TRACE_COUNTS[key] += 1
        caches = state.caches
        staged = None if state.staged_ids is None else \
            (state.staged_ids, state.staged_rows, state.staged_scales)
        out = units.step(params, state.tok[:, None], caches.lens[:, None],
                         caches, state.slot_mask, staged)
        logits = out.logits[:, -1]                             # [B,V]
        g = greedy(logits)
        smp = _maybe_sample(units, state, logits, g)
        t = jnp.where(state.sample_mask, smp, g)
        live = state.slot_mask
        upd = {} if staged is None else dict(
            staged_ids=out.stats["staged_ids"],
            staged_rows=out.stats["staged_rows"],
            staged_scales=out.stats.get("staged_scales"))
        new_state = state._replace(
            caches=out.caches,
            tok=jnp.where(live, t, state.tok),
            hidden=jnp.where(live[:, None], out.stats["hidden"][:, -1],
                             state.hidden),
            emit_index=state.emit_index + live.astype(jnp.int32),
            **upd)
        ro = RoundOut(jnp.where(live, t, 0)[:, None], live.astype(jnp.int32),
                      h2d_rows=out.stats["misses"].sum())
        if staged is not None:
            ro = ro._replace(pf_hits=out.stats["pf_hits"],
                             pf_misses=out.stats["pf_misses"],
                             pf_wasted=out.stats["pf_wasted"])
        return new_state, ro

    return fn


def _spec_round_fn(units: _Units, key: str) -> Callable:
    """Fused MTP round: the speculative core (draft + Q=depth+1 verify +
    accept/rollback) plus emission packing.  Greedy slots emit the
    accepted prefix + bonus; sampling slots force-reject their drafts
    inside ``speculative_step`` and draw from the verify step's
    position-0 logits (the exact Q=1 distribution) with the same
    ``(seed, emit_index)`` key the Q=1 program would fold."""

    def fn(params, state: EngineState):
        TRACE_COUNTS[key] += 1
        live = state.slot_mask
        staged = None if state.staged_ids is None else \
            (state.staged_ids, state.staged_rows, state.staged_scales)
        spec = units.spec(params, state.caches, state.tok, state.hidden,
                          live, state.sample_mask, staged)
        # false branch reuses the verify step's own position-0 argmax
        smp = _maybe_sample(units, state, spec.logits[:, 0],
                            spec.tokens[:, 0])
        tokens = spec.tokens.at[:, 0].set(
            jnp.where(state.sample_mask, smp, spec.tokens[:, 0]))
        n_emit = jnp.where(live,
                           jnp.where(state.sample_mask, 1, spec.n_accepted),
                           0)
        last = jnp.take_along_axis(tokens,
                                   jnp.maximum(n_emit - 1, 0)[:, None],
                                   axis=1)[:, 0]
        upd = {} if staged is None else dict(
            staged_ids=spec.stats["staged_ids"],
            staged_rows=spec.stats["staged_rows"],
            staged_scales=spec.stats.get("staged_scales"))
        new_state = state._replace(
            caches=spec.caches,
            tok=jnp.where(live, last, state.tok),
            hidden=jnp.where(live[:, None], spec.hidden, state.hidden),
            emit_index=state.emit_index + live.astype(jnp.int32),
            **upd)
        ro = RoundOut(jnp.where(live[:, None], tokens, 0), n_emit,
                      h2d_rows=spec.stats["misses"].sum())
        if staged is not None:
            ro = ro._replace(pf_hits=spec.stats["pf_hits"],
                             pf_misses=spec.stats["pf_misses"],
                             pf_wasted=spec.stats["pf_wasted"])
        return new_state, ro

    return fn


def _prefill_round_fn(chunk_core: Callable, units: _Units, last: bool,
                      key: str) -> Callable:
    """One shape-bucketed prefill chunk for a dynamically-indexed slot.
    On the last chunk the first token is selected in-device (greedy or
    sampled at emission index 0) and the slot is promoted inside the
    round: ``tok``/``hidden``/``emit_index``/``slot_mask`` flip so the
    host only fetches the one first-token scalar."""

    def fn(params, state: EngineState, tokens, slot, n_valid):
        TRACE_COUNTS[key] += 1
        if not last:
            caches = chunk_core(params, state.caches, tokens, slot, n_valid)
            return state._replace(caches=caches), jnp.zeros((), jnp.int32)
        lg, caches, hid_last = chunk_core(params, state.caches, tokens,
                                          slot, n_valid)
        state = state._replace(caches=caches)
        lg_last = lg[0, jnp.maximum(n_valid - 1, 0)]                 # [V]
        g = greedy(lg_last)
        smp = units.sample_one(state.seed[slot], state.emit_index[slot],
                               lg_last, state.temperature[slot],
                               state.top_k[slot], state.top_p[slot])
        t0 = jnp.where(state.sample_mask[slot], smp, g)
        state = promote_slot(state, slot, t0, hid_last[0])
        return state, t0

    return fn


def _make_chunk_core(cfg: ArchConfig, use_kernel: bool,
                     last: bool) -> Callable:
    from repro.serving import engine as E

    def core(params, caches, tokens, slot, n_valid):
        C = tokens.shape[1]
        start = jax.lax.dynamic_slice_in_dim(caches.lens, slot, 1)   # [1]
        positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
        lg, caches, _, hid_last = E.ess_prefill_chunk(
            params, cfg, tokens, positions, caches, slot=slot,
            want_logits=last, collect_tail=0, use_kernel=use_kernel,
            n_valid=n_valid)
        if not last:
            return caches
        return lg, caches, hid_last

    return core


class _Variants(NamedTuple):
    jitted: Callable
    eager: Callable


def _variants(fn: Callable, donate: tuple[int, ...]) -> _Variants:
    return _Variants(jax.jit(fn, donate_argnums=donate), fn)


class StepPrograms:
    """The round programs of one ``(cfg, shape family)`` — shared across
    every session with the same key, so executables compile once per
    process.  Each accessor takes ``compiled`` and returns either the
    donated jit program or the identical glue function calling the same
    jitted units (eager mode)."""

    def __init__(self, cfg: ArchConfig, num_slots: int, max_seq: int,
                 use_kernel: bool, tbo: bool, depth: int,
                 prefetch: int = 0):
        self._cfg = cfg
        self._use_kernel = use_kernel
        # the cfg hash disambiguates two configs sharing a shape family
        # (e.g. paged vs dense at the same slots/max_seq) so each
        # program's trace counter stays its own; ``prefetch`` keys the
        # pipelined (async-offload) programs apart from the synchronous
        # ones — the state's slab leaves change the traced structure
        self._sig = (f"B{num_slots}s{max_seq}tbo{int(tbo)}"
                     f"d{depth}k{int(use_kernel)}p{prefetch}"
                     f"c{abs(hash(cfg)) % 16 ** 4:04x}")
        raw = _make_raw_step(cfg, use_kernel, tbo)

        spec_core = None
        if depth > 0:
            def spec_core_fn(params, caches, tok, hidden, slot_mask,
                             sample_mask, staged=None):
                def dec_fn(p_, c_, t_, po_, ca_):
                    return raw(p_, t_, po_, ca_, slot_mask, staged)
                return MTP.speculative_step(
                    dec_fn, params, cfg, caches, tok, hidden,
                    slot_mask=slot_mask, sample_mask=sample_mask,
                    depth=depth)
            spec_core = jax.jit(spec_core_fn)

        self._units = _Units(step=jax.jit(raw), spec=spec_core,
                             maybe_sample=jax.jit(_maybe_sample_fn),
                             sample_one=jax.jit(sample_one))
        self._decode = _variants(
            _decode_round_fn(self._units, f"decode/{self._sig}"), (1,))
        self._spec = _variants(
            _spec_round_fn(self._units, f"spec/{self._sig}"),
            (1,)) if depth > 0 else None
        self._prefill: dict[tuple[int, bool], _Variants] = {}

    def decode(self, compiled: bool) -> Callable:
        return self._decode.jitted if compiled else self._decode.eager

    def spec(self, compiled: bool) -> Callable:
        assert self._spec is not None
        return self._spec.jitted if compiled else self._spec.eager

    def prefill(self, C: int, last: bool, compiled: bool) -> Callable:
        v = self._prefill.get((C, last))
        if v is None:
            core = jax.jit(_make_chunk_core(self._cfg, self._use_kernel,
                                            last))
            v = _variants(
                _prefill_round_fn(core, self._units, last,
                                  f"prefill/C{C}last{int(last)}/{self._sig}"),
                (1,))
            self._prefill[(C, last)] = v
        return v.jitted if compiled else v.eager


@functools.lru_cache(maxsize=64)
def get_programs(cfg: ArchConfig, num_slots: int, max_seq: int,
                 use_kernel: bool, tbo: bool, depth: int,
                 prefetch: int = 0) -> StepPrograms:
    return StepPrograms(cfg, num_slots, max_seq, use_kernel, tbo, depth,
                        prefetch)
