"""Serving engine: prefill + decode step functions.

Two decode paths:

* **generic** — any arch via ``repro.models.transformer.forward`` (contiguous
  caches, monolithic attention).
* **ESS** — DSA+MLA archs with ``cfg.ess.enabled``: unrolled layer loop so
  every layer's host fetch / Attn0 / Attn1 dependence structure stays
  visible to the XLA scheduler (DA/DBA overlap, paper §3.3).  Per layer:

    1. ln1 → new latent entry + indexer key appended (device ikeys;
       host_latent via D2H writeback — Figure 3's small D2H),
    2. ``ess_sparse_attention`` (fetch → Attn0 ∥ copy → Attn1 → exact merge,
       LRU admit),
    3. residual + (dense | MoE) ffn.

Prefill runs the chunked DSA path, scatters the latents to the host tier
(the PD-disaggregation "Load" arrow in Figure 3) and applies LRU-Warmup.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.cache import latent_cache as LC
from repro.configs.base import ArchConfig
from repro.core import lru_pool as LP
from repro.core import offload, warmup
from repro.core.overlap import ESSLayerState, ess_sparse_attention
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import mla as M
from repro.models import moe as MoE
from repro.models import transformer as T


class DecodeOut(NamedTuple):
    logits: jax.Array
    caches: Any
    stats: dict


# ---------------------------------------------------------------------------
# Generic path
# ---------------------------------------------------------------------------

def generic_prefill(params, cfg: ArchConfig, tokens, positions, **kw):
    return T.forward(params, cfg, tokens, positions, mode="prefill", **kw)


def generic_decode(params, cfg: ArchConfig, tokens, positions, caches, **kw):
    out = T.forward(params, cfg, tokens, positions, mode="decode",
                    caches=caches, **kw)
    return DecodeOut(out.logits, out.caches, {})


# ---------------------------------------------------------------------------
# ESS path (DSA + MLA + offload)
# ---------------------------------------------------------------------------

def _layer_params(params, cfg: ArchConfig, layer: int):
    nd = cfg.moe.first_dense_layers if cfg.moe else 0
    if layer < nd:
        return jax.tree.map(lambda a: a[layer], params["dense_layers"]), False
    return jax.tree.map(lambda a: a[layer - nd], params["layers"]), \
        cfg.moe is not None


def _overlap_for_layer(cfg: ArchConfig, layer: int,
                       layerwise: tuple[str, ...] | None) -> str:
    if cfg.ess.overlap == "layerwise":
        if layerwise is not None:
            return layerwise[layer]
        return "da"
    return cfg.ess.overlap


def ess_decode(params, cfg: ArchConfig, tokens, positions,
               caches: LC.ESSCaches, *, use_kernel: bool = False,
               layerwise_policy: tuple[str, ...] | None = None) -> DecodeOut:
    """tokens [B,Q] -> logits [B,Q,V].  Q>1 = MTP draft verification."""
    B, Q = tokens.shape
    x = L.embed(params["embed"], tokens).astype(cfg.param_dtype)
    x = shard(x, "batch", None, "embed_act")
    lens = caches.lens
    new_lens = lens + Q
    bi = jnp.arange(B)[:, None]
    widx = lens[:, None] + jnp.arange(Q)[None, :]                # [B,Q]

    host_latent = caches.host_latent
    ikeys_all = caches.ikeys
    pools = caches.pools
    hits = misses = ovf = jnp.zeros((B,), jnp.int32)

    for layer in range(cfg.num_layers):
        lp, is_moe = _layer_params(params, cfg, layer)
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)

        # --- append: indexer key (device) + latent entry (host, D2H) -----
        new_ik = M.indexer_keys(lp["indexer"], h)                # [B,Q,Di]
        ik_l = ikeys_all[layer].at[bi, widx].set(
            new_ik.astype(ikeys_all[layer].dtype), mode="drop")
        ikeys_all = ikeys_all[:layer] + (ik_l,) + ikeys_all[layer + 1:]
        new_lat = M.latent_entries(lp["mla"], cfg, h, positions) # [B,Q,D]
        host_latent = offload.host_scatter_rows(host_latent, widx, new_lat,
                                                layer=layer)

        # --- ESS sparse attention (fetch ∥ Attn0, Attn1, merge, admit) ---
        st = ESSLayerState(pools[layer], host_latent, layer)
        ov = _overlap_for_layer(cfg, layer, layerwise_policy)
        attn, st2, stats = ess_sparse_attention(
            lp["mla"], lp["indexer"], cfg, h, positions, st, ik_l, new_lens,
            overlap=ov, use_kernel=use_kernel)
        pools = pools[:layer] + (st2.pool,) + pools[layer + 1:]
        x = x + attn

        # --- ffn ----------------------------------------------------------
        h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if is_moe:
            f, _ = MoE.moe_apply(lp["ffn"], cfg, h2)
        else:
            f = L.mlp(lp["ffn"], h2, cfg.act)
        x = x + f
        hits = hits + stats.hits
        misses = misses + stats.misses
        ovf = ovf + stats.overflow

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("unembed", params.get("embed")), x,
                       cap=cfg.logit_softcap)
    new_caches = caches._replace(lens=new_lens, host_latent=host_latent,
                                 ikeys=ikeys_all, pools=pools)
    return DecodeOut(logits, new_caches,
                     {"hits": hits, "misses": misses, "overflow": ovf,
                      "hidden": x})


def ess_prefill(params, cfg: ArchConfig, tokens, positions, max_seq: int,
                *, do_warmup: bool = True, use_kernel: bool = False
                ) -> tuple[jax.Array, LC.ESSCaches]:
    """Prefill + LRU-Warmup (paper §3.2).

    The first ``S - W`` tokens run through the chunked DSA prefill; the
    resulting latents are loaded into the host-tier Total Memory Pool
    (Figure 3's cross-node "Load").  The last ``W = warmup_windows`` tokens
    are then replayed as scanned single-token ESS decode steps: each step
    computes the true indexer Top-2K of its window and LRU-admits the
    misses — *exactly* "sequentially insert the Top-2K IDs of the last W
    prefill windows into the LRU cache"."""
    B, S = tokens.shape
    W = min(cfg.ess.warmup_windows, S - 1) if do_warmup else 0
    Sp = S - W
    out = T.forward(params, cfg, tokens[:, :Sp], positions[:, :Sp],
                    mode="prefill")
    mla_c: Any = out.caches["mla"]                     # latent [L,B,Sp,D]
    caches = LC.init_ess_caches(cfg, B, max_seq, cfg.param_dtype)
    lens = jnp.full((B,), Sp, jnp.int32)

    lat_pad = jnp.pad(mla_c.latent,
                      ((0, 0), (0, 0), (0, max_seq - Sp), (0, 0)))
    ik_pad = jnp.pad(mla_c.ikeys, ((0, 0), (0, 0), (0, max_seq - Sp), (0, 0)))
    host = offload.to_host(lat_pad.astype(caches.host_latent.dtype),
                           None, "batch", None, None) \
        if cfg.ess.offload_kv else lat_pad.astype(caches.host_latent.dtype)
    ik_dtype = caches.ikeys[0].dtype
    caches = caches._replace(
        lens=lens, host_latent=host,
        ikeys=tuple(ik_pad[l].astype(ik_dtype)
                    for l in range(cfg.num_layers)))
    logits = out.logits

    if W > 0:
        # warmup replays run on the prefill side (bandwidth-rich): use the
        # exact miss envelope (M = K) so outputs match the monolithic model
        # bit-for-bit; the steady-state decode envelope stays provisioned
        # at cfg.ess.max_miss_ratio.
        import dataclasses
        cfg_x = dataclasses.replace(
            cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))

        def step(c, tw):
            tok, pos = tw                                  # [B], [B]
            o = ess_decode(params, cfg_x, tok[:, None], pos[:, None], c,
                           use_kernel=use_kernel)
            return o.caches, o.logits[:, 0]

        toks_w = tokens[:, Sp:].T                          # [W, B]
        pos_w = positions[:, Sp:].T
        caches, lg = jax.lax.scan(step, caches, (toks_w, pos_w))
        logits = jnp.concatenate([logits, lg.transpose(1, 0, 2)], axis=1)
    return logits, caches
