"""Serving engine: prefill + decode step functions.

Two decode paths:

* **generic** — any arch via ``repro.models.transformer.forward`` (contiguous
  caches, monolithic attention).
* **ESS** — DSA+MLA archs with ``cfg.ess.enabled``: unrolled layer loop so
  every layer's host fetch / Attn0 / Attn1 dependence structure stays
  visible to the XLA scheduler (DA/DBA overlap, paper §3.3).  Per layer:

    1. ln1 → new latent entry + indexer key appended (device ikeys;
       host_latent via D2H writeback — Figure 3's small D2H),
    2. ``ess_sparse_attention`` (fetch → Attn0 ∥ copy → Attn1 → exact merge,
       LRU admit),
    3. residual + (dense | MoE) ffn.

Prefill runs the chunked DSA path, scatters the latents to the host tier
(the PD-disaggregation "Load" arrow in Figure 3) and applies LRU-Warmup.

The serving stack is split across four modules:

* this one — the model step functions (``ess_decode`` /
  ``ess_prefill_chunk``) and the host-side :class:`ServeSession`
  **re-entrant engine core**: ``step_round()`` runs exactly one serve
  round (admissions → one prefill chunk → one decode/verify step) and
  returns the round's :class:`~repro.serving.api.TokenEvent` batch —
  requests can be submitted and aborted between any two rounds, EOS /
  stop tokens truncate *within* a speculative round (the over-accepted
  suffix's lens/pool state is rolled back), and every request ends with
  exactly one terminal event (``stop | length | abort | rejected |
  budget``).  ``run()`` survives as a thin run-to-completion compat
  shim over the same core, with bit-identical streams;
* :mod:`repro.serving.api` — the public front-end (``EssEngine`` with
  ``submit`` / ``step`` / ``stream`` / ``generate`` / ``abort`` /
  ``metrics``, ``SamplingParams``, ``TokenEvent``, ``RequestOutput``);
* :mod:`repro.serving.state` — the device-resident ``EngineState``
  pytree a round consumes and produces;
* :mod:`repro.serving.step` — the ``StepProgram`` builder that compiles
  each round kind (decode / MTP draft+verify / prefill chunk) into one
  donated jit program with in-device token selection.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.cache import latent_cache as LC
from repro.configs.base import ArchConfig
from repro.core import lru_pool as LP
from repro.core import offload, warmup
from repro.core import transfer as TR
from repro.core.overlap import (ESSLayerState, _attend_rows,
                                ess_sparse_attention,
                                ess_sparse_attention_staged)
from repro.distributed import compression as cmp
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import mla as M
from repro.models import moe as MoE
from repro.models import transformer as T
from repro.serving import state as ES
from repro.serving import step as SP
from repro.serving.api import TokenEvent
from repro.serving.sampling import greedy, request_key, sample
from repro.serving.scheduler import Request, Scheduler


class DecodeOut(NamedTuple):
    logits: jax.Array
    caches: Any
    stats: dict


# ---------------------------------------------------------------------------
# Generic path
# ---------------------------------------------------------------------------

def generic_prefill(params, cfg: ArchConfig, tokens, positions, **kw):
    return T.forward(params, cfg, tokens, positions, mode="prefill", **kw)


def generic_decode(params, cfg: ArchConfig, tokens, positions, caches, **kw):
    out = T.forward(params, cfg, tokens, positions, mode="decode",
                    caches=caches, **kw)
    return DecodeOut(out.logits, out.caches, {})


# ---------------------------------------------------------------------------
# ESS path (DSA + MLA + offload)
# ---------------------------------------------------------------------------

def _layer_params(params, cfg: ArchConfig, layer: int):
    nd = cfg.moe.first_dense_layers if cfg.moe else 0
    if layer < nd:
        return jax.tree.map(lambda a: a[layer], params["dense_layers"]), False
    return jax.tree.map(lambda a: a[layer - nd], params["layers"]), \
        cfg.moe is not None


def _overlap_for_layer(cfg: ArchConfig, layer: int,
                       layerwise: tuple[str, ...] | None) -> str:
    if cfg.ess.overlap == "layerwise":
        if layerwise is not None:
            return layerwise[layer]
        return "da"
    return cfg.ess.overlap


def ess_decode(params, cfg: ArchConfig, tokens, positions,
               caches: LC.ESSCaches, *, use_kernel: bool = False,
               layerwise_policy: tuple[str, ...] | None = None,
               slot_mask: jax.Array | None = None,
               staged: tuple[jax.Array, jax.Array] | None = None
               ) -> DecodeOut:
    """tokens [B,Q] -> logits [B,Q,V].  Q>1 = MTP draft verification.

    ``slot_mask`` [B] bool marks the live decode slots of a continuous
    batch.  Masked slots are gated *inside* the step: their host scatter
    and indexer-cache append are dropped, their pool takes no lookups or
    admissions, and their ``lens`` do not advance.  Without in-step gating
    a freed (or still-prefilling) slot runs a phantom step — its stale
    block table can alias a live slot's physical host page and its pool
    silently admits a garbage latent row that a future occupant then
    *hits* on.

    ``staged`` switches the step into the **pipelined** round shape
    (plan → compute → commit, the async-offload tentpole): it carries the
    previous round's staging slab pair ``(staged_ids [L,B,P],
    staged_rows [L,B,P,D])``.  The compute stage then sources miss rows
    from the slab (:func:`repro.core.overlap.ess_sparse_attention_staged`
    — own-round bypass, slab match, cond-gated sync fallback), the
    per-layer D2H spill of new latents is deferred into **one** stacked
    commit-stage scatter after the layer loop, and the plan stage gathers
    next round's predicted rows into a fresh slab *after* that commit (so
    the predictions may include this round's appends).  The stats dict
    gains ``staged_ids`` / ``staged_rows`` (the next slab) and
    ``pf_hits`` / ``pf_misses`` / ``pf_wasted`` ``[B]`` prefetch
    counters.  ``staged=None`` is the synchronous path, bit-identical to
    the pre-pipeline graph.
    """
    B, Q = tokens.shape
    x = L.embed(params["embed"], tokens).astype(cfg.param_dtype)
    x = shard(x, "batch", None, "embed_act")
    lens = caches.lens
    if slot_mask is None:
        live = jnp.ones((B,), bool)
    else:
        live = slot_mask
    new_lens = lens + Q * live.astype(lens.dtype)
    bi = jnp.arange(B)[:, None]
    widx = jnp.where(live[:, None],
                     lens[:, None] + jnp.arange(Q)[None, :], -1)  # [B,Q]
    # per-query attention horizon: draft q sees positions <= its own (the
    # Q window stays causal — without this every draft would attend to
    # entries appended by later drafts, breaking parity with sequential
    # Q=1 steps); masked slots contribute no valid entries at all
    attn_lens = widx + 1                                          # [B,Q]

    host_latent = caches.host_latent
    host_scales = caches.host_scales   # per-row scales of a quantized tier
    ikeys_all = caches.ikeys
    pools = caches.pools
    hits = misses = ovf = jnp.zeros((B,), jnp.int32)
    lat_stack: list[jax.Array] = []    # staged mode: deferred D2H spill
    scale_stack: list[jax.Array] = []  # staged+quantized: the rows' scales
    plan_sigs: list[tuple] = []        # staged mode: per-layer plan signal
    pf_h = pf_m = pf_w = jnp.zeros((B,), jnp.int32)

    for layer in range(cfg.num_layers):
        lp, is_moe = _layer_params(params, cfg, layer)
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)

        # --- append: indexer key (device) + latent entry (host, D2H) -----
        new_ik = M.indexer_keys(lp["indexer"], h)                # [B,Q,Di]
        S_ik = ikeys_all[layer].shape[1]
        ik_widx = jnp.where(widx >= 0, widx, S_ik)               # OOB -> drop
        ik_l = ikeys_all[layer].at[bi, ik_widx].set(
            new_ik.astype(ikeys_all[layer].dtype), mode="drop")
        ikeys_all = ikeys_all[:layer] + (ik_l,) + ikeys_all[layer + 1:]
        new_lat = M.latent_entries(lp["mla"], cfg, h, positions) # [B,Q,D]
        if staged is None:
            # masked slots' gating is already folded into widx (-1 drops)
            host_latent, host_scales = offload.scatter_tier_rows(
                host_latent, host_scales, widx, new_lat, slot_mask=None,
                layer=layer, block_table=caches.block_tables)
        elif host_scales is None:
            # pipelined: spill deferred to the commit stage (one stacked
            # scatter after the loop); keep the host-dtype rows at hand so
            # same-round misses are served from the live activations
            lat_stack.append(new_lat.astype(host_latent.dtype))
            own_rows = lat_stack[-1]
        else:
            # pipelined + quantized: quantize ONCE here and commit the
            # exact (q, s) pair later — the own-row bypass serves
            # dequant(q, s), which is bit-identical to the synchronous
            # scatter→gather round trip (re-quantizing dequantized rows
            # would land on a different grid point)
            q_lat, s_lat = cmp.quantize_rows(new_lat, host_latent.dtype)
            lat_stack.append(q_lat)
            scale_stack.append(s_lat)
            own_rows = cmp.dequantize_rows(q_lat, s_lat, cfg.param_dtype)

        # --- ESS sparse attention (fetch ∥ Attn0, Attn1, merge, admit) ---
        st = ESSLayerState(pools[layer], host_latent, layer,
                           block_table=caches.block_tables,
                           host_scales=host_scales)
        ov = _overlap_for_layer(cfg, layer, layerwise_policy)
        if staged is None:
            attn, st2, stats = ess_sparse_attention(
                lp["mla"], lp["indexer"], cfg, h, positions, st, ik_l,
                attn_lens, overlap=ov, use_kernel=use_kernel,
                slot_mask=live)
        else:
            sc_l = None if len(staged) < 3 or staged[2] is None \
                else staged[2][layer]
            attn, st2, stats, sig, pf = ess_sparse_attention_staged(
                lp["mla"], lp["indexer"], cfg, h, positions, st, ik_l,
                attn_lens, new_rows=own_rows, widx=widx,
                staged_ids_l=staged[0][layer],
                staged_rows_l=staged[1][layer],
                staged_scales_l=sc_l, overlap=ov,
                use_kernel=use_kernel, slot_mask=live)
            plan_sigs.append(sig)
            pf_h, pf_m = pf_h + pf[0], pf_m + pf[1]
        pools = pools[:layer] + (st2.pool,) + pools[layer + 1:]
        x = x + attn

        # --- ffn ----------------------------------------------------------
        h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if is_moe:
            f, _ = MoE.moe_apply(lp["ffn"], cfg, h2)
        else:
            f = L.mlp(lp["ffn"], h2, cfg.act)
        x = x + f
        hits = hits + stats.hits
        misses = misses + stats.misses
        ovf = ovf + stats.overflow

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("unembed", params.get("embed")), x,
                       cap=cfg.logit_softcap)
    stats_out = {"hits": hits, "misses": misses, "overflow": ovf,
                 "hidden": x}
    if staged is not None:
        # --- commit stage: one stacked D2H spill of the round's appends
        # (quantized tier: the layer loop's precomputed (q, s) pairs land
        # verbatim — payload and scale plane in one stacked scatter each,
        # so the PCIe bytes stay at compressed width) -----------------
        host_latent = offload.scatter_from_slab(
            host_latent, widx, jnp.stack(lat_stack), slot_mask=None,
            block_table=caches.block_tables)
        if host_scales is not None:
            host_scales = offload.scatter_from_slab(
                host_scales, widx, jnp.stack(scale_stack), slot_mask=None,
                block_table=caches.block_tables)
        # --- plan stage: stage next round's predicted rows (after the
        # commit, so predictions may target rows appended this round).
        # The whole plan is gated on the round having *missed at all*: a
        # zero-miss round proves residency covered the working set, so
        # the freshest plan is the one already staged — the slab passes
        # through untouched and the steady-state round pays one skipped
        # cond instead of a top-k + gather.  Rounds that did miss rank
        # the per-layer signals in one batched top-k rather than L
        # separate ones ------------------------------------------------
        Lh, P = staged[0].shape[0], staged[0].shape[2]
        st_scales = staged[2] if len(staged) > 2 else None

        def _plan():
            sc_all = jnp.stack([s[0] for s in plan_sigs])         # [L,B,S]
            so_all = jnp.stack([s[2] for s in plan_sigs])         # [L,B,S]
            pred = TR.plan_prefetch(
                sc_all.reshape(Lh * B, -1), jnp.tile(plan_sigs[0][1], Lh),
                so_all.reshape(Lh * B, -1), jnp.tile(live, Lh),
                cfg.dsa.index_topk, P).reshape(Lh, B, P)          # [L,B,P]
            # rows already staged last round are reused in place:
            # committed host rows are append-only below the truncation
            # edges, and every truncation edge cancels the staged ids it
            # invalidates, so a surviving id's bytes cannot have changed.
            # Only genuinely new ids touch the link — a plan that
            # re-predicts a stable margin skips the H2D gather entirely.
            # A quantized tier's scale plane shadows the rows exactly
            # (same reuse select, same slab gather at one byte-pair per
            # row extra) so the staged pair always dequantizes
            # coherently.
            old_ids, old_rows = staged[0], staged[1]
            eq = (pred[..., None] == old_ids[..., None, :]) \
                & (old_ids >= 0)[..., None, :] & (pred >= 0)[..., None]
            have = eq.any(-1)                                     # [L,B,P]
            src = jnp.argmax(eq, axis=-1)
            reused = jnp.take_along_axis(old_rows, src[..., None], axis=2)
            new_ids = jnp.where(have, -1, pred)

            def _gather():
                rows = offload.gather_into_slab(
                    host_latent, new_ids, slot_mask=None,
                    block_table=caches.block_tables)
                if st_scales is None:
                    return (rows,)
                return rows, offload.gather_into_slab(
                    host_scales, new_ids, slot_mask=None,
                    block_table=caches.block_tables)

            def _zeros():
                if st_scales is None:
                    return (jnp.zeros_like(old_rows),)
                return jnp.zeros_like(old_rows), jnp.zeros_like(st_scales)

            fresh = jax.lax.cond(jnp.any(new_ids >= 0), _gather, _zeros)
            rows_out = jnp.where(have[..., None], reused, fresh[0])
            if st_scales is None:
                return pred, rows_out
            reused_s = jnp.take_along_axis(st_scales, src[..., None],
                                           axis=2)
            return pred, rows_out, jnp.where(have[..., None], reused_s,
                                             fresh[1])

        keep = (lambda: (staged[0], staged[1])) if st_scales is None \
            else (lambda: (staged[0], staged[1], st_scales))
        plan_out = jax.lax.cond(jnp.any(misses > 0), _plan, keep)
        pred, slab_rows = plan_out[0], plan_out[1]
        pf_w = ((staged[0] >= 0).sum((0, 2)).astype(jnp.int32)
                * live.astype(jnp.int32) - pf_h)
        stats_out.update(staged_ids=pred, staged_rows=slab_rows,
                         pf_hits=pf_h, pf_misses=pf_m, pf_wasted=pf_w)
        if st_scales is not None:
            stats_out["staged_scales"] = plan_out[2]
    new_caches = caches._replace(lens=new_lens, host_latent=host_latent,
                                 host_scales=host_scales,
                                 ikeys=ikeys_all, pools=pools)
    return DecodeOut(logits, new_caches, stats_out)


def ess_prefill_chunk(params, cfg: ArchConfig, tokens, positions,
                      caches: LC.ESSCaches, *, slot=None,
                      want_logits: bool = True, collect_tail: int = 0,
                      use_kernel: bool = False,
                      n_valid: jax.Array | int | None = None
                      ) -> tuple[Optional[jax.Array], LC.ESSCaches, tuple,
                                 Optional[jax.Array]]:
    """One chunked-prefill step: ``tokens [B,C]`` continue the sequence(s)
    at ``caches.lens`` and their latents/indexer keys land **directly in
    the already-mapped host pages** — no donor cache, no graft.

    * ``slot`` restricts the step to one decode slot of a shared
      continuous-batching cache (``None`` = all ``B`` rows, the compat
      :func:`ess_prefill` path).  It may be a traced i32 scalar: the
      compiled serve round passes the admitting slot dynamically so one
      program covers every slot.
    * ``n_valid`` (scalar, may be traced) marks the first ``n_valid``
      chunk positions as real; the rest are padding that a shape-bucketed
      ragged final chunk carries.  Pad positions write nothing (host
      scatter and indexer-cache appends dropped, ``lens`` advance by
      ``n_valid``), are never attended by valid queries (their ``widx``
      is ``-1``, so the causal mask excludes them), and their own
      outputs are finite garbage that is discarded.  Because pad tokens
      sit *after* every valid token, the MoE capacity cumsum assigns
      valid tokens the same expert slots as an unpadded run — valid
      positions are bit-identical to the unpadded chunk (as long as no
      token hits the capacity clip, the same assumption the chunked ==
      one-shot parity already rests on).
    * Attention is the exact causal DSA selection: per-query Top-K over the
      slot's indexer cache, prior-context rows fetched from the host tier,
      intra-chunk rows served from the chunk itself (they are D2H'd once,
      *after* the layer loop, via one stacked scatter per chunk).
    * The Sparse Memory Pool is untouched — prefill runs on the
      bandwidth-rich side of the PD split; LRU-Warmup is replayed
      separately after the last chunk.
    * Per-token outputs are invariant to the chunking (fixed-shape score /
      gather / attend stages), so any ``prefill_chunk`` is bit-identical
      to the one-shot path.

    Returns ``(logits|None, caches, tails, hidden_last)`` where ``tails``
    holds each layer's post-ln1 hidden states for the last
    ``collect_tail`` chunk positions (LRU-Warmup replay input) and
    ``hidden_last`` is the post-final-norm hidden at the chunk's last
    position (``None`` unless ``want_logits`` — the MTP draft seed when
    a slot promotes from prefill to speculative decode).
    """
    if slot is None:
        b0, Bc = 0, tokens.shape[0]
    else:
        b0, Bc = slot, 1
    C = tokens.shape[1]
    start = jax.lax.dynamic_slice_in_dim(caches.lens, b0, Bc)    # [Bc]
    x = L.embed(params["embed"], tokens).astype(cfg.param_dtype)
    x = shard(x, "batch", None, "embed_act")
    bi = jnp.arange(Bc)[:, None]
    nv = jnp.asarray(C if n_valid is None else n_valid, jnp.int32)
    cpos = jnp.arange(C, dtype=jnp.int32)
    widx = jnp.where(cpos[None, :] < nv,
                     start[:, None] + cpos[None, :], -1)         # [Bc,C]

    host = caches.host_latent
    ikeys_all = caches.ikeys
    S = ikeys_all[0].shape[1]
    K = min(cfg.dsa.index_topk, S)
    causal = jnp.arange(S)[None, None, :] <= widx[:, :, None]    # [Bc,C,S]
    lat_stack = []
    scale_stack = []           # quantized tier: the chunk rows' scales
    tails = []

    for layer in range(cfg.num_layers):
        lp, is_moe = _layer_params(params, cfg, layer)
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        if collect_tail:
            tails.append(h[:, -collect_tail:])

        # --- append indexer keys (device) + chunk latents (deferred D2H) --
        ik_full = ikeys_all[layer]
        ik_slot = jax.lax.dynamic_slice_in_dim(ik_full, b0, Bc, axis=0)
        new_ik = M.indexer_keys(lp["indexer"], h)                # [Bc,C,Di]
        ik_slot = ik_slot.at[bi, jnp.where(widx >= 0, widx, S)].set(
            new_ik.astype(ik_slot.dtype), mode="drop")
        ik_full = jax.lax.dynamic_update_slice_in_dim(ik_full, ik_slot, b0,
                                                      axis=0)
        ikeys_all = ikeys_all[:layer] + (ik_full,) + ikeys_all[layer + 1:]
        new_lat = M.latent_entries(lp["mla"], cfg, h, positions)  # [Bc,C,D]
        if caches.host_scales is None:
            new_lat = new_lat.astype(host.dtype)
            lat_stack.append(new_lat)
        else:
            # quantize ONCE: the (q, s) pair is what the deferred stacked
            # scatter commits, and intra-chunk attention serves
            # dequant(q, s) — the same value any *cross*-chunk query
            # reads back from the tier, so chunked == one-shot parity
            # survives quantization
            q_lat, s_lat = cmp.quantize_rows(new_lat, host.dtype)
            lat_stack.append(q_lat)
            scale_stack.append(s_lat)
            new_lat = cmp.dequantize_rows(q_lat, s_lat, cfg.param_dtype)

        # --- exact causal DSA: per-query Top-K over the slot's keys ------
        iq = M.indexer_query(lp["indexer"], h)
        sc = M.indexer_scores(iq, ik_slot)                       # [Bc,C,S]
        ids = M.topk_ids(sc, K, causal)                          # [Bc,C,K]
        req_valid = jnp.take_along_axis(
            jnp.broadcast_to(causal, (Bc, C, S)), ids, axis=2)
        # prior context from host pages; intra-chunk rows from the chunk
        local = ids >= start[:, None, None]
        prior_ids = jnp.where(local, -1, ids)
        rows_h = offload.gather_tier_rows(
            host, caches.host_scales, prior_ids.reshape(Bc, C * K),
            layer=layer, batch_offset=b0, block_table=caches.block_tables,
            out_dtype=new_lat.dtype).reshape(Bc, C, K, -1)
        loc = jnp.clip(ids - start[:, None, None], 0, C - 1)
        rows_l = jnp.take_along_axis(new_lat[:, None], loc[..., None],
                                     axis=2)                     # [Bc,C,K,D]
        rows = jnp.where(local[..., None], rows_l, rows_h)

        q_comb = M.absorbed_query(lp["mla"], cfg, h, positions)
        # fp32 attend (prefill runs on the compute-rich side): matches the
        # monolithic prefill/train references' softmax precision, so the
        # selection sets of deeper layers don't drift across near-ties
        part = _attend_rows(q_comb.astype(jnp.float32),
                            rows.astype(jnp.float32), req_valid, cfg,
                            use_kernel=use_kernel)
        attn = M.output_proj(lp["mla"], cfg,
                             M.finalize_partial(part, x.dtype))
        x = x + attn

        # --- ffn ----------------------------------------------------------
        h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if is_moe:
            f, _ = MoE.moe_apply(lp["ffn"], cfg, h2)
        else:
            f = L.mlp(lp["ffn"], h2, cfg.act)
        x = x + f

    # one stacked D2H scatter for the whole chunk (all layers, same rows;
    # pad rows carry widx == -1 and are dropped).  Quantized tier: payload
    # and scale plane each take one stacked scatter of the precomputed
    # (q, s) pairs — compressed D2H width
    host = offload.host_scatter_rows_stacked(
        host, widx, jnp.stack(lat_stack), slot_mask=None, batch_offset=b0,
        block_table=caches.block_tables)
    host_scales = caches.host_scales
    if host_scales is not None:
        host_scales = offload.host_scatter_rows_stacked(
            host_scales, widx, jnp.stack(scale_stack), slot_mask=None,
            batch_offset=b0, block_table=caches.block_tables)
    new_lens = jax.lax.dynamic_update_slice(
        caches.lens, start + nv, (b0,))
    logits = None
    hidden_last = None
    if want_logits:
        xf = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed(params.get("unembed", params.get("embed")), xf,
                           cap=cfg.logit_softcap)
        hidden_last = xf[:, jnp.maximum(nv - 1, 0)]          # [Bc, d]
    caches = caches._replace(lens=new_lens, host_latent=host,
                             host_scales=host_scales, ikeys=ikeys_all)
    return logits, caches, tuple(tails), hidden_last


def ess_prefill(params, cfg: ArchConfig, tokens, positions, max_seq: int,
                *, do_warmup: bool = True, use_kernel: bool = False,
                prefill_chunk: Optional[int] = None
                ) -> tuple[jax.Array, LC.ESSCaches]:
    """Prefill + LRU-Warmup (paper §3.2) — compat shim over the chunked
    prefill engine.

    The first ``S - W`` tokens stream through :func:`ess_prefill_chunk`
    (one chunk by default, ``prefill_chunk``-sized chunks otherwise —
    bit-identical either way); their latents land in the host-tier Total
    Memory Pool (Figure 3's cross-node "Load").  The last
    ``W = warmup_windows`` tokens are then replayed as scanned
    single-token ESS decode steps: each step computes the true indexer
    Top-2K of its window and LRU-admits the misses — *exactly*
    "sequentially insert the Top-2K IDs of the last W prefill windows
    into the LRU cache"."""
    B, S = tokens.shape
    W = min(cfg.ess.warmup_windows, S - 1) if do_warmup else 0
    Sp = S - W
    caches = LC.init_ess_caches(cfg, B, max_seq, cfg.param_dtype)
    # cap the default chunk: a single Sp-sized chunk materializes
    # O(Sp*K*D) gathered rows + O(Sp*S) score tensors, and chunking is
    # bit-identical anyway
    C = min(Sp, 512) if prefill_chunk is None else max(1, prefill_chunk)
    parts = []
    for c0 in range(0, Sp, C):
        ck = min(C, Sp - c0)
        lg, caches, _, _ = ess_prefill_chunk(
            params, cfg, tokens[:, c0:c0 + ck], positions[:, c0:c0 + ck],
            caches, use_kernel=use_kernel, n_valid=None)
        parts.append(lg)
    logits = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)

    if W > 0:
        # warmup replays run on the prefill side (bandwidth-rich): use the
        # exact miss envelope (M = K) so outputs match the monolithic model
        # bit-for-bit; the steady-state decode envelope stays provisioned
        # at cfg.ess.max_miss_ratio.
        import dataclasses
        cfg_x = dataclasses.replace(
            cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))

        def step(c, tw):
            tok, pos = tw                                  # [B], [B]
            o = ess_decode(params, cfg_x, tok[:, None], pos[:, None], c,
                           use_kernel=use_kernel, slot_mask=None)
            return o.caches, o.logits[:, 0]

        toks_w = tokens[:, Sp:].T                          # [W, B]
        pos_w = positions[:, Sp:].T
        caches, lg = jax.lax.scan(step, caches, (toks_w, pos_w))
        logits = jnp.concatenate([logits, lg.transpose(1, 0, 2)], axis=1)
    return logits, caches


# ---------------------------------------------------------------------------
# Continuous-batching serve loop (scheduler + paged host tier)
# ---------------------------------------------------------------------------

# depth of the round pipeline: a freshly promoted slot needs this many
# decode rounds before its slab/working set reach steady state (round N
# computes against rows staged in round N-1, which planned off round
# N-2's scores)
PIPELINE_FILL_ROUNDS = 2


@dataclasses.dataclass
class ServeReport:
    rounds: int = 0                     # decode rounds actually stepped
    decode_tokens: int = 0              # tokens emitted by active slots
    prefill_chunks: int = 0             # chunked-prefill steps run
    prefill_tokens: int = 0             # prompt tokens prefilled
    wall_s: float = 0.0
    # wall time spent inside decode rounds only (plan stage -> commit
    # stage of rounds that actually stepped a program).  `rounds_per_s`
    # uses it so admission-only / prefill-only rounds — the pipeline's
    # fill and drain — don't dilute the decode cadence.
    decode_wall_s: float = 0.0
    # decode rounds inside a slot's pipeline-fill window (its first
    # PIPELINE_FILL_ROUNDS rounds after promotion: the slab is empty and
    # the working set cold).  Counted in `rounds` but excluded — numerator
    # *and* denominator — from `rounds_per_s`, identically in sync and
    # overlapped modes, so the cadence compares steady-state rounds only
    # instead of double-counting the pipeline's fill/drain.
    fill_rounds: int = 0
    # async-offload prefetch accounting (summed over layers and slots):
    # staged rows that served misses / misses that fell back to the
    # synchronous gather / staged rows nobody requested
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    prefetch_wasted_rows: int = 0
    # PCIe traffic accounting in *rows*, converted to bytes with the
    # dtype-exact row width (payload + per-row scale for a quantized
    # tier) — the compressed-transfer win shows up here as ~0.5x bytes
    # at identical row counts
    h2d_rows: int = 0                   # miss rows served from the host tier
    d2h_rows: int = 0                   # latent rows written back (all layers)
    host_bytes_per_row: int = 0         # dtype-exact bytes/row of the tier
    finished_rids: list = dataclasses.field(default_factory=list)
    admissions_blocked: int = 0         # admit attempts gated on resources
    peak_pages_in_use: int = 0          # sampled every serve round
    num_pages: int = 0
    ttft_rounds: dict = dataclasses.field(default_factory=dict)
    ttft_s: dict = dataclasses.field(default_factory=dict)
    events: list = dataclasses.field(default_factory=list)
    # MTP speculative accounting.  With mtp_depth > 0 each round emits a
    # *variable* 1..depth+1 tokens per live slot (accepted drafts + the
    # bonus token), so decode_tokens counts **accepted** tokens —
    # `tokens_per_s` is accepted-tokens/s, while `rounds_per_s` tracks
    # verify-step cadence; the two are equal only at Q=1.
    spec_rounds: int = 0                # rounds run as draft+verify
    drafted_tokens: int = 0             # greedy-slot drafts scored
    accepted_tokens: int = 0            # drafts accepted (excl. bonus)
    # request-lifecycle accounting (public serving API)
    rejected: int = 0                   # oversize/unservable requests
    aborted: int = 0                    # client aborts + budget kills
    finish_reasons: dict = dataclasses.field(default_factory=dict)

    @property
    def tokens_per_s(self) -> float:
        return self.decode_tokens / self.wall_s if self.wall_s > 0 else 0.0

    # alias making the MTP semantics explicit at call sites
    accepted_tokens_per_s = tokens_per_s

    @property
    def rounds_per_s(self) -> float:
        denom = self.decode_wall_s if self.decode_wall_s > 0 else self.wall_s
        return (self.rounds - self.fill_rounds) / denom if denom > 0 else 0.0

    @property
    def prefetch_hit_rate(self) -> float:
        """Staged-row hits / miss-buffer entries needing host rows."""
        tot = self.prefetch_hits + self.prefetch_misses
        return self.prefetch_hits / tot if tot else 0.0

    @property
    def h2d_bytes(self) -> int:
        return self.h2d_rows * self.host_bytes_per_row

    @property
    def d2h_bytes(self) -> int:
        return self.d2h_rows * self.host_bytes_per_row

    @property
    def transfer_bytes_per_round(self) -> float:
        """Mean H2D + D2H bytes per decode round (dtype-exact rows)."""
        return (self.h2d_bytes + self.d2h_bytes) / self.rounds \
            if self.rounds else 0.0

    @property
    def accept_rate(self) -> float:
        """Accepted drafts / drafted tokens (greedy speculative slots)."""
        return self.accepted_tokens / self.drafted_tokens \
            if self.drafted_tokens else 0.0

    @property
    def mean_ttft_s(self) -> float:
        vals = list(self.ttft_s.values())
        return sum(vals) / len(vals) if vals else 0.0


class _RoundPlan(NamedTuple):
    """Output of the round pipeline's plan stage (host-side half)."""
    active: list            # slots stepping this round
    pending: list           # (slot, req, t0_dev) deferred first tokens
    spec: bool              # MTP draft+verify round?
    t0: float               # plan-stage entry time (decode_wall_s)


@dataclasses.dataclass
class _PrefillTask:
    """Chunk cursor of one admitting slot (engine-side prefill state)."""
    req: Request
    tokens: jax.Array        # [1, prompt_len]
    cursor: int = 0
    # rolling per-layer post-ln1 tails of the last `warmup_windows` prompt
    # positions (accumulated across chunks so warmup depth never depends
    # on prompt_len % prefill_chunk)
    tails: Optional[list] = None


class ServeSession:
    """One long-lived ESS decode batch driven by the continuous-batching
    scheduler.

    * ``num_slots`` decode slots share one jit-shaped batch; more requests
      than slots stream through as slots free up.
    * Prefill is **chunked and interleaved**: each serve round runs one
      ``prefill_chunk``-token chunk for at most one admitting slot plus one
      decode step for all running slots.  Chunk latents scatter straight
      into the slot's mapped host pages (no max_seq-sized donor cache, no
      graft), so admitting a long prompt never stalls the decode batch —
      it costs one chunk per round.
    * With the paged host tier, admission is gated on **free host pages**
      (``pages = ceil((prompt + max_new) / page_rows)`` per request) and
      free Sparse-Memory-Pool entries; ``num_host_pages`` can be provisioned
      *below* ``num_slots × blocks_per_slot`` — the dense layout's pin — to
      exercise the gate.
    * A finished or preempted slot returns its pages to the allocator and
      gets a full per-slot cache reset (``reset_slot``: lens + pool maps),
      so a recycled slot can never take pool hits on the previous
      occupant's latents.  Decode steps gate inactive slots *in-step*
      (``slot_mask``), so a freed or mid-prefill slot can never scatter a
      phantom latent row or pollute its pool between admissions.
    * ``mtp_depth > 0`` runs each decode round as an **MTP speculative
      round** over the live batch: draft ``mtp_depth`` tokens per slot
      from the carried backbone hidden (``mtp_draft``), verify all drafts
      with one ``ess_decode`` call at ``Q = depth+1``, emit the accepted
      prefix + bonus token, and roll back lens/pools for rejected drafts
      (frozen slots gated — see ``speculative_step``).  Greedy output is
      bit-identical to the Q=1 baseline; sampling requests degrade to
      exact Q=1 emission inside the round.
    * ``tbo=True`` composes Two-Batch Overlap: every decode/verify step
      splits the batch into two half-batches (``split_caches``), steps
      them as independent programs so half-A's H2D pool fetches overlap
      half-B's compute, and reconciles the shared paged host tier by page
      ownership (``merge_caches``).
    * ``compiled=True`` (the default) runs every round as a **donated
      jitted StepProgram** (:mod:`repro.serving.step`) over the
      device-resident :class:`~repro.serving.state.EngineState`: token
      selection (greedy *and* per-slot temperature/top-k/top-p sampling)
      happens in-device and the host fetches exactly one packed
      ``(tokens [B,Q], n_emit [B])`` struct per decode round.  Host code
      keeps only scheduler bookkeeping, page allocation and stream
      emission.  ``compiled=False`` (the debugging path) executes the
      *same* round functions with the glue op-by-op but the same jitted
      floating-point units (model step, speculative core, samplers), so
      both modes emit bit-identical streams — see
      :mod:`repro.serving.step`.  ``do_warmup=True`` prefill chunks take
      the legacy eager path (the LRU-warmup replay is host-driven);
      decode rounds still compile.
    """

    def __init__(self, params, cfg: ArchConfig, *, num_slots: int,
                 max_seq: int, num_host_pages: Optional[int] = None,
                 host_byte_budget: Optional[int] = None,
                 prompt_fn: Optional[Callable[[Request], jax.Array]] = None,
                 do_warmup: bool = False, use_kernel: bool = False,
                 prefill_chunk: int = 64, mtp_depth: int = 0,
                 tbo: bool = False, compiled: bool = True,
                 overlap: bool = False,
                 prefetch_rows: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.do_warmup = do_warmup
        self.use_kernel = use_kernel
        self.compiled = compiled
        self.prefill_chunk = max(1, prefill_chunk)
        if mtp_depth > 0 and mtp_depth > cfg.mtp_depth:
            raise ValueError(f"mtp_depth {mtp_depth} > cfg.mtp_depth "
                             f"{cfg.mtp_depth} stacked draft modules")
        self.mtp_depth = max(0, mtp_depth)
        self.tbo = tbo and num_slots >= 2
        # async-offload pipeline: size the staging slab to the steady
        # -state miss envelope (the same max_miss_ratio * K bound the
        # lookup provisions) unless the caller pins it explicitly
        self.overlap = overlap
        if overlap:
            self.prefetch_rows = prefetch_rows if prefetch_rows is not None \
                else max(1, int(cfg.ess.max_miss_ratio
                                * min(cfg.dsa.index_topk, max_seq)))
        else:
            self.prefetch_rows = 0
        self.paged = LC.uses_paged_host(cfg)
        blocks_per_slot = LC.num_blocks(cfg, max_seq) if cfg.ess.enabled \
            else 0
        self.num_pages = 0
        self.allocator: Optional[LC.HostPageAllocator] = None
        # dtype-exact tier widths: admission reasons in BYTES, so a fixed
        # host budget admits ~2x the pages when the tier is quantized
        # (int8 payload + f16 scale vs bf16 rows)
        self.host_row_bytes = LC.host_row_bytes(cfg, cfg.param_dtype) \
            if cfg.ess.enabled else 0
        self.host_page_bytes = LC.host_page_bytes(cfg, cfg.param_dtype) \
            if cfg.ess.enabled else 0
        if self.paged:
            if host_byte_budget is not None:
                # byte-denominated provisioning: floor to whole pages of
                # the *storage* dtype.  num_host_pages, if also given, is
                # an additional cap.
                by_bytes = host_byte_budget // max(1, self.host_page_bytes)
                self.num_pages = by_bytes if num_host_pages is None \
                    else min(by_bytes, num_host_pages)
            else:
                self.num_pages = (num_host_pages
                                  if num_host_pages is not None
                                  else num_slots * blocks_per_slot)
            self.allocator = LC.HostPageAllocator(self.num_pages)
        caches = LC.init_ess_caches(
            cfg, num_slots, max_seq, cfg.param_dtype,
            num_pages=self.num_pages if self.paged else None,
            map_slots=not self.paged)
        # the device-resident round state: caches + tok/hidden carries +
        # per-slot sampling knobs + live/sampling masks.  The compiled
        # StepPrograms donate it every round; host code touches it only
        # at slot-lifecycle edges with .at[slot] updates.
        self.state = ES.init_engine_state(cfg, caches, num_slots,
                                          prefetch_rows=self.prefetch_rows)
        # the host half of the pipeline (slab arming + lifecycle-edge
        # cancellation + commit accounting); None when synchronous
        self.transfer: Optional[TR.TransferEngine] = None
        if self.prefetch_rows > 0:
            self.transfer = TR.TransferEngine(
                cfg.num_layers, num_slots, self.prefetch_rows,
                caches.host_latent.shape[-1], caches.host_latent.dtype,
                scale_dtype=None if caches.host_scales is None
                else caches.host_scales.dtype)
        self._programs = SP.get_programs(cfg, num_slots, max_seq,
                                         use_kernel, self.tbo,
                                         self.mtp_depth,
                                         self.prefetch_rows)
        self.pool_entries_per_slot = LC.pool_entries(cfg, max_seq)
        self.free_pool_entries = num_slots * self.pool_entries_per_slot
        self.sched = Scheduler(num_slots, max_seq,
                               admission_gate=self._admission_gate,
                               release_hook=self._release_slot,
                               reject_hook=self._reject)
        # per-request emitted token stream (prefill first-token + decode
        # emissions, truncated to max_new_tokens); reset on re-admission
        self.outputs: dict[int, list[int]] = {}
        self.report = ServeReport(num_pages=self.num_pages,
                                  host_bytes_per_row=self.host_row_bytes)
        # request-lifecycle event stream: every delivered token and every
        # terminal record (exactly one per rid) as TokenEvents.
        # `token_events` is the full log (latency accounting);
        # `_pending_events` buffers the current round for step_round()'s
        # return / the front-end's drain.
        self.token_events: list[TokenEvent] = []
        self._pending_events: list[TokenEvent] = []
        self._terminal: dict[int, str] = {}     # rid -> finish_reason
        self._last_done: list[Request] = []
        self._prompt_fn = prompt_fn or self._default_prompt
        # resources promised to earlier admissions of the same admit batch
        # (the scheduler consults the gate before the engine allocates)
        self._promised_pages = 0
        self._promised_slots = 0
        # chunked-prefill state machine: slot -> task, FIFO service order
        # by dict insertion (re-admissions re-insert at the back)
        self._prefill: dict[int, _PrefillTask] = {}
        # just-promoted slots whose on-device first tokens still await
        # delivery: [(slot, req, t0_dev)].  decode_round packs them into
        # the round's single device_get (one-fetch contract); the normal
        # step_round cadence holds at most one entry
        self._pending_first: list[tuple] = []
        # decode rounds each slot has run since its promotion — the
        # pipeline-fill window detector for ServeReport.fill_rounds
        self._rounds_since_promote: dict[int, int] = {}
        self._round = 0
        self._submit_round: dict[int, int] = {}
        self._submit_time: dict[int, float] = {}

    # -- device-state views (compat accessors over EngineState) --------------

    @property
    def caches(self) -> LC.ESSCaches:
        return self.state.caches

    @caches.setter
    def caches(self, value: LC.ESSCaches) -> None:
        self.state = self.state._replace(caches=value)

    @property
    def tok(self) -> jax.Array:
        """[B] next input token per slot (device-resident)."""
        return self.state.tok

    @property
    def hidden(self) -> jax.Array:
        """[B,d] post-final-norm hidden at each slot's last accepted
        position — the MTP draft seed, carried across rounds and across
        the prefill -> decode promotion (device-resident)."""
        return self.state.hidden

    # -- resource accounting -------------------------------------------------

    def _default_prompt(self, req: Request) -> jax.Array:
        return jax.random.randint(jax.random.key(1000 + req.rid),
                                  (1, req.prompt_len), 0,
                                  self.cfg.vocab_size)

    def pages_needed(self, req: Request) -> int:
        return LC.pages_for_len(self.cfg, req.prompt_len + req.max_new_tokens)

    def _admission_gate(self, req: Request) -> bool:
        # pool-entry gate: with today's per-slot dedicated pools this tracks
        # slot freeness exactly (the scheduler already enforces it); it is
        # the accounting hook that becomes load-bearing once the Sparse
        # Memory Pool is shared across slots
        need_entries = self.pool_entries_per_slot * (self._promised_slots + 1)
        if self.free_pool_entries < need_entries:
            return False
        need = self.pages_needed(req)
        if self.allocator is not None:
            # byte-denominated gate (dtype-aware): pages are the
            # allocation unit, but the resource being rationed is host
            # bytes — a quantized tier's smaller pages admit ~2x the
            # requests into the same byte budget
            need_bytes = need * self.host_page_bytes
            free_bytes = (self.allocator.free_pages
                          - self._promised_pages) * self.host_page_bytes
            if need_bytes > free_bytes:
                ev = (f"blocked rid={req.rid}: needs {need_bytes} host "
                      f"bytes ({need} pages), {free_bytes} free")
                if not self.report.events or self.report.events[-1] != ev:
                    self.report.events.append(ev)
                return False
        self._promised_pages += need
        self._promised_slots += 1
        return True

    def _release_slot(self, slot: int) -> None:
        # a mid-prefill preemption drops the chunk cursor: the attempt
        # re-prefills from scratch on its next admission
        self._prefill.pop(slot, None)
        if self.allocator is not None:
            self.allocator.release(slot)
            self.caches = LC.unmap_slot(self.caches, slot)
        self.caches = LC.reset_slot(self.caches, slot)
        self.state = ES.release_slot(self.state, slot)
        self._rounds_since_promote.pop(slot, None)
        self.free_pool_entries += self.pool_entries_per_slot

    def _sample_pages(self) -> None:
        if self.allocator is not None:
            used = self.num_pages - self.allocator.free_pages
            self.report.peak_pages_in_use = max(
                self.report.peak_pages_in_use, used)

    # -- event stream --------------------------------------------------------

    def _event(self, ev: TokenEvent) -> None:
        self._pending_events.append(ev)
        self.token_events.append(ev)

    def drain_events(self) -> list[TokenEvent]:
        """Hand the buffered TokenEvents to the front-end (also returned
        by :meth:`step_round`; this drains out-of-round events too —
        submit-time rejections, between-round aborts)."""
        evs, self._pending_events = self._pending_events, []
        return evs

    def _finalize(self, req: Request) -> None:
        """Emit the request's single terminal event.  Every request ends
        here exactly once, whatever the path (natural completion, stop
        token, abort, rejection, round-budget kill)."""
        reason = req.finish_reason or "length"
        assert req.rid not in self._terminal, \
            f"rid={req.rid} already terminal ({self._terminal[req.rid]})"
        self._terminal[req.rid] = reason
        self.report.finish_reasons[req.rid] = reason
        self._event(TokenEvent(rid=req.rid, token=None,
                               index=len(self.outputs.get(req.rid, [])),
                               finish_reason=reason,
                               t=time.perf_counter()))

    def _reject(self, req: Request) -> None:
        """Scheduler reject hook: an oversize request bounced at
        admission surfaces as a terminal ``rejected`` event + counter
        instead of silently vanishing."""
        self.report.rejected += 1
        self.report.events.append(
            f"rejected rid={req.rid}: prompt {req.prompt_len} + max_new "
            f"{req.max_new_tokens} > max_seq {self.sched.max_seq}")
        self._finalize(req)

    # -- request flow --------------------------------------------------------

    def submit(self, req: Request) -> None:
        # unconditional stamps: a missing rid must surface as a KeyError
        # at delivery, never as a silently ~0 TTFT (the old defaulted
        # lookup reported perf_counter() - perf_counter() for it)
        self._submit_round[req.rid] = self._round
        self._submit_time[req.rid] = time.perf_counter()
        # a request needing more pages than the whole pool can never be
        # admitted — reject up front instead of blocking the queue
        # forever (the scheduler itself only screens against max_seq)
        if self.allocator is not None \
                and self.pages_needed(req) > self.num_pages:
            req.finished = True
            req.finish_reason = "rejected"
            self.sched.finished.append(req)
            self.report.rejected += 1
            self.report.events.append(
                f"rejected rid={req.rid}: needs {self.pages_needed(req)} "
                f"pages, pool has {self.num_pages}")
            self._finalize(req)
            return
        self.sched.submit(req)

    def abort(self, rid: int, *, reason: str = "abort") -> bool:
        """Abort a queued or running request between rounds.  A running
        slot's host pages return to the allocator immediately and the
        slot gets the full reset (pool maps + lens + engine masks) via
        the scheduler's release hook — mid-prefill aborts also drop the
        chunk cursor; the stream closes with one terminal event."""
        req = self.sched.running.get(rid)
        if req is None:
            req = next((r for r in self.sched.queue if r.rid == rid), None)
        if req is None or req.finished:
            return False
        req.finish_reason = reason
        released = self.sched.abort(rid)
        assert released
        self.report.aborted += 1
        self.report.events.append(
            f"round {self._round}: rid={rid} aborted ({reason})")
        self._finalize(req)
        return True

    def preempt(self, slot: int) -> None:
        """Evict a running slot (node loss / rebalance); pages return and
        the slot's caches are fully reset via the scheduler's hook."""
        self.sched.preempt(slot)

    def admit(self) -> list[tuple[int, Request]]:
        """Admit queued requests into free slots: allocate + map host pages
        and enqueue the slot on the chunked-prefill state machine.  The
        prompt itself streams in ``prefill_chunk``-token chunks across
        subsequent :meth:`prefill_round` calls — admission never blocks the
        decode batch on a monolithic prefill."""
        self._promised_pages = 0
        self._promised_slots = 0
        admitted = self.sched.admit()
        for slot, req in admitted:
            if self.allocator is not None:
                pages = self.allocator.alloc(slot, self.pages_needed(req))
                self.caches = LC.map_slot(self.caches, slot, pages)
            self._sample_pages()
            self.free_pool_entries -= self.pool_entries_per_slot
            self._prefill[slot] = _PrefillTask(req, self._prompt_fn(req))
            # install the request's sampling knobs into the device state
            # (the slot itself stays frozen until the last prefill chunk)
            self.state = ES.admit_slot(self.state, slot, req)
            # a preempted re-admission regenerates its full stream
            self.outputs[req.rid] = []
            self.report.events.append(
                f"round {self._round}: rid={req.rid} -> slot {slot} "
                f"(prefill {req.prompt_len} toks, "
                f"preempted {req.preempted_count}x)")
        return admitted

    def prefill_round(self) -> bool:
        """Run one prefill chunk for the oldest admitting slot (if any).

        The chunk's latents and indexer keys scatter directly into the
        slot's mapped host pages.  Without warmup the chunk runs as a
        shape-bucketed StepProgram (ragged final chunks zero-padded to
        the bucket, masked via ``n_valid`` — no retrace, bit-identical
        valid rows) that also selects the first token in-device and
        promotes the slot inside the program; with ``do_warmup`` the
        legacy eager chunk collects the per-layer warmup tails and the
        LRU replay runs after the last chunk.

        **One-fetch contract**: a last chunk does *not* fetch its first
        token here.  The promotion bookkeeping is token-free, so the slot
        promotes immediately and the on-device ``t0`` is stashed; it
        rides this round's single packed ``device_get`` in
        :meth:`decode_round` (the promoted slot is active, so the decode
        program always runs).  Only the legacy ``do_warmup`` path — whose
        chunk is eager and host-driven anyway — still resolves ``t0``
        inline."""
        if not self._prefill:
            return False
        slot = next(iter(self._prefill))         # FIFO by insertion order
        task = self._prefill[slot]
        n = task.req.prompt_len
        c0 = task.cursor
        ck = min(self.prefill_chunk, n - c0)
        last = c0 + ck >= n
        if self.do_warmup:
            t0 = self._prefill_chunk_warmup(slot, task, c0, ck, n, last)
            t0_dev = None
        else:
            C = SP.chunk_bucket(ck, self.prefill_chunk)
            toks = task.tokens[:, c0:c0 + ck]
            if C > ck:
                toks = jnp.pad(toks, ((0, 0), (0, C - ck)))
            fn = self._programs.prefill(C, last, self.compiled)
            self.state, t0_dev = fn(self.params, self.state, toks,
                                    jnp.asarray(slot, jnp.int32),
                                    jnp.asarray(ck, jnp.int32))
        task.cursor += ck
        self.report.prefill_chunks += 1
        self.report.prefill_tokens += ck
        self.report.events.append(
            f"round {self._round}: rid={task.req.rid} prefill chunk "
            f"[{c0}:{c0 + ck})/{n} (slot {slot})")
        if last:
            if self.do_warmup:
                self._finish_prefill(slot, task, t0)
            else:
                req = task.req
                self.sched.promote(slot)
                self._rounds_since_promote[slot] = 0
                del self._prefill[slot]
                self._pending_first.append((slot, req, t0_dev))
        return True

    def _prefill_chunk_warmup(self, slot: int, task: _PrefillTask, c0: int,
                              ck: int, n: int, last: bool) -> Optional[int]:
        """Legacy eager prefill chunk for ``do_warmup`` sessions: ragged
        chunk shapes, per-layer tail collection across chunks, LRU-warmup
        replay after the last chunk, host-side first-token draw."""
        W = max(0, min(self.cfg.ess.warmup_windows, n - 1))
        toks = task.tokens[:, c0:c0 + ck]
        pos = jnp.arange(c0, c0 + ck, dtype=jnp.int32)[None]
        lg, self.caches, tails, hid_last = ess_prefill_chunk(
            self.params, self.cfg, toks, pos, self.caches, slot=slot,
            want_logits=last, collect_tail=min(W, ck),
            use_kernel=self.use_kernel, n_valid=None)
        if W > 0:
            if task.tails is None:
                task.tails = list(tails)
            else:
                task.tails = [jnp.concatenate([a, b], axis=1)[:, -W:]
                              for a, b in zip(task.tails, tails)]
        if not last:
            return None
        if W > 0:
            self._warmup_slot(slot, tuple(task.tails), n)
        req = task.req
        # legacy eager warmup path: syncs per chunk by design (the
        # compiled path defers t0 into decode_round's packed fetch)
        if req.sampling:
            t0 = int(self._draw(req, lg[0, -1], 0))    # esslint: disable=ESS002
        else:
            t0 = int(greedy(lg[:, -1])[0])             # esslint: disable=ESS002
        self.state = ES.promote_slot(self.state, slot, t0, hid_last[0])
        return t0

    def _deliver_first_token(self, slot: int, req: Request, t0: int,
                             now: Optional[float] = None) -> Optional[str]:
        """Deliver a freshly promoted slot's first token (stream + event
        + TTFT stamps).  ``now`` is the round's delivery time — the
        instant the packed fetch landed on the host — so latency stamps
        measure when the token became *available*, not when the commit
        stage's bookkeeping got around to it.  Returns the terminal kind
        if the request is already done at its first token — ``"stop"``
        (t0 is an EOS/stop token) or ``"length"`` (``max_new_tokens ==
        1`` spent the whole budget) — else ``None``."""
        if now is None:
            now = time.perf_counter()
        self.outputs[req.rid] = [t0]
        self._event(TokenEvent(rid=req.rid, token=t0, index=0, t=now))
        rid = req.rid
        ttft = self._round - self._submit_round[rid]
        # a preempted request's first token was already delivered by its
        # first attempt: keep that TTFT
        self.report.ttft_rounds.setdefault(rid, ttft)
        self.report.ttft_s.setdefault(rid, now - self._submit_time[rid])
        self.report.events.append(
            f"round {self._round}: rid={rid} first token ready "
            f"(ttft {ttft} rounds)")
        if t0 in req.stop_set:
            req.finish_reason = "stop"
            return "stop"
        if self.sched.budget_left(slot) == 0:
            return "length"
        return None

    def _finish_prefill(self, slot: int, task: _PrefillTask,
                        t0: int) -> None:
        """Legacy (``do_warmup``) promotion bookkeeping after the last
        prefill chunk: deliver the host-resolved first token and promote
        the slot into the decode batch.  A ``max_new_tokens == 1``
        request's budget is spent by the first token — it finishes right
        here, before any decode round; so does a request whose first
        token is one of its EOS/stop tokens.  (The compiled path defers
        delivery to :meth:`decode_round`'s packed fetch instead.)"""
        req = task.req
        self.sched.promote(slot)
        self._rounds_since_promote[slot] = 0
        del self._prefill[slot]
        done = self._deliver_first_token(slot, req, t0)
        if done == "stop":
            self._handle_done([self.sched.finish(slot)])
        elif done == "length":
            self._handle_done(self.sched.record_tokens({slot: 0}))

    def _warmup_slot(self, slot: int, tails: tuple, prompt_len: int) -> None:
        """LRU-Warmup replay for one freshly prefilled slot (paper §3.2):
        the Top-K sets of the last W prefill windows are inserted into a
        fresh batch-1 pool from the slot's mapped pages, then grafted into
        the shared Sparse Memory Pool with clock-clamped stamps."""
        lens1 = jnp.full((1,), prompt_len, jnp.int32)
        pools = []
        for layer, x_tail in enumerate(tails):
            lp, _ = _layer_params(self.params, self.cfg, layer)
            full = self.caches.pools[layer]
            one = LP.init_pool(1, full.data.shape[1],
                               self.caches.ikeys[layer].shape[1],
                               full.data.shape[2], full.data.dtype)
            ik_slot = jax.lax.slice_in_dim(self.caches.ikeys[layer], slot,
                                           slot + 1, axis=0)
            one = warmup.lru_warmup(
                one, self.caches.host_latent, x_tail, lp["indexer"], ik_slot,
                lens1, self.cfg, slot_mask=None, layer=layer,
                batch_offset=slot, block_table=self.caches.block_tables,
                host_scales=self.caches.host_scales)
            pools.append(LC.graft_pool_into(full, one, slot))
        self.caches = self.caches._replace(pools=tuple(pools))

    # -- decode stepping -----------------------------------------------------

    def _slot_req(self, slot: int) -> Request:
        return self.sched.running[self.sched.slots[slot].rid]

    def _draw(self, req: Request, logits: jax.Array, index: int):
        """Sample one token for a sampling request.  ``index`` is the
        chain position (0 = prefill first token, ``generated + 1`` in
        decode rounds) — the single key-derivation point that keeps
        sampled streams identical across Q=1 and speculative modes."""
        return sample(request_key(req.sample_seed, index), logits,
                      req.temperature, req.top_k, req.top_p)

    def _emit(self, slot: int, req: Request, tokens: list[int],
              now: Optional[float] = None) -> tuple[int, bool]:
        """Deliver a round's emitted tokens for one slot: extend the
        request's output stream (as TokenEvents too) and return
        ``(generated-budget charge, stop-token hit)``.
        Charge == delivery, always: both are clamped by the *same*
        ``remaining`` headroom (budget and max_seq), so the scheduler
        never records a token that was not appended to the stream —
        ``len(outputs[rid]) == generated + 1`` holds at finish (the old
        code charged ``min(len(tokens), remaining)`` while delivering
        under an additional ``max_new - len(out)`` clamp, so a verify
        round at the budget edge recorded ghost tokens).

        EOS/stop-token termination cuts *within* the round: the stream
        ends exactly at the stop position (the stop token is the last
        delivery) and the caller rolls back the over-accepted suffix an
        MTP verify round may have appended past it.

        ``now`` (the round's post-fetch delivery instant) stamps the
        TokenEvents, keeping ITL a delivery-latency measure rather than
        a commit-latency one."""
        out = self.outputs.setdefault(req.rid, [])
        delivered = tokens[:max(0, self.sched.remaining(slot))]
        stops = req.stop_set
        stopped = False
        if stops:
            for j, t in enumerate(delivered):
                if t in stops:
                    delivered = delivered[:j + 1]
                    stopped = True
                    break
        if now is None:
            now = time.perf_counter()
        for t in delivered:
            self._event(TokenEvent(rid=req.rid, token=t, index=len(out),
                                   t=now))
            out.append(t)
        if stopped:
            req.finish_reason = "stop"
        return len(delivered), stopped

    def _truncate_slot_tail(self, slot: int, n_drop: int) -> None:
        """Roll back the last ``n_drop`` appended positions of one slot
        (stop-token termination inside a speculative round): ``lens``
        shrink and pool entries beyond are invalidated — exactly the MTP
        rejection rollback, so the slot's lens/pool state matches a run
        that never drafted past the stop position.  (Indexer-cache and
        host rows beyond ``lens`` are dead by construction and reset
        with the slot.)"""
        if n_drop <= 0:
            return
        caches = self.caches
        new_lens = caches.lens.at[slot].add(jnp.int32(-n_drop))
        pools = tuple(LP.invalidate_beyond(p, new_lens)
                      for p in caches.pools)
        self.caches = caches._replace(lens=new_lens, pools=pools)
        if self.transfer is not None:
            # cancel staged transfers landing beyond the rollback point —
            # their host rows are about to be overwritten by the re-append
            # and would otherwise serve dead-draft latents next round.
            # new_lens[slot] stays a traced device scalar: an int() here
            # would be a second host sync inside the round (ESS102).
            self.state = self.transfer.truncate_slot(self.state, slot,
                                                     new_lens[slot])

    def _plan_round(self) -> Optional["_RoundPlan"]:
        """**Plan stage** of the round pipeline: decide what this round
        runs before any device work — sample page pressure, collect the
        just-promoted slots whose first tokens are still on device, and
        pick the round kind.  Returns ``None`` when no slot is active
        (a pipeline fill/drain round: nothing to compute, and the round
        is *not* counted toward the decode cadence).  The speculative
        plan half — which rows to stage for round N+1 — is traced inside
        the round program itself (``ess_decode``'s plan stage), where the
        indexer scores live."""
        self._sample_pages()
        pending, self._pending_first = self._pending_first, []
        # drop stale entries (slot preempted/aborted before its first
        # token was fetched — the re-admission regenerates the stream)
        pending = [(s, r, t) for s, r, t in pending
                   if self.sched.slots[s].active
                   and self.sched.slots[s].rid == r.rid]
        active = self.sched.active_slots()
        if not active:
            assert not pending       # a promoted slot is always active
            return None
        return _RoundPlan(active=active, pending=pending,
                          spec=self.mtp_depth > 0,
                          t0=time.perf_counter())

    def _compute_round(self, plan: "_RoundPlan") -> ES.RoundOut:
        """**Compute stage**: launch the round's donated StepProgram over
        the device state and return its packed :class:`RoundOut` handle —
        still on device; nothing here blocks the host.  With overlap on,
        the program consumes the slab staged by round N-1 and leaves
        round N+1's staging transfer in flight inside the same program."""
        fn = self._programs.spec(self.compiled) if plan.spec \
            else self._programs.decode(self.compiled)
        self.state, out = fn(self.params, self.state)
        return out

    def _commit_round(self, plan: "_RoundPlan",
                      out: ES.RoundOut) -> list[Request]:
        """**Commit stage**: the round's single packed fetch (one-fetch
        contract — decode emissions, the just-promoted slots' deferred
        first tokens, and the prefetch counters all ride one
        ``device_get``), then scheduler bookkeeping + stream emission.
        Every TokenEvent is stamped with the post-fetch *delivery*
        instant, not the time this bookkeeping finishes."""
        active, pending, spec = plan.active, plan.pending, plan.spec
        pf = () if out.pf_hits is None else \
            (out.pf_hits, out.pf_misses, out.pf_wasted)
        h2d = () if out.h2d_rows is None else (out.h2d_rows,)
        toks, n_emit, t0s, pf_host, h2d_host = jax.device_get(
            (out.tokens, out.n_emit, [t for _, _, t in pending], pf, h2d))
        t_deliver = time.perf_counter()
        if pf_host:
            self.transfer.commit(self.report, pf_host[0].sum(),
                                 pf_host[1].sum(), pf_host[2].sum())
        if h2d_host:
            self.report.h2d_rows += int(h2d_host[0])
        # decode-round D2H writeback: every live slot appends Q latent
        # rows per layer (compressed width on a quantized tier)
        q_round = (self.mtp_depth + 1) if spec else 1
        self.report.d2h_rows += len(active) * q_round * self.cfg.num_layers
        slot_tokens = {}
        stop_slots = []
        first_done = {}
        for (s0, r0, _), t0 in zip(pending, t0s):
            fd = self._deliver_first_token(s0, r0, int(t0), now=t_deliver)
            if fd is not None:
                first_done[s0] = fd
        for i in active:
            req = self._slot_req(i)
            if i in first_done:
                # the request ended at its very first token (stop token
                # or max_new_tokens == 1); the decode step the program
                # already took for the slot is discarded wholesale when
                # the slot releases (full reset: lens, pool maps, pages)
                slot_tokens[i] = 0
                if first_done[i] == "stop":
                    stop_slots.append(i)
                continue
            n = int(n_emit[i])
            charged, stopped = self._emit(i, req,
                                          [int(t) for t in toks[i, :n]],
                                          now=t_deliver)
            slot_tokens[i] = charged
            if stopped:
                # the verify round drafted past the stop: drop the
                # over-accepted suffix from the slot's lens + pools
                # (staged transfers beyond the cut are cancelled too)
                self._truncate_slot_tail(i, n - charged)
                stop_slots.append(i)
            if spec and not req.sampling:
                self.report.drafted_tokens += self.mtp_depth
                self.report.accepted_tokens += n - 1
        done = self.sched.record_tokens(slot_tokens)
        for i in stop_slots:
            if self.sched.slots[i].active:   # not already budget-finished
                done.append(self.sched.finish(i))
        # a round is *fill* while any stepping slot is still inside its
        # pipeline-fill window; fill rounds count toward `rounds` but not
        # toward the decode cadence (numerator nor denominator) — see
        # ServeReport.fill_rounds.  The window is a function of the
        # admission schedule alone, so sync and overlapped runs classify
        # identical rounds.
        fill = any(self._rounds_since_promote.get(i, PIPELINE_FILL_ROUNDS)
                   < PIPELINE_FILL_ROUNDS for i in active)
        for i in active:
            if self._rounds_since_promote.get(i, 99) < PIPELINE_FILL_ROUNDS:
                self._rounds_since_promote[i] += 1
        self.report.rounds += 1
        if spec:
            self.report.spec_rounds += 1
        self.report.decode_tokens += sum(slot_tokens.values())
        if fill:
            self.report.fill_rounds += 1
        else:
            self.report.decode_wall_s += time.perf_counter() - plan.t0
        return done

    def decode_round(self) -> list[Request]:
        """One decode round over the running slots; returns newly
        finished.

        The round is an explicit three-stage pipeline —
        :meth:`_plan_round` → :meth:`_compute_round` →
        :meth:`_commit_round`.  The whole compute — model step (Q=1, or
        the fused MTP draft+verify when ``mtp_depth > 0``, TBO halves
        included), greedy/sampled token selection, ``tok``/``hidden``
        carries, and (with ``overlap``) the staged-slab consumption +
        next round's prefetch staging — runs as one StepProgram over the
        donated device state; inactive and mid-prefill slots are masked
        *inside* the step (``slot_mask``).  The host fetches exactly one
        packed struct per round in the commit stage."""
        plan = self._plan_round()
        if plan is None:
            return []
        out = self._compute_round(plan)
        return self._commit_round(plan, out)

    def _handle_done(self, done: list[Request]) -> None:
        for req in done:
            out = self.outputs.get(req.rid, [])
            assert len(out) == req.generated + 1, \
                (f"rid={req.rid}: delivered {len(out)} != "
                 f"generated {req.generated} + first token")
            self._finalize(req)
            self.report.events.append(
                f"round {self._round}: rid={req.rid} finished "
                f"({len(out)} tokens, {req.finish_reason})")

    def step_round(self) -> list[TokenEvent]:
        """The re-entrant engine core: one serve round — admissions, then
        one prefill chunk for at most one admitting slot, then one decode
        step for all running slots — returning the round's TokenEvents
        (token deliveries + terminal records).  The front-end
        (:class:`repro.serving.api.EssEngine`) drives this directly;
        ``submit`` and ``abort`` may be called between any two rounds.
        Wall time accumulates per round, so throughput metrics hold for
        any driver (``run``, ``generate``, manual ``step`` loops)."""
        t0 = time.perf_counter()
        self.admit()
        self.prefill_round()
        done = self.decode_round()
        self._handle_done(done)
        self._round += 1
        self._last_done = done
        self.report.wall_s += time.perf_counter() - t0
        return self.drain_events()

    def step(self) -> list[Request]:
        """Compat wrapper over :meth:`step_round` returning the round's
        newly finished requests (events stay buffered for drain)."""
        evs = self.step_round()
        self._pending_events = evs + self._pending_events
        return self._last_done

    def _terminate_remaining(self, reason: str) -> None:
        """Terminal records for every still-unfinished request (round
        budget exhausted): running slots release their pages, queued
        requests drop, each rid gets exactly one ``reason`` event."""
        for rid in [r.rid for r in self.sched.queue] + \
                list(self.sched.running):
            self.abort(rid, reason=reason)

    def run(self, requests=None, *, max_rounds: int = 200,
            on_round: Optional[Callable[["ServeSession", int], None]] = None
            ) -> ServeReport:
        """Compat shim: drive :meth:`step_round` until every submitted
        request reaches a terminal event (streams are bit-identical to
        the front-end's ``generate``).  Requests still unfinished after
        ``max_rounds`` rounds are terminated with
        ``finish_reason="budget"`` — nothing is ever stranded without a
        terminal record."""
        for req in (requests or []):
            self.submit(req)
        budget = max_rounds            # rounds granted to THIS run() call
        while self.sched.running or self.sched.queue:
            self.step_round()          # accumulates report.wall_s
            if on_round is not None:
                # the serve round just executed (aligned with event labels)
                on_round(self, self._round - 1)
            budget -= 1
            if budget <= 0:
                self.report.events.append("max_rounds reached")
                self._terminate_remaining("budget")
                break
        self.report.finished_rids = [r.rid for r in self.sched.finished]
        self.report.admissions_blocked = self.sched.blocked_admissions
        # lifecycle contract: every submitted rid ended with exactly one
        # terminal event (single-emission is enforced in _finalize)
        missing = [rid for rid in self._submit_round
                   if rid not in self._terminal]
        assert not missing, f"no terminal event for rids {missing}"
        return self.report
