"""Serving engine: prefill + decode step functions.

Two decode paths:

* **generic** — any arch via ``repro.models.transformer.forward`` (contiguous
  caches, monolithic attention).
* **ESS** — DSA+MLA archs with ``cfg.ess.enabled``: unrolled layer loop so
  every layer's host fetch / Attn0 / Attn1 dependence structure stays
  visible to the XLA scheduler (DA/DBA overlap, paper §3.3).  Per layer:

    1. ln1 → new latent entry + indexer key appended (device ikeys;
       host_latent via D2H writeback — Figure 3's small D2H),
    2. ``ess_sparse_attention`` (fetch → Attn0 ∥ copy → Attn1 → exact merge,
       LRU admit),
    3. residual + (dense | MoE) ffn.

Prefill runs the chunked DSA path, scatters the latents to the host tier
(the PD-disaggregation "Load" arrow in Figure 3) and applies LRU-Warmup.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.cache import latent_cache as LC
from repro.configs.base import ArchConfig
from repro.core import lru_pool as LP
from repro.core import offload, warmup
from repro.core.overlap import ESSLayerState, ess_sparse_attention
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import mla as M
from repro.models import moe as MoE
from repro.models import transformer as T
from repro.serving.sampling import greedy
from repro.serving.scheduler import Request, Scheduler


class DecodeOut(NamedTuple):
    logits: jax.Array
    caches: Any
    stats: dict


# ---------------------------------------------------------------------------
# Generic path
# ---------------------------------------------------------------------------

def generic_prefill(params, cfg: ArchConfig, tokens, positions, **kw):
    return T.forward(params, cfg, tokens, positions, mode="prefill", **kw)


def generic_decode(params, cfg: ArchConfig, tokens, positions, caches, **kw):
    out = T.forward(params, cfg, tokens, positions, mode="decode",
                    caches=caches, **kw)
    return DecodeOut(out.logits, out.caches, {})


# ---------------------------------------------------------------------------
# ESS path (DSA + MLA + offload)
# ---------------------------------------------------------------------------

def _layer_params(params, cfg: ArchConfig, layer: int):
    nd = cfg.moe.first_dense_layers if cfg.moe else 0
    if layer < nd:
        return jax.tree.map(lambda a: a[layer], params["dense_layers"]), False
    return jax.tree.map(lambda a: a[layer - nd], params["layers"]), \
        cfg.moe is not None


def _overlap_for_layer(cfg: ArchConfig, layer: int,
                       layerwise: tuple[str, ...] | None) -> str:
    if cfg.ess.overlap == "layerwise":
        if layerwise is not None:
            return layerwise[layer]
        return "da"
    return cfg.ess.overlap


def ess_decode(params, cfg: ArchConfig, tokens, positions,
               caches: LC.ESSCaches, *, use_kernel: bool = False,
               layerwise_policy: tuple[str, ...] | None = None) -> DecodeOut:
    """tokens [B,Q] -> logits [B,Q,V].  Q>1 = MTP draft verification."""
    B, Q = tokens.shape
    x = L.embed(params["embed"], tokens).astype(cfg.param_dtype)
    x = shard(x, "batch", None, "embed_act")
    lens = caches.lens
    new_lens = lens + Q
    bi = jnp.arange(B)[:, None]
    widx = lens[:, None] + jnp.arange(Q)[None, :]                # [B,Q]

    host_latent = caches.host_latent
    ikeys_all = caches.ikeys
    pools = caches.pools
    hits = misses = ovf = jnp.zeros((B,), jnp.int32)

    for layer in range(cfg.num_layers):
        lp, is_moe = _layer_params(params, cfg, layer)
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)

        # --- append: indexer key (device) + latent entry (host, D2H) -----
        new_ik = M.indexer_keys(lp["indexer"], h)                # [B,Q,Di]
        ik_l = ikeys_all[layer].at[bi, widx].set(
            new_ik.astype(ikeys_all[layer].dtype), mode="drop")
        ikeys_all = ikeys_all[:layer] + (ik_l,) + ikeys_all[layer + 1:]
        new_lat = M.latent_entries(lp["mla"], cfg, h, positions) # [B,Q,D]
        host_latent = offload.host_scatter_rows(
            host_latent, widx, new_lat, layer=layer,
            block_table=caches.block_tables)

        # --- ESS sparse attention (fetch ∥ Attn0, Attn1, merge, admit) ---
        st = ESSLayerState(pools[layer], host_latent, layer,
                           block_table=caches.block_tables)
        ov = _overlap_for_layer(cfg, layer, layerwise_policy)
        attn, st2, stats = ess_sparse_attention(
            lp["mla"], lp["indexer"], cfg, h, positions, st, ik_l, new_lens,
            overlap=ov, use_kernel=use_kernel)
        pools = pools[:layer] + (st2.pool,) + pools[layer + 1:]
        x = x + attn

        # --- ffn ----------------------------------------------------------
        h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if is_moe:
            f, _ = MoE.moe_apply(lp["ffn"], cfg, h2)
        else:
            f = L.mlp(lp["ffn"], h2, cfg.act)
        x = x + f
        hits = hits + stats.hits
        misses = misses + stats.misses
        ovf = ovf + stats.overflow

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("unembed", params.get("embed")), x,
                       cap=cfg.logit_softcap)
    new_caches = caches._replace(lens=new_lens, host_latent=host_latent,
                                 ikeys=ikeys_all, pools=pools)
    return DecodeOut(logits, new_caches,
                     {"hits": hits, "misses": misses, "overflow": ovf,
                      "hidden": x})


def ess_prefill(params, cfg: ArchConfig, tokens, positions, max_seq: int,
                *, do_warmup: bool = True, use_kernel: bool = False
                ) -> tuple[jax.Array, LC.ESSCaches]:
    """Prefill + LRU-Warmup (paper §3.2).

    The first ``S - W`` tokens run through the chunked DSA prefill; the
    resulting latents are loaded into the host-tier Total Memory Pool
    (Figure 3's cross-node "Load").  The last ``W = warmup_windows`` tokens
    are then replayed as scanned single-token ESS decode steps: each step
    computes the true indexer Top-2K of its window and LRU-admits the
    misses — *exactly* "sequentially insert the Top-2K IDs of the last W
    prefill windows into the LRU cache"."""
    B, S = tokens.shape
    W = min(cfg.ess.warmup_windows, S - 1) if do_warmup else 0
    Sp = S - W
    out = T.forward(params, cfg, tokens[:, :Sp], positions[:, :Sp],
                    mode="prefill")
    mla_c: Any = out.caches["mla"]                     # latent [L,B,Sp,D]
    caches = LC.init_ess_caches(cfg, B, max_seq, cfg.param_dtype)
    lens = jnp.full((B,), Sp, jnp.int32)

    ik_pad = jnp.pad(mla_c.ikeys, ((0, 0), (0, 0), (0, max_seq - Sp), (0, 0)))
    if caches.block_tables is not None:
        # paged host tier: with the identity slot mapping of init_ess_caches
        # (page j of slot b = b*NB + j, pages batch-major) the page pool's
        # flat view IS the dense [L,B,S_pad,D] layout, so loading the
        # prefill latents is one pad + reshape — no per-row scatter.
        Lh, NP, R, D = caches.host_latent.shape
        NB = NP // B
        S_pad = NB * R
        lat_pad = jnp.pad(mla_c.latent,
                          ((0, 0), (0, 0), (0, S_pad - Sp), (0, 0)))
        host = lat_pad.astype(caches.host_latent.dtype).reshape(Lh, NP, R, D)
        host = offload.to_host(host, None, "cache_batch", None, None)
    else:
        lat_pad = jnp.pad(mla_c.latent,
                          ((0, 0), (0, 0), (0, max_seq - Sp), (0, 0)))
        host = lat_pad.astype(caches.host_latent.dtype)
        if cfg.ess.offload_kv:
            host = offload.to_host(host, None, "batch", None, None)
    ik_dtype = caches.ikeys[0].dtype
    caches = caches._replace(
        lens=lens, host_latent=host,
        ikeys=tuple(ik_pad[l].astype(ik_dtype)
                    for l in range(cfg.num_layers)))
    logits = out.logits

    if W > 0:
        # warmup replays run on the prefill side (bandwidth-rich): use the
        # exact miss envelope (M = K) so outputs match the monolithic model
        # bit-for-bit; the steady-state decode envelope stays provisioned
        # at cfg.ess.max_miss_ratio.
        import dataclasses
        cfg_x = dataclasses.replace(
            cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))

        def step(c, tw):
            tok, pos = tw                                  # [B], [B]
            o = ess_decode(params, cfg_x, tok[:, None], pos[:, None], c,
                           use_kernel=use_kernel)
            return o.caches, o.logits[:, 0]

        toks_w = tokens[:, Sp:].T                          # [W, B]
        pos_w = positions[:, Sp:].T
        caches, lg = jax.lax.scan(step, caches, (toks_w, pos_w))
        logits = jnp.concatenate([logits, lg.transpose(1, 0, 2)], axis=1)
    return logits, caches


# ---------------------------------------------------------------------------
# Continuous-batching serve loop (scheduler + paged host tier)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeReport:
    rounds: int = 0
    decode_tokens: int = 0              # tokens emitted by active slots
    wall_s: float = 0.0
    finished_rids: list = dataclasses.field(default_factory=list)
    admissions_blocked: int = 0         # admit attempts gated on resources
    peak_pages_in_use: int = 0
    num_pages: int = 0
    events: list = dataclasses.field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        return self.decode_tokens / self.wall_s if self.wall_s > 0 else 0.0


class ServeSession:
    """One long-lived ESS decode batch driven by the continuous-batching
    scheduler.

    * ``num_slots`` decode slots share one jit-shaped batch; more requests
      than slots stream through as slots free up.
    * With the paged host tier, admission is gated on **free host pages**
      (``pages = ceil((prompt + max_new) / page_rows)`` per request) and
      free Sparse-Memory-Pool entries; ``num_host_pages`` can be provisioned
      *below* ``num_slots × blocks_per_slot`` — the dense layout's pin — to
      exercise the gate.
    * A finished or preempted slot returns its pages to the allocator and
      gets a full per-slot cache reset (``reset_slot``: lens + pool maps),
      so a recycled slot can never take pool hits on the previous
      occupant's latents.
    """

    def __init__(self, params, cfg: ArchConfig, *, num_slots: int,
                 max_seq: int, num_host_pages: Optional[int] = None,
                 prompt_fn: Optional[Callable[[Request], jax.Array]] = None,
                 do_warmup: bool = False, use_kernel: bool = False):
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.do_warmup = do_warmup
        self.use_kernel = use_kernel
        self.paged = LC.uses_paged_host(cfg)
        blocks_per_slot = LC.num_blocks(cfg, max_seq) if cfg.ess.enabled \
            else 0
        self.num_pages = 0
        self.allocator: Optional[LC.HostPageAllocator] = None
        if self.paged:
            self.num_pages = (num_host_pages if num_host_pages is not None
                              else num_slots * blocks_per_slot)
            self.allocator = LC.HostPageAllocator(self.num_pages)
        self.caches = LC.init_ess_caches(
            cfg, num_slots, max_seq, cfg.param_dtype,
            num_pages=self.num_pages if self.paged else None,
            map_slots=not self.paged)
        self.pool_entries_per_slot = LC.pool_entries(cfg, max_seq)
        self.free_pool_entries = num_slots * self.pool_entries_per_slot
        self.sched = Scheduler(num_slots, max_seq,
                               admission_gate=self._admission_gate,
                               release_hook=self._release_slot)
        self.tok = jnp.zeros((num_slots,), jnp.int32)
        self.report = ServeReport(num_pages=self.num_pages)
        self._prompt_fn = prompt_fn or self._default_prompt
        # resources promised to earlier admissions of the same admit batch
        # (the scheduler consults the gate before the engine allocates)
        self._promised_pages = 0
        self._promised_slots = 0

    # -- resource accounting -------------------------------------------------

    def _default_prompt(self, req: Request) -> jax.Array:
        return jax.random.randint(jax.random.key(1000 + req.rid),
                                  (1, req.prompt_len), 0,
                                  self.cfg.vocab_size)

    def pages_needed(self, req: Request) -> int:
        return LC.pages_for_len(self.cfg, req.prompt_len + req.max_new_tokens)

    def _admission_gate(self, req: Request) -> bool:
        # pool-entry gate: with today's per-slot dedicated pools this tracks
        # slot freeness exactly (the scheduler already enforces it); it is
        # the accounting hook that becomes load-bearing once the Sparse
        # Memory Pool is shared across slots
        need_entries = self.pool_entries_per_slot * (self._promised_slots + 1)
        if self.free_pool_entries < need_entries:
            return False
        need = self.pages_needed(req)
        if self.allocator is not None \
                and not self.allocator.can_alloc(need + self._promised_pages):
            ev = (f"blocked rid={req.rid}: needs {need} pages, "
                  f"{self.allocator.free_pages - self._promised_pages} free")
            if not self.report.events or self.report.events[-1] != ev:
                self.report.events.append(ev)
            return False
        self._promised_pages += need
        self._promised_slots += 1
        return True

    def _release_slot(self, slot: int) -> None:
        if self.allocator is not None:
            self.allocator.release(slot)
            self.caches = LC.unmap_slot(self.caches, slot)
        self.caches = LC.reset_slot(self.caches, slot)
        self.free_pool_entries += self.pool_entries_per_slot

    # -- request flow --------------------------------------------------------

    def submit(self, req: Request) -> None:
        # a request needing more pages than the whole pool can never be
        # admitted — reject up front instead of blocking the FIFO head
        # forever (the scheduler itself only screens against max_seq)
        if self.allocator is not None \
                and self.pages_needed(req) > self.num_pages:
            req.finished = True
            self.sched.finished.append(req)
            self.report.events.append(
                f"rejected rid={req.rid}: needs {self.pages_needed(req)} "
                f"pages, pool has {self.num_pages}")
            return
        self.sched.submit(req)

    def preempt(self, slot: int) -> None:
        """Evict a running slot (node loss / rebalance); pages return and
        the slot's caches are fully reset via the scheduler's hook."""
        self.sched.preempt(slot)

    def admit(self) -> list[tuple[int, Request]]:
        """Admit queued requests into free slots: allocate + map host pages,
        prefill the prompt (batch-1), and graft it into the shared batch."""
        self._promised_pages = 0
        self._promised_slots = 0
        admitted = self.sched.admit()
        for slot, req in admitted:
            if self.allocator is not None:
                pages = self.allocator.alloc(slot, self.pages_needed(req))
                self.caches = LC.map_slot(self.caches, slot, pages)
                used = self.num_pages - self.allocator.free_pages
                self.report.peak_pages_in_use = max(
                    self.report.peak_pages_in_use, used)
            self.free_pool_entries -= self.pool_entries_per_slot
            toks = self._prompt_fn(req)
            pos = jnp.arange(req.prompt_len, dtype=jnp.int32)[None]
            lg, donor = ess_prefill(self.params, self.cfg, toks, pos,
                                    self.max_seq, do_warmup=self.do_warmup,
                                    use_kernel=self.use_kernel)
            self.caches = LC.graft_slot(self.caches, slot, donor,
                                        req.prompt_len,
                                        use_kernel=self.use_kernel)
            self.tok = self.tok.at[slot].set(greedy(lg[:, -1])[0])
        return admitted

    def decode_round(self) -> list[Request]:
        """One decode step over the whole batch; returns newly finished."""
        active = self.sched.active_slots()
        out = ess_decode(self.params, self.cfg, self.tok[:, None],
                         self.caches.lens[:, None], self.caches,
                         use_kernel=self.use_kernel)
        self.caches = out.caches
        self.tok = greedy(out.logits[:, -1])
        # inactive slots must not accumulate phantom length
        if len(active) < self.num_slots:
            mask = jnp.zeros((self.num_slots,), bool)
            if active:
                mask = mask.at[jnp.asarray(active)].set(True)
            self.caches = self.caches._replace(
                lens=jnp.where(mask, self.caches.lens, 0))
        done = self.sched.record_tokens({i: 1 for i in active})
        self.report.rounds += 1
        self.report.decode_tokens += len(active)
        return done

    def run(self, requests=None, *, max_rounds: int = 200,
            on_round: Optional[Callable[["ServeSession", int], None]] = None
            ) -> ServeReport:
        """Drive the loop until every submitted request finishes."""
        for req in (requests or []):
            self.submit(req)
        t0 = time.perf_counter()
        self.admit()
        rounds = 0
        while self.sched.running or self.sched.queue:
            done = self.decode_round()
            for req in done:
                self.report.events.append(
                    f"round {rounds}: rid={req.rid} finished "
                    f"({req.generated} tokens)")
            if on_round is not None:
                on_round(self, rounds)
            for slot, req in self.admit():
                self.report.events.append(
                    f"round {rounds}: rid={req.rid} -> slot {slot} "
                    f"(preempted {req.preempted_count}x)")
            rounds += 1
            if rounds >= max_rounds:
                self.report.events.append("max_rounds reached")
                break
        self.report.wall_s = time.perf_counter() - t0
        self.report.finished_rids = [r.rid for r in self.sched.finished]
        self.report.admissions_blocked = self.sched.blocked_admissions
        return self.report
