"""Multi-Token Prediction speculative decode (paper Table 1: MTP=2/4;
DeepSeek-V3 MTP modules).

Draft: each depth-k MTP module predicts token t+k+1 from the backbone's
final hidden and the previous draft's embedding:

    h_k = Block_k( W_proj [ RMSNorm(h_{k-1}) ; RMSNorm(Emb(tok_k)) ] )

(The deployed MTP block includes its own attention over the prefix; here the
draft head runs position-local — the *verification* pass is always the full
model, so acceptance is exact w.r.t. the backbone.  Accept-ratio dynamics at
the paper's settings are modelled byte-accurately in the simulator.)

Verify: one decode step with Q = depth+1 tokens scores all drafts; accepted
prefix keeps greedy-consistency with the full model; rejected positions are
rolled back by clamping ``lens`` and invalidating pool entries beyond.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import lru_pool as LP
from repro.models import layers as L
from repro.serving.sampling import greedy


def mtp_draft(params: dict, cfg: ArchConfig, hidden_last: jax.Array,
              first_tok: jax.Array, *, depth: int | None = None
              ) -> jax.Array:
    """hidden_last [B,d] (post-final-norm at the last accepted position),
    first_tok [B] (the token just sampled) -> drafts [B, depth]
    (``depth`` defaults to ``cfg.mtp_depth`` and may be lowered at serve
    time — the paper's MTP=2 vs MTP=4 deployment knob)."""
    depth = cfg.mtp_depth if depth is None else depth
    if depth > cfg.mtp_depth:
        raise ValueError(f"draft depth {depth} > cfg.mtp_depth "
                         f"{cfg.mtp_depth} stacked MTP modules")
    emb_w = params["embed"]
    head_w = params.get("unembed", params.get("embed"))
    h = hidden_last
    tok = first_tok
    drafts = []
    for k in range(depth):
        mp = jax.tree.map(lambda a: a[k], params["mtp"])
        e = L.embed(emb_w, tok).astype(h.dtype)
        z = jnp.concatenate([L.rmsnorm(mp["ln_h"], h, cfg.norm_eps),
                             L.rmsnorm(mp["ln_e"], e, cfg.norm_eps)], axis=-1)
        h = z @ mp["proj"]
        # position-local block pass: ffn path of the MTP block
        blk = mp["block"]
        h2 = L.rmsnorm(blk["ln2"], h, cfg.norm_eps)
        if "router" in blk["ffn"]:
            from repro.models import moe as MoE
            f, _ = MoE.moe_apply(blk["ffn"], cfg, h2[:, None])
            f = f[:, 0]
        else:
            f = L.mlp(blk["ffn"], h2, cfg.act)
        h = h + f
        logits = L.unembed(head_w, h, cap=cfg.logit_softcap)
        tok = greedy(logits)
        drafts.append(tok)
    return jnp.stack(drafts, axis=1)


class SpecOut(NamedTuple):
    """Result of one speculative round.  Pure arrays end to end: the
    serve loop's spec StepProgram traces :func:`speculative_step` —
    draft, verify, acceptance, rollback — into one donated jit program
    and packs (tokens, n_accepted) into its single per-round host fetch.
    """
    tokens: jax.Array     # [B, depth+1] verified output tokens
    n_accepted: jax.Array # [B] tokens actually emitted (1..depth+1)
    caches: object
    hidden: jax.Array     # [B, d] hidden at the last accepted position
    logits: jax.Array | None = None   # [B, depth+1, V] verify logits
    stats: dict | None = None         # verify step stats (async-offload
                                      # slab + prefetch counters ride here)


def speculative_step(decode_fn: Callable, params: dict, cfg: ArchConfig,
                     caches, prev_tok: jax.Array, prev_hidden: jax.Array,
                     *, slot_mask: jax.Array | None = None,
                     sample_mask: jax.Array | None = None,
                     depth: int | None = None) -> SpecOut:
    """One MTP speculative round.

    decode_fn(params, cfg, tokens [B,Q], positions [B,Q], caches)
      -> DecodeOut with stats["hidden"] [B,Q,d].

    ``slot_mask`` [B] bool marks the live decode slots of a continuous
    batch (the decode_fn is expected to gate the same mask *inside* the
    step).  The rollback is gated on it: a frozen slot's step appended
    nothing, so ``lens_after == lens`` and the unconditional correction
    would *shrink* the frozen slot by ``depth - n_acc`` and drop its live
    pool entries.

    ``sample_mask`` [B] bool marks slots emitting with stochastic
    sampling: their greedy drafts are force-rejected (``n_acc = 0``) so
    the round degrades to an exact single-token step for them — the
    caller samples their next token from ``SpecOut.logits[:, 0]``, which
    is exactly the Q=1 distribution.  Greedy slots keep full
    greedy-consistent acceptance.
    """
    B = prev_tok.shape[0]
    depth = cfg.mtp_depth if depth is None else depth
    drafts = mtp_draft(params, cfg, prev_hidden, prev_tok,
                       depth=depth)                              # [B,depth]
    q_tokens = jnp.concatenate([prev_tok[:, None], drafts], axis=1)
    positions = caches.lens[:, None] + jnp.arange(depth + 1)[None, :]

    out = decode_fn(params, cfg, q_tokens, positions, caches)
    model_next = greedy(out.logits)                              # [B,Q]

    # acceptance: draft[i] accepted iff it equals the model's prediction at
    # slot i (greedy spec-decode); emitted tokens = model_next[:, :n+1]
    match = (drafts == model_next[:, :depth])
    n_acc = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)  # [B]
    if sample_mask is not None:
        n_acc = jnp.where(sample_mask, 0, n_acc)

    # rollback: a live slot's decode pass appended depth+1 entries; keep
    # the accepted prefix + the bonus token (spec-decode emits n_acc+1
    # tokens per round).  Frozen slots (slot_mask False: freed or
    # mid-prefill) appended nothing and keep their lens verbatim.
    live = jnp.ones((B,), bool) if slot_mask is None else slot_mask
    new_caches = out.caches
    lens_after = new_caches.lens if hasattr(new_caches, "lens") else \
        new_caches["lens"]
    corrected = jnp.where(live,
                          lens_after - (depth + 1) + (n_acc + 1),
                          lens_after)
    if hasattr(new_caches, "_replace"):
        new_caches = new_caches._replace(lens=corrected)
        if hasattr(new_caches, "pools"):
            # after the step's admit+tick (see LP.invalidate_beyond's
            # ordering contract): drop pool entries the flattened Q>1
            # lookup admitted at now-rejected draft positions
            inv = tuple(LP.invalidate_beyond(p_, corrected)
                        for p_ in new_caches.pools)
            new_caches = new_caches._replace(pools=inv)
    else:
        new_caches = dict(new_caches)
        new_caches["lens"] = corrected

    hid = out.stats["hidden"]                                    # [B,Q,d]
    last_idx = jnp.clip(n_acc, 0, depth)
    hidden = jnp.take_along_axis(hid, last_idx[:, None, None], axis=1)[:, 0]
    stats = dict(out.stats)
    if "staged_ids" in stats:
        # the rollback edge of the async-offload pipeline: cancel staged
        # transfers targeting rejected draft positions (their host rows
        # hold rolled-back content that the next round's re-append will
        # overwrite — serving them would leak a dead draft's latents).
        # -1 stays -1 (corrected >= 0).
        sid = stats["staged_ids"]                                # [L,B,P]
        stats["staged_ids"] = jnp.where(sid < corrected[None, :, None],
                                        sid, -1)
    return SpecOut(model_next, n_acc + 1, new_caches, hidden, out.logits,
                   stats)
