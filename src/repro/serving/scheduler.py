"""Continuous-batching scheduler (host side).

Manages a fixed pool of decode slots: admission from a request queue,
completion/eviction, preemption (e.g. elastic down-scale or straggler
re-balance) with requeue, client aborts, and the batch-size/memory
accounting that the paper's analysis revolves around
(GPU-memory-feasible batch vs ESS batch).

Admission is **priority-aware**: the candidate is the queued request with
the highest ``priority``, FIFO (stable submission order) within a
priority class.  A preempted request re-enters *ahead* of its class so a
node-loss victim is re-served first.  Deterministic: all decisions
derive from (step, priority, submission order), so a restart from a
checkpointed step replays identically — the admission gate blocks on
the selected candidate with no head-of-line bypass (a lower-priority
request never sneaks past a resource-blocked higher-priority one).

Every request ends with exactly one ``finish_reason``
(``stop | length | abort | rejected | budget`` — see
:mod:`repro.serving.api`); the scheduler stamps ``length`` (budget /
max_seq exhaustion) and ``rejected`` (oversize) itself, the engine
stamps the rest before calling :meth:`Scheduler.finish` /
:meth:`Scheduler.abort`.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    arrived_step: int = 0
    generated: int = 0
    slot: Optional[int] = None
    finished: bool = False
    preempted_count: int = 0
    # per-request sampling: temperature == 0.0 -> greedy (the default);
    # > 0 draws from the (temperature, top_k, top_p)-shaped distribution
    # with a PRNG keyed on (seed, emission index) — see
    # repro.serving.sampling.request_key.  seed=None derives from rid.
    temperature: float = 0.0
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    seed: Optional[int] = None
    # lifecycle (public serving API, repro.serving.api): emitting any
    # token in eos_token_ids | stop_token_ids terminates the stream at
    # that position (finish_reason="stop"); priority orders admission
    # (higher first, FIFO within a class); seq is the scheduler-assigned
    # submission rank; finish_reason is stamped exactly once at the end.
    eos_token_ids: tuple = ()
    stop_token_ids: tuple = ()
    priority: int = 0
    seq: int = 0
    finish_reason: Optional[str] = None

    @property
    def sampling(self) -> bool:
        return self.temperature > 0.0

    @property
    def stop_set(self) -> frozenset:
        return frozenset(self.eos_token_ids) | frozenset(self.stop_token_ids)

    @property
    def sample_seed(self) -> int:
        return self.rid if self.seed is None else self.seed


@dataclasses.dataclass
class SlotState:
    rid: int = -1
    active: bool = False
    len: int = 0
    phase: str = "idle"      # idle | prefill | decode
    # the engine delivered the prefill's first token at promotion: it
    # consumes one unit of the request's max_new_tokens budget, so the
    # decode-round budget is max_new_tokens - 1 and at finish
    # len(outputs) == generated + 1 (first token + decode deliveries)
    first_emitted: bool = False


class Scheduler:
    """Slot-based continuous batching with preemption.

    Resource hooks wire the scheduler to the engine's cache tiers:

    * ``admission_gate(req) -> bool`` — called before a queued request takes
      a free slot; the engine gates on free host pages / free pool entries.
      A ``False`` verdict blocks the queue head (FIFO — no head-of-line
      bypass, so admission order stays deterministic).
    * ``release_hook(slot)`` — called whenever a slot stops serving its
      request (completion, preemption *or* abort); the engine returns the
      slot's host pages and performs the full per-slot cache reset
      (:func:`repro.cache.latent_cache.reset_slot`).
    * ``reject_hook(req)`` — called when an oversize request
      (``prompt_len + max_new_tokens > max_seq``) is bounced at admission
      so the engine can surface a terminal ``finish_reason="rejected"``
      event instead of letting the request silently vanish.
    """

    def __init__(self, num_slots: int, max_seq: int,
                 admission_gate: Optional[Callable[["Request"], bool]] = None,
                 release_hook: Optional[Callable[[int], None]] = None,
                 reject_hook: Optional[Callable[["Request"], None]] = None):
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.slots = [SlotState() for _ in range(num_slots)]
        self.queue: deque[Request] = deque()
        self.running: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.step = 0
        self.admission_gate = admission_gate
        self.release_hook = release_hook
        self.reject_hook = reject_hook
        self.blocked_admissions = 0
        self._seq = 0          # submission rank (FIFO within a class)
        self._seq_front = -1   # preempted requests jump their class's line

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.arrived_step = self.step
        req.seq = self._seq
        self._seq += 1
        self.queue.append(req)

    def _next_candidate(self) -> Optional[Request]:
        """Highest priority first; stable FIFO (submission seq) within a
        priority class — deterministic in (priority, submission order)."""
        if not self.queue:
            return None
        return min(self.queue, key=lambda r: (-r.priority, r.seq))

    def admit(self) -> list[tuple[int, Request]]:
        """Fill free slots from the queue; returns [(slot, request)] needing
        prefill."""
        admitted = []
        for i, s in enumerate(self.slots):
            if s.active:
                continue
            # reject oversize candidates outright (they can never be
            # admitted) and surface them via the reject hook
            while True:
                req = self._next_candidate()
                if req is None or (req.prompt_len + req.max_new_tokens
                                   <= self.max_seq):
                    break
                self.queue.remove(req)
                req.finished = True
                req.finish_reason = "rejected"
                self.finished.append(req)
                if self.reject_hook is not None:
                    self.reject_hook(req)
            if req is None:
                break
            if self.admission_gate is not None \
                    and not self.admission_gate(req):
                self.blocked_admissions += 1
                break                        # resources exhausted: wait
            self.queue.remove(req)
            s.rid, s.active, s.len = req.rid, True, req.prompt_len
            s.phase = "prefill"
            req.slot = i
            self.running[req.rid] = req
            admitted.append((i, req))
        return admitted

    # -- stepping -----------------------------------------------------------

    def active_slots(self) -> list[int]:
        """Decode-eligible slots.  Slots still streaming prefill chunks are
        admitted (they hold pages + a pool reservation) but must not take
        decode steps until :meth:`promote`."""
        return [i for i, s in enumerate(self.slots)
                if s.active and s.phase == "decode"]

    def prefill_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots)
                if s.active and s.phase == "prefill"]

    def promote(self, slot: int) -> None:
        """Prefill finished: the slot joins the decode batch.  Promotion
        is the moment the engine delivers the prefill's first token, so
        it charges one unit of the ``max_new_tokens`` budget
        (``first_emitted``); callers must check :meth:`remaining` — a
        ``max_new_tokens == 1`` request is already done."""
        s = self.slots[slot]
        if s.active and s.phase == "prefill":
            s.phase = "decode"
            s.first_emitted = True

    def budget_left(self, slot: int) -> int:
        """max_new_tokens budget still open for decode deliveries (the
        prefill first token consumes one unit once promoted)."""
        s = self.slots[slot]
        if not s.active:
            return 0
        req = self.running[s.rid]
        return max(0, req.max_new_tokens - req.generated
                   - (1 if s.first_emitted else 0))

    def remaining(self, slot: int) -> int:
        """Tokens slot ``slot``'s request may still emit before finishing
        (budget *and* max_seq headroom).  ``_emit`` clamps every round's
        delivery to this, so a request never over-runs ``max_new_tokens``
        just because a verify round accepted more drafts than it had
        budget left."""
        s = self.slots[slot]
        if not s.active:
            return 0
        return max(0, min(self.budget_left(slot), self.max_seq - s.len))

    def record_tokens(self, slot_tokens: dict[int, int]) -> list[Request]:
        """slot -> n tokens *delivered* this step; returns newly finished.

        ``n`` may vary per slot and per round (Q>1 speculative decode
        emits ``n_accepted + 1`` tokens a round); ``s.len`` advances by
        exactly ``n`` so the scheduler's length view tracks the engine's
        rolled-back cache ``lens``.  The charge equals what the engine
        actually appended to the output stream (see ``ServeSession._emit``),
        so at finish ``len(outputs) == generated + first_emitted``."""
        done = []
        for i, n in slot_tokens.items():
            s = self.slots[i]
            if not s.active:
                continue
            req = self.running[s.rid]
            req.generated += n
            s.len += n
            limit = req.max_new_tokens - (1 if s.first_emitted else 0)
            if req.generated >= limit or s.len >= self.max_seq:
                req.finished = True
                if req.finish_reason is None:   # engine may have set "stop"
                    req.finish_reason = "length"
                done.append(req)
                self._release(i)
        self.step += 1
        return done

    def finish(self, slot: int) -> Request:
        """Force-complete a running slot mid-budget (EOS / stop-token
        termination): the engine stamps ``finish_reason`` first, then the
        slot releases exactly as a natural completion."""
        s = self.slots[slot]
        assert s.active, f"finish() on inactive slot {slot}"
        req = self.running[s.rid]
        req.finished = True
        if req.finish_reason is None:
            req.finish_reason = "stop"
        self._release(slot)
        return req

    def abort(self, rid: int) -> bool:
        """Abort a queued or running request (client disconnect / budget
        kill).  A running slot releases through the engine's hook (pages
        return, caches reset); a queued request is simply removed.  No
        requeue — the request is terminally finished."""
        for req in self.queue:
            if req.rid == rid:
                self.queue.remove(req)
                req.finished = True
                if req.finish_reason is None:
                    req.finish_reason = "abort"
                self.finished.append(req)
                return True
        req = self.running.get(rid)
        if req is None:
            return False
        req.finished = True
        if req.finish_reason is None:
            req.finish_reason = "abort"
        self._release(req.slot)
        return True

    # -- PD-disaggregated handoff edges --------------------------------------

    def adopt(self, req: Request, slot: int) -> None:
        """Install an already-prefilled request directly into a free slot
        (the decode side of a PD handoff): the request enters in the
        ``decode`` phase with ``first_emitted`` charged — the prefill
        worker computed its first token and the installing engine
        delivers it — bypassing the admission queue.  The byte/slot gate
        runs on the *installing worker* before calling this (the router's
        placement decision); the scheduler only records the occupancy."""
        s = self.slots[slot]
        assert not s.active, f"adopt() into occupied slot {slot}"
        assert req.rid not in self.running, \
            f"adopt(): rid={req.rid} already running here"
        s.rid, s.active, s.len = req.rid, True, req.prompt_len
        s.phase = "decode"
        s.first_emitted = True
        req.slot = slot
        req.finished = False
        self.running[req.rid] = req

    def release_migrated(self, slot: int) -> Request:
        """Release a slot whose request migrated to another worker: the
        resources free exactly as a completion (pages return, caches
        reset via the release hook) but the request is *not* finished —
        no terminal record here; the decode worker that adopted it owns
        the rest of its lifecycle."""
        s = self.slots[slot]
        assert s.active, f"release_migrated() on inactive slot {slot}"
        req = self.running.pop(s.rid)
        req.slot = None
        s.rid, s.active, s.len, s.phase = -1, False, 0, "idle"
        s.first_emitted = False
        if self.release_hook is not None:
            self.release_hook(slot)
        return req

    def preempt(self, slot: int) -> None:
        """Evict a running sequence (node loss / rebalance); it re-queues and
        will re-prefill on next admission (PD-disaggregation semantics).

        Per-attempt progress resets: the next attempt re-prefills from
        scratch and generates the full ``max_new_tokens`` again.  Carrying
        ``generated`` across attempts made :meth:`record_tokens` finish the
        re-admitted request ``generated`` tokens early."""
        s = self.slots[slot]
        if not s.active:
            return
        req = self.running.pop(s.rid)
        req.preempted_count += 1
        req.slot = None
        req.generated = 0
        # jump the line within its priority class (the old appendleft
        # semantics under priority-aware candidate selection)
        req.seq = self._seq_front
        self._seq_front -= 1
        self.queue.appendleft(req)
        s.rid, s.active, s.len, s.phase = -1, False, 0, "idle"
        s.first_emitted = False
        if self.release_hook is not None:
            self.release_hook(slot)

    def _release(self, slot: int) -> None:
        s = self.slots[slot]
        req = self.running.pop(s.rid, None)
        if req is not None:
            self.finished.append(req)
        s.rid, s.active, s.len, s.phase = -1, False, 0, "idle"
        s.first_emitted = False
        if self.release_hook is not None:
            self.release_hook(slot)

    # -- accounting ----------------------------------------------------------

    def occupancy(self) -> float:
        return sum(s.active for s in self.slots) / max(1, self.num_slots)


@dataclasses.dataclass(frozen=True)
class WorkerLoad:
    """One decode worker's admission headroom, byte-denominated.

    ``free_host_bytes`` is the worker's free host-page count times its
    *storage-dtype* page bytes (PR 8's dtype-aware accounting: a
    quantized tier's smaller pages mean the same page count is less
    byte headroom than a bf16 tier's), so placement compares workers on
    the resource actually being rationed even across mixed-dtype fleets.
    """
    worker: int              # index into the router's decode-worker list
    free_host_bytes: int
    free_slots: int
    queued: int              # running + queued requests (tiebreak load)


def pick_decode_worker(loads: list[WorkerLoad],
                       need_bytes: int) -> Optional[int]:
    """Router placement: the decode worker with the most free host bytes
    among those that can admit *now* (a free slot and ``need_bytes`` of
    page headroom).  A full or byte-exhausted worker is routed around —
    never a rejection; if no worker can admit now the caller holds the
    request and retries after the next round frees resources (returns
    ``None``).  Ties break toward the lighter (fewer requests), then
    lower-indexed worker, keeping placement deterministic."""
    fits = [l for l in loads
            if l.free_slots > 0 and l.free_host_bytes >= need_bytes]
    if not fits:
        return None
    best = max(fits, key=lambda l: (l.free_host_bytes, -l.queued,
                                    -l.worker))
    return best.worker


def feasible_batch_size(hbm_bytes: int, weight_bytes_per_dev: int,
                        cache_bytes_per_seq: int, activation_slack: float
                        = 0.9) -> int:
    """Paper §2.1: GPU memory caps the decode batch.  Returns max B with
    full cache on device (the 'batch 52' ceiling)."""
    free = hbm_bytes * activation_slack - weight_bytes_per_dev
    return max(0, int(free // max(1, cache_bytes_per_seq)))
