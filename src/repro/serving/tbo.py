"""Two-Batch Overlap (paper Table 1: "Two-Batch Overlap: open").

Splits the decode batch into two independent half-batches executed in one
jit program with per-layer interleaved program order, so half-A's EP
all-to-all / host fetches overlap half-B's compute under the XLA scheduler
(the TPU equivalent of SGLang's TBO dual-stream schedule).

For the ESS engine, DBA overlap (repro.core.overlap) already splits the
*indexer* within a half; TBO composes with it at the step level.  The
engine composes ``split_caches -> two_batch_step -> merge_caches``; with
the paged host tier the merge is a *page-ownership select*, not a concat —
both halves carry the whole global page pool, so keeping either half's
``host_latent`` verbatim would silently drop the other half's D2H writes
(the page-merge bug this module's merge fixes).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.cache import latent_cache as LC
from repro.core import lru_pool as LP


def tbo_step(step_fn: Callable, params, cfg, tokens, positions, caches, *,
             slot_mask: jax.Array | None = None,
             staged: tuple | None = None):
    """Full split → two-half step → page-ownership merge composition over
    an un-split cache: the step-level TBO building block the serve round
    uses (``repro.serving.step`` traces it — split, both halves and the
    merge — into one donated jit program, which is what actually lets the
    XLA scheduler interleave half-A's H2D fetches with half-B's compute).

    With the async-offload pipeline, TBO is just the degenerate special
    case of the same plan/compute/commit structure — the two halves are
    two pipeline lanes whose transfers and compute the scheduler
    interleaves — so the staging slab pair (``staged``) splits along the
    slot axis and rides through each half unchanged in meaning.

    Returns ``(logits [B,Q,V], merged_caches, stats)``.
    """
    B = tokens.shape[0]
    ca, cb = split_caches(caches, B // 2)
    logits, ca2, cb2, stats = two_batch_step(
        step_fn, params, cfg, tokens, positions, ca, cb,
        slot_mask=slot_mask, staged=staged)
    return logits, merge_caches(ca2, cb2), stats


def two_batch_step(step_fn: Callable, params, cfg, tokens, positions,
                   caches_a, caches_b, *,
                   slot_mask: jax.Array | None = None,
                   staged: tuple | None = None):
    """tokens/positions [B,Q] split at ``B // 2``; caches pre-split by the
    engine (:func:`split_caches`).  ``slot_mask`` [B] (continuous-batching
    live mask) is split alongside and forwarded to ``step_fn`` as a
    keyword, so freed / mid-prefill slots stay gated inside each half.
    ``staged`` (the async-offload slab pair ``(ids [L,B,P], rows
    [L,B,P,D])``) splits along the slot axis the same way.

    Returns ``(logits [B,Q,V], caches_a', caches_b', stats)`` where
    ``stats`` is the per-key batch concatenation of the halves' step stats
    (hits/misses/overflow [B], hidden [B,Q,d]; ``staged_*`` slabs carry
    the slot axis second, so they concatenate on axis 1).  Reconcile the
    halves with :func:`merge_caches` — with a paged host tier neither
    half's ``host_latent`` alone contains both halves' writes.
    """
    B = tokens.shape[0]
    h = B // 2
    kw_a, kw_b = {}, {}
    if slot_mask is not None:
        kw_a["slot_mask"] = slot_mask[:h]
        kw_b["slot_mask"] = slot_mask[h:]
    if staged is not None:
        sc = staged[2] if len(staged) > 2 else None
        kw_a["staged"] = (staged[0][:, :h], staged[1][:, :h],
                          None if sc is None else sc[:, :h])
        kw_b["staged"] = (staged[0][:, h:], staged[1][:, h:],
                          None if sc is None else sc[:, h:])
    out_a = step_fn(params, cfg, tokens[:h], positions[:h], caches_a, **kw_a)
    out_b = step_fn(params, cfg, tokens[h:], positions[h:], caches_b, **kw_b)
    logits = jnp.concatenate([out_a.logits, out_b.logits], axis=0)
    stats = {}
    for k in out_a.stats:
        va, vb = out_a.stats[k], out_b.stats[k]
        if k.startswith("staged_"):              # [L,B/2,...] slab halves
            stats[k] = None if va is None else \
                jnp.concatenate([va, vb], axis=1)
        else:
            stats[k] = jnp.concatenate([va, vb], axis=0) \
                if getattr(va, "ndim", 0) > 0 else va
    return logits, out_a.caches, out_b.caches, stats


def split_caches(caches, half: int):
    """Split a cache pytree along the batch dim.

    Handles both cache layouts:
    * ESSCaches — lens [B], host_latent [L,B,S,D] (batch axis 1), ikeys
      tuple of [B,S,Di], pools tuple of PoolState ([B,...] leaves, scalar
      step);
    * dict caches — lens [B], stacked [L,B,...] leaves (batch axis 1).
    """
    def cut(lo, hi):
        if hasattr(caches, "pools"):            # ESSCaches
            paged = getattr(caches, "block_tables", None) is not None
            # paged host tier: the page pool is global; each half keeps the
            # whole pool and slices its block-table rows (slots own disjoint
            # pages, so the halves' writebacks never collide)
            hs = getattr(caches, "host_scales", None)
            return caches._replace(
                lens=caches.lens[lo:hi],
                host_latent=caches.host_latent if paged
                else caches.host_latent[:, lo:hi],
                ikeys=tuple(a[lo:hi] for a in caches.ikeys),
                pools=tuple(jax.tree.map(
                    lambda a: a[lo:hi] if a.ndim > 0 else a, p)
                    for p in caches.pools),
                block_tables=caches.block_tables[lo:hi] if paged else None,
                # the scale plane shadows the payload pool: global when
                # paged (ownership-merged later), batch-sliced when dense
                host_scales=hs if hs is None or paged else hs[:, lo:hi])
        def one(a):
            if a.ndim == 0:
                return a
            if a.ndim == 1:
                return a[lo:hi]
            return a[:, lo:hi]
        return jax.tree.map(one, caches)
    return cut(0, half), cut(half, None)


def merge_caches(caches_a, caches_b):
    """Reconcile the two halves of a TBO step back into one full-batch
    :class:`~repro.cache.latent_cache.ESSCaches`.

    Batch-dim leaves (lens, ikeys, pool rows, block tables) concatenate.
    The host tier needs layout-aware reconciliation:

    * **paged** — both halves stepped against the *same* global page pool
      and wrote disjoint physical pages (each slot scatters only through
      its own block-table rows).  Select half-B's writes out of half-A's
      copy by page ownership (:func:`LC.pages_owned_mask` over half-B's
      block tables); pages mapped by neither half (free pages) come from
      half-A verbatim — no half wrote them.
    * **dense** — each half carried its own ``[L, B/2, S, D]`` slice;
      concatenate on the batch axis.

    Pool ``step`` clocks advanced in lockstep (one tick per step per
    half), so half-A's scalar is kept.
    """
    a_paged = getattr(caches_a, "block_tables", None) is not None
    b_paged = getattr(caches_b, "block_tables", None) is not None
    if a_paged != b_paged:
        raise ValueError("cannot merge paged and dense cache halves")
    hs_a = getattr(caches_a, "host_scales", None)
    if a_paged:
        NP = caches_a.host_latent.shape[1]
        owned_b = LC.pages_owned_mask(caches_b.block_tables, NP)
        host = jnp.where(owned_b[None, :, None, None],
                         caches_b.host_latent, caches_a.host_latent)
        bt = jnp.concatenate([caches_a.block_tables,
                              caches_b.block_tables], axis=0)
        # the scale plane takes the exact same page-ownership select —
        # keeping either half's scales verbatim would dequantize the
        # other half's fresh payload with stale scales
        scales = None if hs_a is None else jnp.where(
            owned_b[None, :, None, None], caches_b.host_scales, hs_a)
    else:
        host = jnp.concatenate([caches_a.host_latent,
                                caches_b.host_latent], axis=1)
        bt = None
        scales = None if hs_a is None else jnp.concatenate(
            [hs_a, caches_b.host_scales], axis=1)
    pools = tuple(
        LP.PoolState(*(jnp.concatenate([la, lb], axis=0)
                       if la.ndim > 0 else la
                       for la, lb in zip(pa, pb)))
        for pa, pb in zip(caches_a.pools, caches_b.pools))
    return caches_a._replace(
        lens=jnp.concatenate([caches_a.lens, caches_b.lens], axis=0),
        host_latent=host,
        ikeys=tuple(jnp.concatenate([ia, ib], axis=0)
                    for ia, ib in zip(caches_a.ikeys, caches_b.ikeys)),
        pools=pools,
        block_tables=bt,
        host_scales=scales)
