"""Two-Batch Overlap (paper Table 1: "Two-Batch Overlap: open").

Splits the decode batch into two independent half-batches executed in one
jit program with per-layer interleaved program order, so half-A's EP
all-to-all / host fetches overlap half-B's compute under the XLA scheduler
(the TPU equivalent of SGLang's TBO dual-stream schedule).

For the ESS engine, DBA overlap (repro.core.overlap) already splits the
*indexer* within a half; TBO composes with it at the step level.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def two_batch_step(step_fn: Callable, params, cfg, tokens, positions, caches_a,
                   caches_b):
    """tokens/positions [B,Q] split evenly; caches pre-split by the engine.
    Returns (logits [B,Q,V], caches_a', caches_b')."""
    B = tokens.shape[0]
    h = B // 2
    out_a = step_fn(params, cfg, tokens[:h], positions[:h], caches_a)
    out_b = step_fn(params, cfg, tokens[h:], positions[h:], caches_b)
    logits = jnp.concatenate([out_a.logits, out_b.logits], axis=0)
    return logits, out_a.caches, out_b.caches


def split_caches(caches, half: int):
    """Split a cache pytree along the batch dim.

    Handles both cache layouts:
    * ESSCaches — lens [B], host_latent [L,B,S,D] (batch axis 1), ikeys
      tuple of [B,S,Di], pools tuple of PoolState ([B,...] leaves, scalar
      step);
    * dict caches — lens [B], stacked [L,B,...] leaves (batch axis 1).
    """
    def cut(lo, hi):
        if hasattr(caches, "pools"):            # ESSCaches
            paged = getattr(caches, "block_tables", None) is not None
            # paged host tier: the page pool is global; each half keeps the
            # whole pool and slices its block-table rows (slots own disjoint
            # pages, so the halves' writebacks never collide)
            return caches._replace(
                lens=caches.lens[lo:hi],
                host_latent=caches.host_latent if paged
                else caches.host_latent[:, lo:hi],
                ikeys=tuple(a[lo:hi] for a in caches.ikeys),
                pools=tuple(jax.tree.map(
                    lambda a: a[lo:hi] if a.ndim > 0 else a, p)
                    for p in caches.pools),
                block_tables=caches.block_tables[lo:hi] if paged else None)
        def one(a):
            if a.ndim == 0:
                return a
            if a.ndim == 1:
                return a[lo:hi]
            return a[:, lo:hi]
        return jax.tree.map(one, caches)
    return cut(0, half), cut(half, None)
