"""Public serving API: request lifecycle over the re-entrant engine core.

The paper's decoupled batch-size scaling only pays off if real workloads
can use it — chat streams, early stopping, client disconnects that
reclaim host pages immediately.  This module is that front-end, the
layer holistic offload-centric serving systems (KVDrive, NOSA) put above
their step loop:

* :class:`SamplingParams` — per-request generation knobs (temperature /
  top-k / top-p / seed, ``max_tokens``, EOS + stop token sets, admission
  ``priority``);
* :class:`TokenEvent` — one incremental stream element: a delivered
  token, or the request's single terminal record
  (``finish_reason`` set);
* :class:`RequestOutput` — the aggregate result of one finished request;
* :class:`EssEngine` — the facade: ``submit(prompt, params) -> rid``,
  ``step() -> [TokenEvent]``, ``stream(rid)`` generator,
  ``generate(prompts, params)`` batch convenience, ``abort(rid)`` and
  ``metrics()``.  Under the hood it drives
  :meth:`repro.serving.engine.ServeSession.step_round` — the re-entrant
  serve round (admit → one prefill chunk → one decode/verify step) that
  requests can be submitted to and aborted from *between any two
  rounds*.

``finish_reason`` state machine (exactly one terminal event per rid):

    submitted ──admit──> prefill ──promote──> decode
        │                   │                    │
        │ oversize          │ abort()            ├── EOS/stop token ──> "stop"
        ├──────> "rejected" ├──────> "abort"     ├── budget/max_seq ──> "length"
        │ abort()           │                    ├── abort() ─────────> "abort"
        ├──────> "abort"    │                    │
        │ run()/generate()  round budget exhausted
        └────────────────────────────────────────┴─────────────────> "budget"

A preemption is *not* terminal: the request requeues (jumping its
priority class's line) and its re-admission regenerates the identical
stream, so the deterministic-replay contract holds across node loss.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Iterator, Optional, Sequence, Union

from repro.serving.scheduler import Request

FINISH_REASONS = ("stop", "length", "abort", "rejected", "budget")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation knobs.

    ``temperature == 0`` is greedy; ``top_k=None`` / ``top_p=None``
    disable the respective truncation; ``seed=None`` derives the
    sampling PRNG from the rid.  Emitting any token in
    ``eos_token_ids | stop_token_ids`` ends the stream *at that
    position* (``finish_reason="stop"``) — inside a speculative round
    the over-accepted suffix is rolled back so the slot state matches a
    run that never drafted past the stop.  ``priority`` orders
    admission (higher first, stable FIFO within a class)."""
    max_tokens: int = 16
    temperature: float = 0.0
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    seed: Optional[int] = None
    eos_token_ids: tuple = ()
    stop_token_ids: tuple = ()
    priority: int = 0


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One element of a request's incremental result stream.

    Token events carry ``token`` with ``finish_reason=None``; the single
    terminal event carries ``finish_reason`` with ``token=None`` and
    ``index`` = the final stream length.  ``t`` is a
    ``time.perf_counter`` stamp at delivery (TTFT / inter-token-latency
    accounting — see :func:`latency_stats`)."""
    rid: int
    token: Optional[int]
    index: int
    finish_reason: Optional[str] = None
    t: float = 0.0

    @property
    def is_terminal(self) -> bool:
        return self.finish_reason is not None


@dataclasses.dataclass
class RequestOutput:
    """Aggregate result of one finished request."""
    rid: int
    prompt_len: int
    tokens: list
    finish_reason: str
    ttft_s: Optional[float] = None

    @property
    def n_generated(self) -> int:
        return len(self.tokens)


def _pctl(vals: list, q: float) -> Optional[float]:
    if not vals:
        return None
    vs = sorted(vals)
    return vs[min(len(vs) - 1, int(round(q * (len(vs) - 1))))]


def latency_stats(events: Sequence[TokenEvent],
                  submit_times: dict) -> dict:
    """p50/p95 TTFT and inter-token gap from TokenEvent timestamps.

    TTFT = first token event's stamp minus the rid's submit stamp;
    inter-token gap = consecutive token-event stamp deltas per rid
    (tokens of one speculative round share a stamp, so accepted drafts
    correctly count as ~zero-gap emissions)."""
    ttft, gaps = [], []
    prev: dict[int, float] = {}
    for ev in events:
        if ev.token is None:
            continue
        if ev.index == 0:
            sub = submit_times.get(ev.rid)
            if sub is not None:
                ttft.append(ev.t - sub)
        elif ev.rid in prev:
            gaps.append(ev.t - prev[ev.rid])
        prev[ev.rid] = ev.t
    return {
        "ttft_p50_s": _pctl(ttft, 0.50),
        "ttft_p95_s": _pctl(ttft, 0.95),
        "itl_p50_s": _pctl(gaps, 0.50),
        "itl_p95_s": _pctl(gaps, 0.95),
        "n_token_events": len(ttft) + len(gaps),
    }


class EssEngine:
    """Request-lifecycle facade over one :class:`ServeSession`.

    Construction takes the same knobs as ``ServeSession`` (``num_slots``,
    ``max_seq``, ``num_host_pages``, ``prefill_chunk``, ``mtp_depth``,
    ``tbo``, ``compiled``, ...).  Prompts are either an ``int`` (a
    synthetic prompt of that length, derived deterministically from the
    rid — the benchmarking path) or an explicit token sequence.

    The engine assigns rids, distributes every round's
    :class:`TokenEvent` batch into per-rid buffers, and guarantees each
    rid's stream ends with exactly one terminal event.  ``stream(rid)``
    is single-consumer per rid; ``generate`` and manual
    ``submit``+``step`` loops can interleave freely with it — any call
    to :meth:`step` advances *all* in-flight requests one serve round.
    """

    def __init__(self, params, cfg, *, num_slots: int, max_seq: int,
                 **session_kw):
        from repro.serving import engine as E   # api is engine's import
        self._user_prompt_fn = session_kw.pop("prompt_fn", None)
        self.session = E.ServeSession(params, cfg, num_slots=num_slots,
                                      max_seq=max_seq,
                                      prompt_fn=self._prompt_for,
                                      **session_kw)
        self._next_rid = 0
        self._prompts: dict[int, Any] = {}
        self._plens: dict[int, int] = {}
        self._buffers: dict[int, deque] = {}

    # -- request lifecycle ---------------------------------------------------

    def _prompt_for(self, req: Request):
        p = self._prompts.get(req.rid)
        if p is not None:
            return p
        if self._user_prompt_fn is not None:
            return self._user_prompt_fn(req)
        return self.session._default_prompt(req)

    def submit(self, prompt: Union[int, Sequence[int]],
               params: Optional[SamplingParams] = None) -> int:
        """Enqueue one request; returns its rid.  Admission happens at
        the next :meth:`step` (between rounds, never mid-round).  An
        unservable request (needs more host pages than the whole pool)
        is rejected immediately — its terminal event is already buffered
        when ``submit`` returns."""
        params = params or SamplingParams()
        rid = self._next_rid
        self._next_rid += 1
        if isinstance(prompt, int):
            plen = prompt
        else:
            import jax.numpy as jnp
            toks = jnp.asarray(prompt, jnp.int32)[None, :]
            self._prompts[rid] = toks
            plen = int(toks.shape[1])
        self._plens[rid] = plen
        self._buffers.setdefault(rid, deque())
        self.session.submit(Request(
            rid=rid, prompt_len=plen, max_new_tokens=params.max_tokens,
            temperature=params.temperature, top_k=params.top_k,
            top_p=params.top_p, seed=params.seed,
            eos_token_ids=tuple(params.eos_token_ids),
            stop_token_ids=tuple(params.stop_token_ids),
            priority=params.priority))
        self._distribute(self.session.drain_events())
        return rid

    def abort(self, rid: int) -> bool:
        """Abort a queued or running request between rounds: host pages
        return to the allocator immediately, the slot fully resets, and
        the stream closes with ``finish_reason="abort"``."""
        ok = self.session.abort(rid)
        self._distribute(self.session.drain_events())
        return ok

    def step(self) -> list:
        """Run one serve round; returns (and buffers) its TokenEvents."""
        evs = self.session.step_round()
        self._distribute(evs)
        return evs

    def _distribute(self, evs) -> None:
        for ev in evs:
            self._buffers.setdefault(ev.rid, deque()).append(ev)

    # -- results -------------------------------------------------------------

    def is_finished(self, rid: int) -> bool:
        return rid in self.session._terminal

    def finish_reason(self, rid: int) -> Optional[str]:
        return self.session._terminal.get(rid)

    def has_work(self) -> bool:
        return bool(self.session.sched.running or self.session.sched.queue)

    def stream(self, rid: int) -> Iterator[TokenEvent]:
        """Incremental results for one rid, driving serve rounds as
        needed; ends after yielding the terminal event.  Single-consumer
        per rid (events are popped from the rid's buffer)."""
        buf = self._buffers[rid]
        while True:
            while buf:
                ev = buf.popleft()
                yield ev
                if ev.is_terminal:
                    return
            if self.is_finished(rid):
                return                     # terminal already consumed
            if not self.has_work():
                raise RuntimeError(
                    f"rid={rid} stream stalled: engine idle with no "
                    f"terminal event")
            self.step()

    def output(self, rid: int) -> RequestOutput:
        """Aggregate result; the rid must have finished."""
        ses = self.session
        assert rid in ses._terminal, f"rid={rid} has not finished"
        return RequestOutput(
            rid=rid, prompt_len=self._plens.get(rid, 0),
            tokens=list(ses.outputs.get(rid, [])),
            finish_reason=ses._terminal[rid],
            ttft_s=ses.report.ttft_s.get(rid))

    def generate(self, prompts: Sequence,
                 params: Union[SamplingParams, Sequence[SamplingParams],
                               None] = None, *,
                 max_rounds: int = 200) -> list:
        """Submit a batch and drive the loop until every request reaches
        a terminal event; returns RequestOutputs in submission order.
        Requests still unfinished after ``max_rounds`` serve rounds are
        terminated with ``finish_reason="budget"``."""
        if params is None or isinstance(params, SamplingParams):
            params = [params or SamplingParams()] * len(prompts)
        assert len(params) == len(prompts)
        rids = [self.submit(p, sp) for p, sp in zip(prompts, params)]
        budget = max_rounds
        while any(not self.is_finished(r) for r in rids):
            self.step()
            budget -= 1
            if budget <= 0:
                for r in rids:
                    if not self.is_finished(r):
                        self.session.abort(r, reason="budget")
                self._distribute(self.session.drain_events())
                break
        return [self.output(r) for r in rids]

    def metrics(self) -> dict:
        """Serving counters + latency percentiles (from TokenEvent
        timestamps) for everything this engine has served so far."""
        rep = self.session.report
        m = {
            "rounds": rep.rounds,
            "spec_rounds": rep.spec_rounds,
            "decode_tokens": rep.decode_tokens,
            "prefill_tokens": rep.prefill_tokens,
            "prefill_chunks": rep.prefill_chunks,
            "accept_rate": rep.accept_rate,
            "rejected": rep.rejected,
            "aborted": rep.aborted,
            "finish_reasons": dict(rep.finish_reasons),
            "admissions_blocked": self.session.sched.blocked_admissions,
            "peak_pages_in_use": rep.peak_pages_in_use,
            "num_pages": rep.num_pages,
            "prefetch_hits": rep.prefetch_hits,
            "prefetch_misses": rep.prefetch_misses,
            "prefetch_wasted_rows": rep.prefetch_wasted_rows,
            "prefetch_hit_rate": rep.prefetch_hit_rate,
        }
        m.update(latency_stats(self.session.token_events,
                               self.session._submit_time))
        return m
