"""Token sampling: greedy / temperature / top-k / top-p (fp32 logits).

Two entry points with identical semantics:

* :func:`sample` — host-driven: one row of logits, Python-typed knobs
  (``top_k`` static, ``lax.top_k`` under the hood).  The eager serve
  path and prefill first-token draws use this.
* :func:`sample_batch` — device-resident: per-slot parameter *arrays*
  (``temperature/top_k/top_p/seed/emit_index [B]``) so the whole draw —
  key fold, truncation, categorical — traces into the compiled serve
  round.  Sentinels replace ``None``: ``top_k <= 0`` and ``top_p >= 1``
  disable the respective truncation.  The k-th-largest threshold comes
  from a full descending sort instead of ``lax.top_k`` (whose k must be
  static); both select the same value, and the masks compare against
  the value, so the two entry points emit bit-identical tokens for the
  same ``(seed, index, logits, knobs)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def request_key(seed: int, index: int) -> jax.Array:
    """Per-emission PRNG key for one request: deterministic in
    ``(seed, emission index)``, so a preempted request's re-run replays
    the identical sampled stream (continuous-batching determinism) and
    MTP-speculative vs Q=1 serve modes draw the same tokens (both draw
    once per chain position)."""
    return jax.random.fold_in(jax.random.key(seed), index)


def sample(key: jax.Array, logits: jax.Array, temperature: float = 1.0,
           top_k: int | None = None, top_p: float | None = None) -> jax.Array:
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k is not None and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p is not None and top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def sample_one(seed: jax.Array, index: jax.Array, logits: jax.Array,
               temperature: jax.Array, top_k: jax.Array,
               top_p: jax.Array) -> jax.Array:
    """One fully-traced draw: logits [V], scalar knobs (sentinels for
    "off": ``top_k <= 0`` / ``top_p >= 1``).  Emits the same token as
    :func:`sample` with ``request_key(seed, index)`` and the equivalent
    Python knobs; callers mask out the result for greedy slots
    (``temperature == 0``) rather than branching."""
    V = logits.shape[-1]
    key = jax.random.fold_in(jax.random.key(seed), index)
    lg = logits.astype(jnp.float32) / jnp.where(temperature > 0.0,
                                                temperature, 1.0)
    # top-k: threshold at the k-th largest value (== lax.top_k(...)[-1])
    use_k = (top_k >= 1) & (top_k < V)
    srt = jnp.sort(lg, axis=-1)[::-1]
    kth = srt[jnp.clip(top_k, 1, V) - 1]
    lg = jnp.where(use_k & (lg < kth), -jnp.inf, lg)
    # top-p over the (possibly top-k-masked) logits, exactly as sample()
    use_p = top_p < 1.0
    sorted_logits = jnp.sort(lg, axis=-1)[::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.sum(cum < top_p, axis=-1)
    cutoff = sorted_logits[jnp.clip(cutoff_idx, 0, V - 1)]
    lg = jnp.where(use_p & (lg < cutoff), -jnp.inf, lg)
    return jax.random.categorical(key, lg).astype(jnp.int32)


def sample_batch(seed: jax.Array, index: jax.Array, logits: jax.Array,
                 temperature: jax.Array, top_k: jax.Array,
                 top_p: jax.Array) -> jax.Array:
    """Per-slot in-device sampling: logits [B,V], all knobs [B] arrays.
    Returns [B] i32 draws; rows with ``temperature == 0`` return an
    arbitrary draw the caller must replace with the greedy token."""
    return jax.vmap(sample_one)(seed, index, logits, temperature,
                                top_k, top_p)
