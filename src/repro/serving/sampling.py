"""Token sampling: greedy / temperature / top-k / top-p (fp32 logits)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def request_key(seed: int, index: int) -> jax.Array:
    """Per-emission PRNG key for one request: deterministic in
    ``(seed, emission index)``, so a preempted request's re-run replays
    the identical sampled stream (continuous-batching determinism) and
    MTP-speculative vs Q=1 serve modes draw the same tokens (both draw
    once per chain position)."""
    return jax.random.fold_in(jax.random.key(seed), index)


def sample(key: jax.Array, logits: jax.Array, temperature: float = 1.0,
           top_k: int | None = None, top_p: float | None = None) -> jax.Array:
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k is not None and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p is not None and top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)
