"""Device-resident engine state for the compiled serve round.

:class:`EngineState` is the single pytree a serve round consumes and
produces: the ESS caches plus everything the round loop used to keep in
per-slot Python variables — the next input token, the carried MTP draft
hidden, the per-slot sampling knobs, and the live/sampling slot masks.
Holding them as ``[B]`` arrays lets the whole round (decode or MTP
draft+verify, token selection included) compile into one donated XLA
program (:mod:`repro.serving.step`); the host touches the state only at
slot-lifecycle edges (admission, promotion, release) with tiny
``.at[slot]`` updates.

Sentinel conventions (``None`` is not a dtype):

* ``top_k <= 0``  — top-k truncation off,
* ``top_p >= 1``  — top-p truncation off,
* ``temperature == 0`` — greedy (``sample_mask`` False).

:class:`RoundOut` is the packed per-round result — the *only* thing the
host fetches per decode round (one ``jax.device_get``): the emitted
tokens ``[B, Q]`` and per-slot emission counts ``[B]``.  Everything else
(caches, tok, hidden, masks) stays on device inside the donated state.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.cache import latent_cache as LC
from repro.configs.base import ArchConfig
from repro.serving.scheduler import Request


class EngineState(NamedTuple):
    caches: LC.ESSCaches
    tok: jax.Array          # [B] i32  next input token per slot
    hidden: jax.Array       # [B,d]    post-final-norm hidden (MTP draft seed)
    temperature: jax.Array  # [B] f32  0 = greedy
    top_k: jax.Array        # [B] i32  <= 0 = off
    top_p: jax.Array        # [B] f32  >= 1 = off
    seed: jax.Array         # [B] i32  per-request PRNG seed
    emit_index: jax.Array   # [B] i32  next sampling chain position
    slot_mask: jax.Array    # [B] bool live decode slots
    sample_mask: jax.Array  # [B] bool slots emitting stochastically
    # async-offload staging slabs (None = overlap off; ``None`` is an
    # empty pytree, so the synchronous state keeps its exact leaf
    # structure).  Donated like every other leaf — XLA's aliasing is the
    # double buffer: each round consumes slab N and writes slab N+1 into
    # the same storage.  Field order matters to the ESS105 audit:
    # ``staged_rows`` is the LAST state leaf in every configuration;
    # ``staged_scales`` (the quantized tier's per-row scale plane, None
    # for a bf16 tier) sits between it and ``staged_ids`` so adding it
    # never moves the audited leaf.
    staged_ids: jax.Array | None = None     # [L,B,P] i32 staged positions
    staged_scales: jax.Array | None = None  # [L,B,P,1] staged row scales
    staged_rows: jax.Array | None = None    # [L,B,P,D] staged host rows


class RoundOut(NamedTuple):
    """Packed per-round emission — the single host fetch of a round.
    With async offload the prefetch accounting rides the same packed
    struct (``None`` fields are empty pytree leaves, so the synchronous
    fetch shape is unchanged)."""
    tokens: jax.Array       # [B,Q] emitted tokens; cols [0, n_emit) valid
    n_emit: jax.Array       # [B] i32 tokens emitted (0 for frozen slots)
    pf_hits: jax.Array | None = None     # [B] staged rows that served misses
    pf_misses: jax.Array | None = None   # [B] misses falling back to sync
    pf_wasted: jax.Array | None = None   # [B] staged rows nobody requested
    # scalar i32: miss rows served from the host tier this round (summed
    # over layers *and* slots on device, so the commit stage reads a
    # plain host int off the packed fetch); multiplied by the dtype-exact
    # bytes/row host-side it gives the compressed-transfer accounting
    # (quantized tiers move ~half the bytes per row)
    h2d_rows: jax.Array | None = None


def init_engine_state(cfg: ArchConfig, caches: LC.ESSCaches,
                      num_slots: int, *,
                      prefetch_rows: int = 0) -> EngineState:
    staged_ids = staged_scales = staged_rows = None
    if prefetch_rows > 0:
        from repro.core import transfer as TR
        staged_ids, staged_rows, staged_scales = TR.empty_slab(
            caches.host_latent.shape[0], num_slots, prefetch_rows,
            caches.host_latent.shape[-1], caches.host_latent.dtype,
            None if caches.host_scales is None
            else caches.host_scales.dtype)
    return EngineState(
        caches=caches,
        tok=jnp.zeros((num_slots,), jnp.int32),
        hidden=jnp.zeros((num_slots, cfg.d_model), cfg.param_dtype),
        temperature=jnp.zeros((num_slots,), jnp.float32),
        top_k=jnp.zeros((num_slots,), jnp.int32),
        top_p=jnp.ones((num_slots,), jnp.float32),
        seed=jnp.zeros((num_slots,), jnp.int32),
        emit_index=jnp.zeros((num_slots,), jnp.int32),
        slot_mask=jnp.zeros((num_slots,), bool),
        sample_mask=jnp.zeros((num_slots,), bool),
        staged_ids=staged_ids,
        staged_scales=staged_scales,
        staged_rows=staged_rows,
    )


def admit_slot(state: EngineState, slot: int, req: Request) -> EngineState:
    """Install a request's sampling knobs into its slot (host-side edge;
    the slot stays frozen — ``slot_mask`` flips in the last prefill
    chunk's program, together with ``tok``/``hidden``/``emit_index``)."""
    return state._replace(
        temperature=state.temperature.at[slot].set(float(req.temperature)),
        top_k=state.top_k.at[slot].set(
            0 if req.top_k is None else int(req.top_k)),
        top_p=state.top_p.at[slot].set(
            1.0 if req.top_p is None else float(req.top_p)),
        seed=state.seed.at[slot].set(int(req.sample_seed)),
        emit_index=state.emit_index.at[slot].set(0),
        sample_mask=state.sample_mask.at[slot].set(bool(req.sampling)),
    )


def promote_slot(state: EngineState, slot, tok, hidden) -> EngineState:
    """Flip a freshly prefilled slot into the decode batch: install the
    first token + draft-seed hidden, arm the sampling chain at emission
    index 1 (the first token drew at index 0), and unfreeze the slot.
    Used traced (inside the last prefill chunk's StepProgram, ``slot``
    dynamic) and host-side (the legacy ``do_warmup`` path) alike."""
    return state._replace(
        tok=state.tok.at[slot].set(tok),
        hidden=state.hidden.at[slot].set(hidden),
        emit_index=state.emit_index.at[slot].set(1),
        slot_mask=state.slot_mask.at[slot].set(True),
    )


def release_slot(state: EngineState, slot: int) -> EngineState:
    """Freeze a finished/preempted slot (host-side edge).  Cache-tier
    cleanup (pages, pools, lens) happens separately via
    :func:`repro.cache.latent_cache.reset_slot` / ``unmap_slot``.  The
    slot's staged transfers are cancelled with it — a surviving staged
    id would serve the *previous occupant's* row to the next one."""
    staged = {} if state.staged_ids is None else {
        "staged_ids": state.staged_ids.at[:, slot].set(-1)}
    return state._replace(
        slot_mask=state.slot_mask.at[slot].set(False),
        sample_mask=state.sample_mask.at[slot].set(False),
        temperature=state.temperature.at[slot].set(0.0),
        emit_index=state.emit_index.at[slot].set(0),
        **staged,
    )
