"""Paged GQA KV cache (vLLM/SGLang-style) — substrate for the generalized
ESS pool on non-MLA architectures and for the serving engine's slot
management.

The *logical* cache of a sequence is a list of fixed-size pages scattered in
a global page pool; a per-sequence page table maps logical block -> physical
page.  The transformer's contiguous-cache decode path stays the default (it
shards and lowers cleanly at scale); the paged variant backs continuous
batching where sequences enter/leave slots dynamically.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PagedKV(NamedTuple):
    pages_k: jax.Array      # [NPAGES, PAGE, KV, HD]
    pages_v: jax.Array      # [NPAGES, PAGE, KV, HD]
    page_table: jax.Array   # [B, MAX_BLOCKS] physical page id (-1 empty)
    lens: jax.Array         # [B]
    free_head: jax.Array    # [] next free page (bump allocator)


def init_paged(npages: int, page: int, kv_heads: int, head_dim: int,
               batch: int, max_blocks: int, dtype=jnp.bfloat16) -> PagedKV:
    return PagedKV(
        jnp.zeros((npages, page, kv_heads, head_dim), dtype),
        jnp.zeros((npages, page, kv_heads, head_dim), dtype),
        jnp.full((batch, max_blocks), -1, jnp.int32),
        jnp.zeros((batch,), jnp.int32),
        jnp.zeros((), jnp.int32),
    )


def append_token(kv: PagedKV, k_new: jax.Array, v_new: jax.Array) -> PagedKV:
    """Append one token per sequence ([B, KV, HD]); allocates pages lazily
    with a bump allocator (freeing is done by the host-side scheduler which
    rebuilds page tables on eviction)."""
    B = k_new.shape[0]
    page = kv.pages_k.shape[1]
    blk = kv.lens // page
    off = kv.lens % page
    need = (off == 0).astype(jnp.int32)                     # new page needed
    alloc_rank = jnp.cumsum(need) - need                    # per-seq offset
    new_page_id = kv.free_head + alloc_rank
    bi = jnp.arange(B)
    table = kv.page_table.at[bi, blk].set(
        jnp.where(need == 1, new_page_id, kv.page_table[bi, blk]))
    phys = table[bi, blk]
    pages_k = kv.pages_k.at[phys, off].set(k_new.astype(kv.pages_k.dtype))
    pages_v = kv.pages_v.at[phys, off].set(v_new.astype(kv.pages_v.dtype))
    return PagedKV(pages_k, pages_v, table, kv.lens + 1,
                   kv.free_head + need.sum())


def gather_kv(kv: PagedKV, max_seq: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Materialize per-sequence contiguous K/V [B, max_seq, KV, HD] + valid
    mask (decode attention input). max_seq must be a multiple of page."""
    B, MB = kv.page_table.shape
    page = kv.pages_k.shape[1]
    nb = max_seq // page
    pt = jnp.where(kv.page_table[:, :nb] >= 0, kv.page_table[:, :nb], 0)
    k = kv.pages_k[pt]                                       # [B, nb, P, KV, HD]
    v = kv.pages_v[pt]
    k = k.reshape(B, nb * page, *k.shape[3:])
    v = v.reshape(B, nb * page, *v.shape[3:])
    valid = jnp.arange(nb * page)[None, :] < kv.lens[:, None]
    return k, v, valid


def release_sequence(kv: PagedKV, seq: int) -> PagedKV:
    """Host-side eviction: clear a slot's table + len (pages recycled by the
    scheduler's compaction pass)."""
    return kv._replace(
        page_table=kv.page_table.at[seq].set(-1),
        lens=kv.lens.at[seq].set(0))
