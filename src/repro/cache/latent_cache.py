"""ESS latent-cache state: host tier + device pools + indexer cache.

Layout (per model):

* ``host_latent`` — the **Total Memory Pool** (paper Fig. 3), pinned host
  memory.  Two layouts:

  - **paged** (default when ``cfg.ess.offload_kv``): a *global* page pool
    ``[L, num_pages, page_rows, D]`` plus per-slot ``block_tables [B, NB]``
    (page id per block, ``-1`` = unmapped).  Host bytes track actual
    sequence lengths: a decode slot only pins the pages its block table
    maps, so serve-loop admission is gated on the free-page count instead
    of ``B × max_seq`` dense rows (KVDrive-style multi-tier paging).
  - **dense** (``cfg.ess.paged_host = False`` or no offload): one
    ``[L, B, max_seq, D]`` buffer, every slot pinning ``max_seq`` rows.

  One buffer either way; layers index it inside the host computation
  (updates alias in place).
* ``ikeys``  — tuple of per-layer [B, S, Di] Indexer-Cache buffers, device
  HBM, never offloaded (16.8 % of cache bytes, fully read each step).
  Per-layer leaves (not a stacked array) so each decode layer touches only
  its own buffer — no full-stack copies in the unrolled step.
* ``pools``  — tuple of per-layer :class:`repro.core.lru_pool.PoolState`,
  the device-side **Sparse Memory Pool**.
"""

from __future__ import annotations

from collections import deque
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import lru_pool as LP
from repro.core import offload
from repro.distributed import compression as cmp
from repro.distributed import sharding as shd


class ESSCaches(NamedTuple):
    lens: jax.Array                    # [B]
    host_latent: jax.Array             # dense [L,B,S,D] | paged [L,NP,R,D]
    ikeys: tuple                       # L x [B, S, Di]
    pools: tuple                       # L x PoolState
    block_tables: Optional[jax.Array] = None   # [B, NB] int32 (paged only)
    # per-row scales of a quantized host tier (None = raw bf16 tier):
    # paged [L,NP,R,1] | dense [L,B,S,1], SCALE_DTYPE, same memory space
    # as host_latent — each page carries its R-row scale vector and moves
    # with it (see repro.distributed.compression.quantize_rows)
    host_scales: Optional[jax.Array] = None

    @property
    def paged(self) -> bool:
        return self.block_tables is not None

    @property
    def quantized(self) -> bool:
        return self.host_scales is not None


def pool_entries(cfg: ArchConfig, max_seq: int) -> int:
    return LP.pool_entries_for(cfg.ess.sparse_memory_ratio, max_seq,
                               cfg.dsa.index_topk, cfg.ess.pool_min_entries)


def uses_paged_host(cfg: ArchConfig) -> bool:
    """Paged host tier is the default for offloaded configs."""
    return cfg.ess.offload_kv and cfg.ess.paged_host


def num_blocks(cfg: ArchConfig, max_seq: int) -> int:
    R = cfg.ess.host_page_rows
    return -(-max_seq // R)


def pages_for_len(cfg: ArchConfig, n_rows: int) -> int:
    """Host pages a sequence of ``n_rows`` latent rows pins."""
    return -(-n_rows // cfg.ess.host_page_rows)


def host_storage_dtype(cfg: ArchConfig, dtype=jnp.bfloat16):
    """(payload dtype, scale dtype | None) of the host latent tier."""
    name = cfg.ess.host_cache_dtype
    if name == "bf16":
        return dtype, None
    if name not in cmp.CACHE_QUANT_DTYPES:
        raise ValueError(f"unknown host_cache_dtype {name!r}; have "
                         f"bf16 | {sorted(cmp.CACHE_QUANT_DTYPES)}")
    return cmp.CACHE_QUANT_DTYPES[name], cmp.SCALE_DTYPE


def host_row_bytes(cfg: ArchConfig, dtype=jnp.bfloat16) -> int:
    """Host bytes one latent row pins (payload + per-row scale).

    This — not a row *count* — is what serve-loop admission budgets
    against: a quantized pool packs ~2x the rows into the same host RAM,
    and a byte-blind gate would let a mixed-precision deployment
    over-admit (see ``ServeSession._admission_gate``)."""
    qdt, sdt = host_storage_dtype(cfg, dtype)
    bytes_row = cfg.mla.latent_dim * jnp.dtype(qdt).itemsize
    if sdt is not None:
        bytes_row += jnp.dtype(sdt).itemsize
    return bytes_row


def host_page_bytes(cfg: ArchConfig, dtype=jnp.bfloat16) -> int:
    """Host bytes one page pins across all layers."""
    return cfg.num_layers * cfg.ess.host_page_rows * host_row_bytes(
        cfg, dtype)


def init_ess_caches(cfg: ArchConfig, batch: int, max_seq: int,
                    dtype=jnp.bfloat16, *, num_pages: int | None = None,
                    map_slots: bool = True) -> ESSCaches:
    """Build decode caches for ``batch`` slots of up to ``max_seq`` tokens.

    Paged host tier (default with ``cfg.ess.offload_kv``):

    * ``num_pages`` sizes the global pool; default ``batch * NB`` (capacity
      parity with the dense layout).  A serve loop passes fewer pages and
      gates admission on the free-page count.
    * ``map_slots=True`` pre-maps slot ``b`` onto the identity page range
      ``[b*NB, (b+1)*NB)`` — the drop-in layout for fixed-batch callers.
      ``map_slots=False`` starts with every block table unmapped; pages are
      assigned at admission (see :class:`HostPageAllocator` / `map_slot`).
    """
    Lh = cfg.num_layers
    D = cfg.mla.latent_dim
    Di = cfg.dsa.index_dim
    P = pool_entries(cfg, max_seq)
    paged = uses_paged_host(cfg)
    qdt, sdt = host_storage_dtype(cfg, dtype)

    block_tables = None
    host_scales = None
    if paged:
        R = cfg.ess.host_page_rows
        NB = num_blocks(cfg, max_seq)
        NP = batch * NB if num_pages is None else num_pages
        host = jnp.zeros((Lh, NP, R, D), qdt)
        host = offload.to_host(host, None, "cache_batch", None, None)
        if sdt is not None:
            host_scales = offload.to_host(
                jnp.zeros((Lh, NP, R, 1), sdt),
                None, "cache_batch", None, None)
        if map_slots:
            if NP < batch * NB:
                raise ValueError(
                    f"identity slot mapping needs {batch * NB} pages, "
                    f"pool has {NP}; pass map_slots=False and admit "
                    f"through a HostPageAllocator")
            block_tables = jnp.arange(batch * NB,
                                      dtype=jnp.int32).reshape(batch, NB)
        else:
            block_tables = jnp.full((batch, NB), -1, jnp.int32)
    else:
        host = jnp.zeros((Lh, batch, max_seq, D), qdt)
        host = offload.to_host(host, None, "batch", None, None) \
            if cfg.ess.offload_kv else host
        if sdt is not None:
            host_scales = jnp.zeros((Lh, batch, max_seq, 1), sdt)
            host_scales = offload.to_host(
                host_scales, None, "batch", None, None) \
                if cfg.ess.offload_kv else host_scales
    return ESSCaches(
        lens=jnp.zeros((batch,), jnp.int32),
        host_latent=host,
        ikeys=tuple(jnp.zeros((batch, max_seq, Di), dtype)
                    for _ in range(Lh)),
        pools=tuple(LP.init_pool(batch, P, max_seq, D, dtype)
                    for _ in range(Lh)),
        block_tables=block_tables,
        host_scales=host_scales,
    )


# ---------------------------------------------------------------------------
# Slot lifecycle (continuous batching)
# ---------------------------------------------------------------------------

def reset_slot(caches: ESSCaches, slot: int) -> ESSCaches:
    """Full per-slot cache reset for a recycled decode slot.

    Clears ``lens`` *and* every layer's pool maps (``ids`` / ``last_use`` /
    ``slot_of``).  Resetting only ``lens`` (the old preemption path) leaves
    stale pool entries behind: the recycled slot's next occupant would take
    pool *hits* on another request's latents.  Pool ``data`` rows become
    unreachable once the maps are cleared, so they are left in place (they
    are overwritten on admission).
    """
    pools = tuple(
        p._replace(ids=p.ids.at[slot].set(-1),
                   last_use=p.last_use.at[slot].set(-1),
                   slot_of=p.slot_of.at[slot].set(-1))
        for p in caches.pools)
    return caches._replace(lens=caches.lens.at[slot].set(0), pools=pools)


def map_slot(caches: ESSCaches, slot: int,
             pages: Sequence[int]) -> ESSCaches:
    """Install a slot's block table from an allocator's page list."""
    if caches.block_tables is None:
        return caches
    NB = caches.block_tables.shape[1]
    if len(pages) > NB:
        raise ValueError(f"{len(pages)} pages > {NB} blocks per slot")
    row = jnp.full((NB,), -1, jnp.int32).at[:len(pages)].set(
        jnp.asarray(list(pages), jnp.int32))
    return caches._replace(
        block_tables=caches.block_tables.at[slot].set(row))


def pages_owned_mask(block_tables: jax.Array, num_pages: int) -> jax.Array:
    """[NP] bool — physical pages mapped by *any* row of ``block_tables``.

    The TBO page-merge (:func:`repro.serving.tbo.merge_caches`) selects
    each half-batch's D2H writes out of the shared global page pool with
    this mask; slots own disjoint pages (allocator invariant), so the two
    halves' masks never overlap."""
    flat = block_tables.reshape(-1)
    return jnp.zeros((num_pages,), bool).at[
        jnp.where(flat >= 0, flat, num_pages)].set(True, mode="drop")


def unmap_slot(caches: ESSCaches, slot: int) -> ESSCaches:
    if caches.block_tables is None:
        return caches
    return caches._replace(
        block_tables=caches.block_tables.at[slot].set(-1))


class HostPageAllocator:
    """Host-side free-list for the global page pool (deterministic FIFO).

    The serve loop owns one of these; admission asks ``can_alloc`` (the
    free-page gate), maps the returned pages into the slot's block table,
    and ``release`` returns them when the slot finishes or is preempted.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: deque[int] = deque(range(num_pages))
        self._owned: dict[int, list[int]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, slot: int, n: int) -> list[int]:
        if not self.can_alloc(n):
            raise RuntimeError(f"allocator: want {n} pages, "
                               f"{len(self._free)} free")
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already owns pages")
        pages = [self._free.popleft() for _ in range(n)]
        self._owned[slot] = pages
        return pages

    def release(self, slot: int) -> list[int]:
        pages = self._owned.pop(slot, [])
        self._free.extend(pages)
        return pages

    def owned(self, slot: int) -> list[int]:
        """Pages currently owned by one slot, in allocation order (the
        slot's block-table prefix).  The PD handoff reads this host-side
        inventory to pack a migration without a device fetch."""
        return list(self._owned.get(slot, []))


# ---------------------------------------------------------------------------
# Paged <-> packed views
# ---------------------------------------------------------------------------

def slot_latents(caches: ESSCaches, slot: int, *,
                 use_kernel: bool = False) -> jax.Array:
    """All host-tier latent rows of one slot, packed ``[L, NB*R, D]``.

    Paged layout routes through the block table; ``use_kernel=True`` runs
    the Pallas ``gather_pages`` page-fetch kernel
    (:mod:`repro.kernels.gather_cache`) — the PagedAttention-style whole-page
    DMA — instead of the jnp reference.  Rows of unmapped pages are zero.
    """
    if caches.block_tables is None:
        out = caches.host_latent[:, slot]
        if caches.host_scales is not None:
            out = cmp.dequantize_rows(out, caches.host_scales[:, slot],
                                      jnp.bfloat16)
        return out
    Lh, NP, R, D = caches.host_latent.shape
    bt = caches.block_tables[slot]                       # [NB]
    NB = bt.shape[0]
    safe = jnp.clip(bt, 0, NP - 1)

    def page_gather(cache):
        d = cache.shape[-1]
        if use_kernel:
            from repro.kernels.gather_cache import ops as gops
            flat = cache.reshape(Lh, NP * R, d)
            return gops.gather_pages(flat, jnp.broadcast_to(safe, (Lh, NB)),
                                     R)
        return jnp.take(cache, safe, axis=1).reshape(Lh, NB * R, d)

    if caches.host_scales is not None and use_kernel:
        # fused page fetch + dequant: the compressed payload is DMA'd and
        # only the gathered pages are widened, inside the kernel
        from repro.kernels.gather_cache import ops as gops
        out = gops.gather_pages_dequant(
            caches.host_latent.reshape(Lh, NP * R, D),
            caches.host_scales.reshape(Lh, NP * R, 1),
            jnp.broadcast_to(safe, (Lh, NB)), R, jnp.bfloat16)
    else:
        out = page_gather(caches.host_latent)
        if caches.host_scales is not None:
            out = cmp.dequantize_rows(out, page_gather(caches.host_scales),
                                      jnp.bfloat16)
    valid = jnp.repeat(bt >= 0, R)                       # [NB*R]
    return jnp.where(valid[None, :, None], out, 0)


def graft_pool_into(full: LP.PoolState, one: LP.PoolState,
                    slot: int) -> LP.PoolState:
    """Install a batch-1 pool (donor prefill or per-slot warmup replay)
    as ``slot`` of a shared pool.

    The source's LRU stamps are clamped to the shared pool's clock so the
    recycled slot's entries do not look hotter than resident ones."""
    lu = jnp.minimum(one.last_use[0], full.step)
    lu = jnp.where(one.last_use[0] < 0, -1, lu)
    return full._replace(
        data=full.data.at[slot].set(one.data[0].astype(full.data.dtype)),
        ids=full.ids.at[slot].set(one.ids[0]),
        last_use=full.last_use.at[slot].set(lu),
        slot_of=full.slot_of.at[slot].set(one.slot_of[0]))


def graft_slot(caches: ESSCaches, slot: int, donor: ESSCaches,
               n_rows: int, *, use_kernel: bool = False) -> ESSCaches:
    """Copy ``donor``'s sequence 0 (a batch-1 prefill) into ``slot``.

    Compat shim for callers that still prefill into a detached donor
    cache.  The serve loop no longer routes admissions through here: its
    chunked prefill scatters each chunk's latents straight into the slot's
    mapped host pages (:func:`repro.serving.engine.ess_prefill_chunk`),
    avoiding this max_seq-sized intermediate + full-pool rewrite.

    Writes the first ``n_rows`` host-tier latent rows through the target
    slot's block table (paged) or batch row (dense), grafts the indexer
    cache and per-layer pool state, and sets ``lens[slot]``.  The target
    slot must already be mapped (serve-loop admission maps pages first).
    """
    rows = slot_latents(donor, 0, use_kernel=use_kernel)[:, :n_rows]
    ids = jnp.arange(n_rows, dtype=jnp.int32)[None]      # [1, n]
    host, scales = offload.scatter_tier_rows_stacked(
        caches.host_latent, caches.host_scales, ids, rows[:, None],
        slot_mask=None, batch_offset=slot,
        block_table=caches.block_tables)

    return caches._replace(
        lens=caches.lens.at[slot].set(n_rows),
        host_latent=host,
        host_scales=scales,
        ikeys=tuple(full.at[slot].set(one[0].astype(full.dtype))
                    for full, one in zip(caches.ikeys, donor.ikeys)),
        pools=tuple(graft_pool_into(fp, op, slot)
                    for fp, op in zip(caches.pools, donor.pools)))


def buffers_distinct(tree) -> bool:
    """True iff no two array leaves of ``tree`` share a device buffer.

    Donation-safety invariant for the compiled serve round: the
    StepPrograms donate the whole :class:`EngineState` pytree (caches
    included), and XLA can only alias each donated buffer into the
    output once — a buffer appearing under two leaves would silently
    fall back to a copy of the multi-GB host tier.  ``init_ess_caches``
    and the per-slot lifecycle updates keep every leaf distinct; tests
    assert it through this helper."""
    seen = set()
    for leaf in jax.tree.leaves(tree):
        ptr = getattr(leaf, "unsafe_buffer_pointer", None)
        if ptr is None:
            continue
        try:
            p = ptr()
        except Exception:       # deleted/donated or non-addressable leaf
            continue
        if p in seen:
            return False
        seen.add(p)
    return True


def abstract_ess_caches(cfg: ArchConfig, batch: int, max_seq: int,
                        dtype=jnp.bfloat16) -> ESSCaches:
    """ShapeDtypeStruct tree with host/device shardings (dry-run)."""
    Lh = cfg.num_layers
    D = cfg.mla.latent_dim
    Di = cfg.dsa.index_dim
    P = pool_entries(cfg, max_seq)
    paged = uses_paged_host(cfg)
    qdt, sdt = host_storage_dtype(cfg, dtype)

    ctx = shd.current()
    # cache shardings are pinned to explicit mesh axes (batch over the data
    # axes) independent of the activation rule profile — weights-stationary
    # profiles unmap the "batch" logical axis but the cache tier must stay
    # batch-parallel (same convention as launch/steps.annotate).
    if ctx is not None and ctx.mesh is not None:
        names = set(ctx.mesh.axis_names)
        data_axes = tuple(a for a in ("pod", "data") if a in names)
        batch_entry = data_axes if len(data_axes) > 1 else \
            (data_axes[0] if data_axes else None)
    else:
        batch_entry = None

    def dev(shape, dt, *axes):
        if ctx is None or ctx.mesh is None:
            return jax.ShapeDtypeStruct(shape, dt)
        from jax.sharding import PartitionSpec as P
        spec_axes = tuple(batch_entry if a == "batch" else None
                          for a in axes)
        spec = shd.prune_spec(P(*spec_axes), shape, ctx.mesh)
        return jax.ShapeDtypeStruct(
            shape, dt, sharding=jax.sharding.NamedSharding(ctx.mesh, spec))

    block_tables = None
    host_scales = None
    if paged:
        R = cfg.ess.host_page_rows
        NB = num_blocks(cfg, max_seq)
        # pages laid out batch-major, so sharding the page dim over the data
        # axes is the paged analogue of batch-sharding the dense tier
        host = offload.abstract_host((Lh, batch * NB, R, D), qdt,
                                     None, "cache_batch", None, None)
        if sdt is not None:
            host_scales = offload.abstract_host(
                (Lh, batch * NB, R, 1), sdt,
                None, "cache_batch", None, None)
        block_tables = dev((batch, NB), jnp.int32, "batch", None)
    elif cfg.ess.offload_kv:
        host = offload.abstract_host((Lh, batch, max_seq, D), qdt,
                                     None, "batch", None, None)
        if sdt is not None:
            host_scales = offload.abstract_host(
                (Lh, batch, max_seq, 1), sdt, None, "batch", None, None)
    else:
        host = dev((Lh, batch, max_seq, D), qdt,
                   None, "batch", None, None)
        if sdt is not None:
            host_scales = dev((Lh, batch, max_seq, 1), sdt,
                              None, "batch", None, None)
    pool = LP.PoolState(
        data=dev((batch, P, D), dtype, "batch", None, None),
        ids=dev((batch, P), jnp.int32, "batch", None),
        last_use=dev((batch, P), jnp.int32, "batch", None),
        slot_of=dev((batch, max_seq), jnp.int32, "batch", None),
        step=dev((), jnp.int32),
    )
    return ESSCaches(
        lens=dev((batch,), jnp.int32, "batch"),
        host_latent=host,
        ikeys=tuple(dev((batch, max_seq, Di), dtype, "batch", None, None)
                    for _ in range(Lh)),
        pools=tuple(pool for _ in range(Lh)),
        block_tables=block_tables,
        host_scales=host_scales,
    )
