"""ESS latent-cache state: host tier + device pools + indexer cache.

Layout (per model):

* ``host_latent [L, B, S, D]`` — the **Total Memory Pool** (paper Fig. 3),
  pinned host memory.  One buffer; layers index it inside the host
  computation (updates alias in place).
* ``ikeys``  — tuple of per-layer [B, S, Di] Indexer-Cache buffers, device
  HBM, never offloaded (16.8 % of cache bytes, fully read each step).
  Per-layer leaves (not a stacked array) so each decode layer touches only
  its own buffer — no full-stack copies in the unrolled step.
* ``pools``  — tuple of per-layer :class:`repro.core.lru_pool.PoolState`,
  the device-side **Sparse Memory Pool**.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import lru_pool as LP
from repro.core import offload
from repro.distributed import sharding as shd


class ESSCaches(NamedTuple):
    lens: jax.Array                    # [B]
    host_latent: jax.Array             # [L, B, S, D] (pinned_host w/ mesh)
    ikeys: tuple                       # L x [B, S, Di]
    pools: tuple                       # L x PoolState


def pool_entries(cfg: ArchConfig, max_seq: int) -> int:
    return LP.pool_entries_for(cfg.ess.sparse_memory_ratio, max_seq,
                               cfg.dsa.index_topk, cfg.ess.pool_min_entries)


def init_ess_caches(cfg: ArchConfig, batch: int, max_seq: int,
                    dtype=jnp.bfloat16) -> ESSCaches:
    Lh = cfg.num_layers
    D = cfg.mla.latent_dim
    Di = cfg.dsa.index_dim
    P = pool_entries(cfg, max_seq)
    host = jnp.zeros((Lh, batch, max_seq, D), dtype)
    host = offload.to_host(host, None, "batch", None, None) \
        if cfg.ess.offload_kv else host
    return ESSCaches(
        lens=jnp.zeros((batch,), jnp.int32),
        host_latent=host,
        ikeys=tuple(jnp.zeros((batch, max_seq, Di), dtype)
                    for _ in range(Lh)),
        pools=tuple(LP.init_pool(batch, P, max_seq, D, dtype)
                    for _ in range(Lh)),
    )


def abstract_ess_caches(cfg: ArchConfig, batch: int, max_seq: int,
                        dtype=jnp.bfloat16) -> ESSCaches:
    """ShapeDtypeStruct tree with host/device shardings (dry-run)."""
    Lh = cfg.num_layers
    D = cfg.mla.latent_dim
    Di = cfg.dsa.index_dim
    P = pool_entries(cfg, max_seq)

    ctx = shd.current()
    # cache shardings are pinned to explicit mesh axes (batch over the data
    # axes) independent of the activation rule profile — weights-stationary
    # profiles unmap the "batch" logical axis but the cache tier must stay
    # batch-parallel (same convention as launch/steps.annotate).
    if ctx is not None and ctx.mesh is not None:
        names = set(ctx.mesh.axis_names)
        data_axes = tuple(a for a in ("pod", "data") if a in names)
        batch_entry = data_axes if len(data_axes) > 1 else \
            (data_axes[0] if data_axes else None)
    else:
        batch_entry = None

    def dev(shape, dt, *axes):
        if ctx is None or ctx.mesh is None:
            return jax.ShapeDtypeStruct(shape, dt)
        from jax.sharding import PartitionSpec as P
        spec_axes = tuple(batch_entry if a == "batch" else None
                          for a in axes)
        spec = shd.prune_spec(P(*spec_axes), shape, ctx.mesh)
        return jax.ShapeDtypeStruct(
            shape, dt, sharding=jax.sharding.NamedSharding(ctx.mesh, spec))

    host = offload.abstract_host((Lh, batch, max_seq, D), dtype,
                                 None, "batch", None, None) \
        if cfg.ess.offload_kv else dev((Lh, batch, max_seq, D), dtype,
                                       None, "batch", None, None)
    pool = LP.PoolState(
        data=dev((batch, P, D), dtype, "batch", None, None),
        ids=dev((batch, P), jnp.int32, "batch", None),
        last_use=dev((batch, P), jnp.int32, "batch", None),
        slot_of=dev((batch, max_seq), jnp.int32, "batch", None),
        step=dev((), jnp.int32),
    )
    return ESSCaches(
        lens=dev((batch,), jnp.int32, "batch"),
        host_latent=host,
        ikeys=tuple(dev((batch, max_seq, Di), dtype, "batch", None, None)
                    for _ in range(Lh)),
        pools=tuple(pool for _ in range(Lh)),
    )
