"""Logical-axis sharding rules + activation-constraint context.

Model code never names mesh axes directly.  It calls ``shard(x, "batch",
None, "embed")`` with *logical* axes; the active :class:`ShardingCtx`
(a context manager installed by the launcher / dry-run) maps those to mesh
axes and applies ``with_sharding_constraint``.  Outside any context this is
an exact no-op, so unit tests and CPU smoke tests never touch device state.

Two built-in rule profiles:

* ``tp``  — tensor-parallel weights over ``model``; weights replicated over
  ``data``; activations batch-sharded over (``pod``, ``data``).
* ``2d``  — additionally shards the non-TP weight dim over ``data``
  (FSDP/ZeRO-3 style weight gathering, needed for >=100B params on 16 GB
  chips).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.params import axes_to_pspec

_STATE = threading.local()


def shard_map_compat(f, *, mesh, in_specs, out_specs,
                     check_replication: bool = False):
    """``shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map(..., check_vma=...)``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.  Both flags
    mean "verify replication of unmapped outputs" — callers here always pass
    manually-merged outputs, so the default disables the check.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs,
                                 check_vma=check_replication)
        except TypeError:  # pragma: no cover - future flag renames
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_replication)


# Logical activation axes: batch, seq (sequence-parallel for long ctx),
# heads/kv/ff/embed/vocab/experts follow the parameter logical axes.
def rules_tp(multi_pod: bool, *, seq_data: bool = False) -> dict[str, Any]:
    data = ("pod", "data") if multi_pod else ("data",)
    r = {
        "batch": data, "heads": "model", "kv": "model", "ff": "model",
        "vocab": "model", "experts": "model",
        # cache-tier batch axis: never unmapped (weights-stationary
        # profiles unmap "batch" for activations, but caches/host tiers
        # must stay batch-parallel)
        "cache_batch": data,
        # Megatron-style sequence parallelism: the residual stream between
        # blocks shards its seq dim over the model axis (all-gather before
        # attention/mlp, reduce-scatter after — inserted by the partitioner)
        "seq_sp": "model",
        # per-head dims / embed stay unsharded for tp profile
    }
    if seq_data:
        # long-context: batch too small to shard -> shard sequence over data
        r["seq"] = data
        r["batch"] = None
    return r


def rules_2d(multi_pod: bool, *, seq_data: bool = False) -> dict[str, Any]:
    r = rules_tp(multi_pod, seq_data=seq_data)
    data = ("pod", "data") if multi_pod else ("data",)
    # FSDP-style: shard the "long" replicated weight dims over the data axis.
    r.update({"embed": data, "ff2": "model"})
    return r


def rules_2d_ws(multi_pod: bool, *, seq_data: bool = False) -> dict[str, Any]:
    """Weights-stationary decode variant of ``2d``.

    Decode moves ~KB of activations but the ``2d`` profile's weight
    gathers move GBs per step.  Mapping the *activation* hidden dim onto
    the data axis aligns activations with the weights' data-sharded
    contraction dim, so matmuls run where the weights live and only tiny
    activation partial-sums cross the network (§Perf iteration 1).
    Batch stays on the data axis for cache-side ops (attention); XLA
    inserts the cheap activation reshards between the two regimes.
    """
    r = rules_2d(multi_pod, seq_data=seq_data)
    data = ("pod", "data") if multi_pod else ("data",)
    # activations vacate the data axis for their hidden dim (weights-
    # stationary); caches keep batch over data via their explicit
    # mesh-axis annotations in launch/steps.py, so attention stays
    # batch-parallel while matmuls stay weight-local.
    r["batch"] = None
    r["embed_act"] = data
    return r


PROFILES = {"tp": rules_tp, "2d": rules_2d, "2d_ws": rules_2d_ws}


def prune_spec(spec: P, shape: tuple[int, ...],
               mesh: jax.sharding.Mesh) -> P:
    """Drop mesh axes whose product doesn't divide the dim size (e.g. 8 kv
    heads on a 16-wide model axis): keeps the largest divisible prefix."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for d, entry in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep: list[str] = []
        prod = 1
        for a in axes:
            if shape[d] % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


class ShardingCtx:
    def __init__(self, mesh: jax.sharding.Mesh, rules: dict[str, Any]):
        self.mesh = mesh
        self.rules = dict(rules)

    def pspec(self, *axes: str | None) -> P:
        return axes_to_pspec(axes, self.rules)

    def sharding(self, *axes: str | None, memory_kind: str | None = None) -> NamedSharding:
        kw = {"memory_kind": memory_kind} if memory_kind else {}
        return NamedSharding(self.mesh, self.pspec(*axes), **kw)

    def sharding_for(self, shape: tuple[int, ...], axes,
                     memory_kind: str | None = None) -> NamedSharding:
        """Shape-aware: prunes mesh axes that don't divide the dims."""
        spec = prune_spec(self.pspec(*axes), shape, self.mesh)
        kw = {"memory_kind": memory_kind} if memory_kind else {}
        return NamedSharding(self.mesh, spec, **kw)


def current() -> ShardingCtx | None:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: jax.sharding.Mesh | None, rules: dict[str, Any] | None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ShardingCtx(mesh, rules) if mesh is not None else None
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain activation sharding by logical axes; no-op w/o context."""
    ctx = current()
    if ctx is None or ctx.mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): {len(axes)} axes for rank-{x.ndim} array")
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding_for(x.shape, axes))


def logical_axis_size(name: str) -> int:
    """Product of mesh-axis sizes the logical axis maps to (1 w/o ctx)."""
    ctx = current()
    if ctx is None or ctx.mesh is None:
        return 1
    r = ctx.rules.get(name)
    if r is None:
        return 1
    axes = r if isinstance(r, tuple) else (r,)
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def logical_sharding(*axes, memory_kind: str | None = None):
    """NamedSharding for the current ctx (None outside a context)."""
    ctx = current()
    if ctx is None or ctx.mesh is None:
        return None
    return ctx.sharding(*axes, memory_kind=memory_kind)
