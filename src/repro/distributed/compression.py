"""Gradient compression for the DP all-reduce (int8 + error feedback),
and the row-wise reference quantizer for the ESS quantized latent tier.

At multi-pod scale the data-parallel gradient all-reduce crosses the slow
pod interconnect; 8-bit quantization cuts that traffic 4x (vs fp32
moments) / 2x (vs bf16).  Error feedback keeps the quantization noise from
biasing convergence: the residual of each round is added back before the
next quantization (Seide et al.; 1-bit Adam lineage).

The same symmetric-quantization idiom, applied per *row* instead of per
tensor, is what the offloaded latent cache tier stores
(:mod:`repro.core.offload`): each host page of ``R`` latent rows carries an
``R``-vector of scales, so a page moves as ``R*D`` one-byte payload values
plus ``R`` half-precision scales — ~0.53x the bf16 bytes — and dequantizes
at miss width on device.  :func:`quantize_rows` / :func:`dequantize_rows`
are exact inverses of each other's grid: dequantizing a quantized row uses
the *stored* (rounded-to-``SCALE_DTYPE``) scale, so the absolute error per
element is bounded by ``scale/2`` (int8) and an all-zero row round-trips to
exactly zero (sentinel rows stay sentinel).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any     # same structure as grads, fp32


def init_ef(params: Any) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Row-wise cache-tier quantization (quantized latent pages)
# ---------------------------------------------------------------------------

# Scales are stored half precision: 2 bytes/row next to D one-byte payload
# bytes keeps the quantized row at (D+2)/(2D) of its bf16 size.  The scale
# used for dequantization is the *stored* one, so quant/dequant share one
# grid regardless of the rounding this cast introduces.
SCALE_DTYPE = jnp.float16

_FP8 = getattr(jnp, "float8_e4m3fn", None)

#: name -> storage dtype of the quantized cache tier
#: (``ESSOptions.host_cache_dtype``); "bf16" means no quantization.
CACHE_QUANT_DTYPES: dict[str, Any] = {"int8": jnp.int8}
if _FP8 is not None:
    CACHE_QUANT_DTYPES["fp8"] = _FP8


def quant_max(dtype) -> float:
    """Largest representable magnitude of a quantized storage dtype."""
    return 127.0 if jnp.dtype(dtype) == jnp.int8 else 448.0   # e4m3fn max


def quantize_rows(x: jax.Array, dtype=jnp.int8
                  ) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric quantization over the trailing axis.

    Returns ``(q [..., D] dtype, scale [..., 1] SCALE_DTYPE)``.  All-zero
    rows get ``scale == 0`` and ``q == 0`` (the guard keeps the division
    finite), so sentinel/empty cache rows survive the round trip exactly.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = (amax / quant_max(dtype)).astype(SCALE_DTYPE)
    s = scale.astype(jnp.float32)
    y = xf / jnp.where(s > 0, s, 1.0)
    if jnp.dtype(dtype) == jnp.int8:
        q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    else:
        m = quant_max(dtype)
        q = jnp.clip(y, -m, m).astype(dtype)
    return q, scale


def dequantize_rows(q: jax.Array, scale: jax.Array,
                    out_dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_rows`; ``scale`` broadcasts over the
    trailing axis (``[..., 1]``)."""
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)).astype(out_dtype)


def wire_nbytes(*arrays) -> int:
    """Total bytes a set of planes occupies on an inter-node wire.

    The quantized storage representation IS the wire codec: a PD
    migration ships host pages in their storage dtype (int8/fp8 payload
    plus the f16 per-row scale plane) verbatim — never dequantized — so
    the wire cost is just the sum of the planes' nbytes.  ``None``
    entries (e.g. the scale plane of a raw bf16 tier) cost nothing."""
    return sum(int(a.nbytes) for a in arrays if a is not None)


def compress_grads(grads: Any, ef: EFState) -> tuple[Any, Any, EFState]:
    """-> (q_tree int8, scale_tree, new error-feedback state).

    The caller all-reduces the int8 payloads (mean of dequantized values —
    in pjit-land the all-reduce is implicit: reduce the *dequantized*
    values so XLA emits the collective on the small int8 tensors when it
    can, or apply in shard_map for explicit control).
    """
    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, s = quantize_int8(v)
        new_r = v - dequantize_int8(q, s)
        return (q, s, new_r)

    trip = jax.tree.map(one, grads, ef.residual,
                        is_leaf=lambda x: isinstance(x, jax.Array))
    q = jax.tree.map(lambda t: t[0], trip,
                     is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], trip,
                     is_leaf=lambda t: isinstance(t, tuple))
    r = jax.tree.map(lambda t: t[2], trip,
                     is_leaf=lambda t: isinstance(t, tuple))
    return q, s, EFState(r)


def decompress_grads(q: Any, s: Any) -> Any:
    return jax.tree.map(dequantize_int8, q, s)


def compression_error(grads: Any, ef: EFState) -> jax.Array:
    """Diagnostic: relative L2 error of one quantize/dequantize round."""
    q, s, _ = compress_grads(grads, ef)
    deq = decompress_grads(q, s)
    num = jax.tree.map(lambda a, b: jnp.sum((a.astype(jnp.float32) - b) ** 2),
                       grads, deq)
    den = jax.tree.map(lambda a: jnp.sum(a.astype(jnp.float32) ** 2), grads)
    tot_n = sum(jax.tree.leaves(num))
    tot_d = sum(jax.tree.leaves(den)) + 1e-12
    return jnp.sqrt(tot_n / tot_d)
