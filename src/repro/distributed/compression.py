"""Gradient compression for the DP all-reduce (int8 + error feedback).

At multi-pod scale the data-parallel gradient all-reduce crosses the slow
pod interconnect; 8-bit quantization cuts that traffic 4x (vs fp32
moments) / 2x (vs bf16).  Error feedback keeps the quantization noise from
biasing convergence: the residual of each round is added back before the
next quantization (Seide et al.; 1-bit Adam lineage).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any     # same structure as grads, fp32


def init_ef(params: Any) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, ef: EFState) -> tuple[Any, Any, EFState]:
    """-> (q_tree int8, scale_tree, new error-feedback state).

    The caller all-reduces the int8 payloads (mean of dequantized values —
    in pjit-land the all-reduce is implicit: reduce the *dequantized*
    values so XLA emits the collective on the small int8 tensors when it
    can, or apply in shard_map for explicit control).
    """
    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, s = quantize_int8(v)
        new_r = v - dequantize_int8(q, s)
        return (q, s, new_r)

    trip = jax.tree.map(one, grads, ef.residual,
                        is_leaf=lambda x: isinstance(x, jax.Array))
    q = jax.tree.map(lambda t: t[0], trip,
                     is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], trip,
                     is_leaf=lambda t: isinstance(t, tuple))
    r = jax.tree.map(lambda t: t[2], trip,
                     is_leaf=lambda t: isinstance(t, tuple))
    return q, s, EFState(r)


def decompress_grads(q: Any, s: Any) -> Any:
    return jax.tree.map(dequantize_int8, q, s)


def compression_error(grads: Any, ef: EFState) -> jax.Array:
    """Diagnostic: relative L2 error of one quantize/dequantize round."""
    q, s, _ = compress_grads(grads, ef)
    deq = decompress_grads(q, s)
    num = jax.tree.map(lambda a, b: jnp.sum((a.astype(jnp.float32) - b) ** 2),
                       grads, deq)
    den = jax.tree.map(lambda a: jnp.sum(a.astype(jnp.float32) ** 2), grads)
    tot_n = sum(jax.tree.leaves(num))
    tot_d = sum(jax.tree.leaves(den)) + 1e-12
    return jnp.sqrt(tot_n / tot_d)
