"""Distributed attention helpers: exact cross-shard flash-decode merge.

For long_500k the cache sequence dim is sharded; each shard computes a
flash partial over its local chunk and the merge is an exact psum-style
renormalization — the distributed analogue of ESS's Attn0/Attn1 merge.
Used by shard_map-based serving variants and validated in tests against
the single-device oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def local_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                  valid: jax.Array, scale: float):
    """One shard's flash statistics. q [B,H,D], k/v [B,Sl,D], valid [B,Sl].
    Returns (o [B,H,Dv], m [B,H], l [B,H]) unnormalized."""
    s = jnp.einsum("bhd,bsd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.where(valid[:, None, :], jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bhs,bsd->bhd", p, v.astype(jnp.float32))
    return o, m, l


def merge_across(axis: str, o: jax.Array, m: jax.Array, l: jax.Array
                 ) -> jax.Array:
    """Exact renormalized merge over a mesh axis (inside shard_map)."""
    m_max = jax.lax.pmax(m, axis)
    corr = jnp.exp(m - m_max)
    o_sum = jax.lax.psum(o * corr[..., None], axis)
    l_sum = jax.lax.psum(l * corr, axis)
    return o_sum / jnp.maximum(l_sum, 1e-30)[..., None]


def sharded_flash_decode(mesh, axis: str, q, k_sharded, v_sharded, valid,
                         scale: float):
    """shard_map wrapper: q replicated, k/v/valid sharded on seq."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    def prog(qq, kk, vv, vd):
        o, m, l = local_partial(qq, kk, vv, vd, scale)
        return merge_across(axis, o, m, l)

    return shard_map_compat(
        prog, mesh=mesh,
        in_specs=(P(), P(None, axis, None), P(None, axis, None),
                  P(None, axis)),
        out_specs=P())(q, k_sharded, v_sharded, valid)
