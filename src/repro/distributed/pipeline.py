"""GPipe-style pipeline parallelism over the ``pod`` axis (shard_map).

For multi-pod training where the pod interconnect (DCN) is much slower
than ICI, pipelining the *layer stack* across pods trades the per-step DP
all-reduce over DCN for thin ``collective_permute`` activations between
stage boundaries.

Schedule: GPipe with M microbatches — stage s processes microbatch m at
tick t = s + m; bubbles = (S-1)/(M+S-1).  Implemented as a lax.scan over
ticks inside shard_map; every stage runs the same program (SPMD) with its
own stage slice of the stacked layer params.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(layer_fn: Callable, stacked_params, x: jax.Array,
                   mesh: jax.sharding.Mesh, *, axis: str = "pod",
                   microbatches: int = 4) -> jax.Array:
    """Run layers split into ``n_stages = size(axis)`` contiguous stages.

    layer_fn(layer_params, x_micro) -> x_micro; stacked_params leaves are
    [L, ...] with L % n_stages == 0; x [B, ...] with B % microbatches == 0.
    """
    n_stages = mesh.devices.shape[list(mesh.axis_names).index(axis)]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    B = x.shape[0]
    assert B % microbatches == 0

    def stage_program(sparams, xin):
        # sparams: this stage's [L/n_stages, ...] slice; xin [1, B, ...]
        idx = jax.lax.axis_index(axis)
        xin = xin[0]
        mb = xin.reshape((microbatches, B // microbatches) + xin.shape[1:])
        n_ticks = microbatches + n_stages - 1

        def run_stage(xm):
            def body(c, lp):
                return layer_fn(lp, c), None
            out, _ = jax.lax.scan(body, xm, sparams)
            return out

        def tick(carry, t):
            buf_in, out_buf = carry
            m = t - idx                      # microbatch this stage works on
            active = (m >= 0) & (m < microbatches)
            mc = jnp.clip(m, 0, microbatches - 1)
            xm = jax.lax.dynamic_index_in_dim(buf_in, mc, 0, keepdims=False)
            ym = run_stage(xm)
            ym = jnp.where(active, ym, xm)
            # last stage collects finals; others ship downstream
            out_buf = jnp.where(
                active & (idx == n_stages - 1),
                jax.lax.dynamic_update_index_in_dim(out_buf, ym, mc, 0),
                out_buf)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            recv = jax.lax.ppermute(ym, axis, perm)
            # receiver (stage idx) stores the message from stage idx-1,
            # which just finished microbatch m_prev = t - (idx - 1)
            m_prev = t - idx + 1
            ok = (idx > 0) & (m_prev >= 0) & (m_prev < microbatches)
            mp = jnp.clip(m_prev, 0, microbatches - 1)
            buf_in = jnp.where(
                ok, jax.lax.dynamic_update_index_in_dim(buf_in, recv, mp, 0),
                buf_in)
            return (buf_in, out_buf), None

        (_, out_buf), _ = jax.lax.scan(
            tick, (mb, jnp.zeros_like(mb)), jnp.arange(n_ticks))
        # broadcast the last stage's collected outputs to every stage
        mine = jnp.where(idx == n_stages - 1, out_buf,
                         jnp.zeros_like(out_buf))
        final = jax.lax.psum(mine, axis)
        return final.reshape((1, B) + x.shape[1:])

    from repro.distributed.sharding import shard_map_compat

    spec_p = jax.tree.map(lambda _: P(axis), stacked_params)
    fn = shard_map_compat(
        stage_program, mesh=mesh,
        in_specs=(spec_p, P(axis)), out_specs=P(axis))
    # replicate x to every stage's input slot (stage 0 uses it; others churn)
    xin = jnp.broadcast_to(x[None], (n_stages,) + x.shape)
    return fn(stacked_params, xin)[0]
