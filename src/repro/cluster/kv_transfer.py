"""Page-granular latent handoff between PD-disaggregated workers.

The paper's Figure 3 draws prefill and decode as *separate* node pools
connected by a "Load" arrow into the Total Memory Pool: a prompt is
prefilled on a bandwidth-rich prefill worker, then its cache state
migrates to a decode worker that owns the request for the rest of its
lifetime.  This module is that arrow.

A migration moves everything the decode round needs, at page
granularity, **in the host tier's storage dtype** (the quantized
representation is the wire codec — int8/fp8 payload + f16 per-row scale
plane travel verbatim, never dequantized/requantized, so the decode
worker's host rows are bit-identical to the prefill worker's):

* the slot's mapped host pages ``[L, n_used, R, D]`` and, on a
  quantized tier, their scale plane ``[L, n_used, R, 1]``,
* the device indexer-cache keys ``[plen, Di]`` per layer (the 16.8 % of
  cache bytes that never offloads — it must travel for the decode
  worker's Top-K selection to be exact),
* the first token (computed in-device by the prefill program's
  promotion) and the post-final-norm hidden (the MTP draft seed),
* optionally the LRU-warmup tails, so a ``do_warmup`` deployment
  replays the Sparse-Memory-Pool warmup on the *decode* side, where the
  pool lives.

**One-pack contract (ESS107)**: :func:`pack_migration` performs exactly
one ``jax.device_get`` — the allowlisted pack site
(:data:`repro.analysis.contracts.PACK_SITE`).  The page inventory comes
from the host-side allocator (``HostPageAllocator.owned``), so the pack
never fetches to discover what to move; install performs *zero* fetches
(the first token rides the packet) and rewrites the pages through a
fresh block-table mapping — physical page ids are worker-local, the
block table is the remap.

Correctness note (why migration preserves bitwise streams): at
promotion a compiled-path slot's Sparse Memory Pool is empty, and the
decode round's per-slot compute (DSA selection, pool lookups, sampling
chain) depends only on ``lens``/pages/scales/ikeys/``tok``/``hidden``
and the request's own sampling knobs — all of which travel.  Rows past
``plen`` inside the last page are beyond the attention horizon and
never read.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.cache import latent_cache as LC
from repro.distributed import compression as cmp
from repro.serving import state as ES
from repro.serving.scheduler import Request


@dataclasses.dataclass
class MigrationPacket:
    """One migrated request: the prefill worker's page-granular snapshot
    of everything the decode round consumes, host-resident (numpy), in
    the tier's storage dtype."""
    rid: int
    prompt_len: int
    req: Request               # the live Request object travels with it
    n_pages: int               # host pages actually carrying prompt rows
    pages: "object"            # [L, n_pages, R, D] storage dtype
    scales: Optional["object"]  # [L, n_pages, R, 1] | None (bf16 tier)
    ikeys: tuple               # L x [plen, Di]
    t0: int                    # first token (promotion output)
    hidden: "object"           # [d_model] MTP draft seed
    tails: Optional[tuple] = None   # LRU-warmup replay input (do_warmup)
    submit_time: Optional[float] = None

    @property
    def wire_bytes(self) -> int:
        """Bytes on the inter-node wire (storage dtype == wire codec)."""
        return cmp.wire_nbytes(self.pages, self.scales, self.hidden,
                               *self.ikeys)


def pack_migration(session, slot: int, req: Request, t0, *,
                   tails: Optional[tuple] = None,
                   submit_time: Optional[float] = None) -> MigrationPacket:
    """Serialize one promoted slot into a :class:`MigrationPacket`.

    ``t0`` is the promotion's first token — a device scalar on the
    compiled path (it rides the pack's fetch), a host int on the legacy
    warmup path.  The single ``jax.device_get`` below is the ESS107
    pack site: pages + scale plane + indexer keys + hidden + t0 in one
    packed fetch, page ids resolved host-side from the allocator."""
    if session.allocator is None:
        raise ValueError("PD migration needs the paged host tier "
                         "(cfg.ess.offload_kv + paged_host)")
    cfg = session.cfg
    plen = req.prompt_len
    n_used = LC.pages_for_len(cfg, plen)
    page_ids = session.allocator.owned(slot)[:n_used]
    assert len(page_ids) == n_used, \
        f"slot {slot} owns {len(page_ids)} pages, prompt needs {n_used}"
    caches = session.caches
    ids = jnp.asarray(page_ids, jnp.int32)
    scale_plane = () if caches.host_scales is None \
        else (caches.host_scales[:, ids],)
    pages, scales, ikeys, hidden, t0_h = jax.device_get(
        (caches.host_latent[:, ids], scale_plane,
         tuple(k[slot, :plen] for k in caches.ikeys),
         session.state.hidden[slot], t0))
    return MigrationPacket(
        rid=req.rid, prompt_len=plen, req=req, n_pages=n_used,
        pages=pages, scales=scales[0] if scales else None,
        ikeys=ikeys, t0=int(t0_h), hidden=hidden, tails=tails,
        submit_time=submit_time)


def can_accept(session, req: Request) -> bool:
    """Would ``install_migration`` succeed on this session *right now*?
    Mirrors the admission gate: a free slot, a pool-entry reservation,
    and enough free host pages for prompt + max_new rows."""
    if not any(not s.active for s in session.sched.slots):
        return False
    if req.prompt_len + req.max_new_tokens > session.sched.max_seq:
        return False
    if session.free_pool_entries < session.pool_entries_per_slot:
        return False
    if session.allocator is not None \
            and not session.allocator.can_alloc(session.pages_needed(req)):
        return False
    return True


def install_migration(session, packet: MigrationPacket) -> int:
    """Install a migrated request into a free slot of ``session``.

    Allocates fresh pages (the block-table remap: physical ids are
    worker-local), scatters the packet's pages and scale plane **raw**
    — storage-dtype bits land verbatim, no dequant/requant round trip —
    restores lens/ikeys, adopts the request in the ``decode`` phase, and
    delivers the first token (stop/length at t0 finish immediately,
    mirroring the single-engine promotion edge).  Zero device fetches.
    Returns the slot."""
    req = packet.req
    if session.allocator is None:
        raise ValueError("PD migration needs the paged host tier")
    assert can_accept(session, req), \
        f"install_migration: rid={req.rid} does not fit (route first)"
    slot = next(i for i, s in enumerate(session.sched.slots) if not s.active)
    plen = packet.prompt_len

    pages = session.allocator.alloc(slot, session.pages_needed(req))
    caches = LC.map_slot(session.caches, slot, pages)
    new_ids = jnp.asarray(pages[:packet.n_pages], jnp.int32)
    host = caches.host_latent.at[:, new_ids].set(
        jnp.asarray(packet.pages, caches.host_latent.dtype))
    host_scales = caches.host_scales
    if host_scales is not None:
        assert packet.scales is not None, \
            "quantized tier but the packet carries no scale plane"
        host_scales = host_scales.at[:, new_ids].set(
            jnp.asarray(packet.scales, host_scales.dtype))
    session.caches = caches._replace(
        host_latent=host, host_scales=host_scales,
        lens=caches.lens.at[slot].set(plen),
        ikeys=tuple(k.at[slot, :plen].set(jnp.asarray(ik, k.dtype))
                    for k, ik in zip(caches.ikeys, packet.ikeys)))
    session.free_pool_entries -= session.pool_entries_per_slot
    session._sample_pages()

    session.sched.adopt(req, slot)
    session._submit_round[req.rid] = session._round
    if packet.submit_time is not None:
        session._submit_time[req.rid] = packet.submit_time
    else:
        session._submit_time.setdefault(req.rid, time.perf_counter())
    session.outputs[req.rid] = []
    session._rounds_since_promote[slot] = 0
    session.state = ES.admit_slot(session.state, slot, req)
    session.state = ES.promote_slot(session.state, slot, packet.t0,
                                    jnp.asarray(packet.hidden))
    if session.do_warmup and packet.tails is not None:
        # the Sparse Memory Pool lives with decode: replay the prefill
        # worker's shipped warmup tails into this worker's pool
        session._warmup_slot(slot,
                             tuple(jnp.asarray(t) for t in packet.tails),
                             plen)
    session.report.events.append(
        f"round {session._round}: rid={req.rid} installed via PD handoff "
        f"(slot {slot}, {packet.n_pages} pages, {packet.wire_bytes} B)")
    done = session._deliver_first_token(slot, req, packet.t0)
    if done == "stop":
        session._handle_done([session.sched.finish(slot)])
    elif done == "length":
        session._handle_done(session.sched.record_tokens({slot: 0}))
    return slot


class InterNodeChannel:
    """Simulated inter-node fabric between prefill and decode workers.

    Deterministic step-granular delivery: a packet sent at cluster step
    ``t`` arrives at ``t + delay`` where ``delay`` either is the fixed
    ``delay_steps`` or derives from a cost model
    (:class:`repro.simulator.costmodel.InterNodeModel`: ``latency_s +
    wire_bytes / bandwidth`` quantized to serve steps of
    ``step_time_s``).  Delivery order is stable (send order within an
    arrival step), so cluster runs replay identically.  ``cancel``
    drops an in-flight migration (client abort mid-handoff) — the
    prefill side already freed its pages at pack, the decode side never
    saw the request."""

    def __init__(self, *, delay_steps: int = 0, model=None,
                 step_time_s: Optional[float] = None):
        self.delay_steps = max(0, int(delay_steps))
        self.model = model
        self.step_time_s = step_time_s
        self._now = 0
        self._inflight: list[tuple[int, int, MigrationPacket]] = []
        self._seq = 0
        self.packets_sent = 0
        self.payload_bytes = 0
        self.sim_transfer_s = 0.0

    @property
    def in_flight(self) -> list[MigrationPacket]:
        return [p for _, _, p in self._inflight]

    def delay_for(self, packet: MigrationPacket) -> int:
        if self.model is not None and self.step_time_s:
            t = self.model.latency_s + packet.wire_bytes / self.model.bandwidth
            return max(1, math.ceil(t / self.step_time_s))
        return self.delay_steps

    def send(self, packet: MigrationPacket) -> int:
        """Enqueue a migration; returns the cluster step it will arrive."""
        delay = self.delay_for(packet)
        if self.model is not None:
            self.sim_transfer_s += (self.model.latency_s
                                    + packet.wire_bytes / self.model.bandwidth)
        arrive = self._now + delay
        self._inflight.append((arrive, self._seq, packet))
        self._seq += 1
        self.packets_sent += 1
        self.payload_bytes += packet.wire_bytes
        return arrive

    def tick(self) -> list[MigrationPacket]:
        """Advance one cluster step; returns packets arriving now (in
        send order)."""
        self._now += 1
        ready = sorted((e for e in self._inflight if e[0] <= self._now),
                       key=lambda e: e[1])
        self._inflight = [e for e in self._inflight if e[0] > self._now]
        return [p for _, _, p in ready]

    def cancel(self, rid: int) -> list[MigrationPacket]:
        """Drop in-flight packets of one rid (abort mid-handoff)."""
        dropped = [p for _, _, p in self._inflight if p.rid == rid]
        self._inflight = [e for e in self._inflight if e[2].rid != rid]
        return dropped
