"""PD-disaggregated serving cluster (paper Fig. 3's cross-node "Load").

:class:`EssCluster` is the multi-node drop-in for
:class:`repro.serving.api.EssEngine`; :mod:`kv_transfer` is the
page-granular latent handoff; :mod:`workers` and :mod:`router` are the
prefill/decode halves and the placement policy.
"""

from repro.cluster.cluster import EssCluster
from repro.cluster.kv_transfer import (InterNodeChannel, MigrationPacket,
                                       can_accept, install_migration,
                                       pack_migration)
from repro.cluster.router import Router
from repro.cluster.workers import DecodeWorker, PrefillWorker

__all__ = [
    "EssCluster", "InterNodeChannel", "MigrationPacket", "Router",
    "PrefillWorker", "DecodeWorker", "pack_migration", "install_migration",
    "can_accept",
]
