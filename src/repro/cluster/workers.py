"""Prefill and decode workers of a PD-disaggregated serving cluster.

Each worker wraps one :class:`repro.serving.engine.ServeSession` — the
same re-entrant round core the single-node engine drives — but runs only
its half of the request lifecycle:

* :class:`PrefillWorker` admits requests and streams their prompts
  through chunked prefill into its *local* paged host tier.  When a
  slot promotes, instead of decoding it the worker packs the slot into
  a :class:`~repro.cluster.kv_transfer.MigrationPacket` (one fetch —
  the ESS107 pack site) and releases the slot's resources via
  ``Scheduler.release_migrated`` — the slot recycles immediately for
  the next prompt, which is the whole point of disaggregation: prefill
  capacity is never held hostage by decode lifetimes.
* :class:`DecodeWorker` installs arriving packets
  (:func:`~repro.cluster.kv_transfer.install_migration`: block-table
  remap + raw page scatter) and runs the ordinary compiled decode /
  MTP-verify round loop.  Preemption inside a decode worker requeues
  locally and re-prefills *locally* (its session has the cluster's
  prompt_fn), exactly like the single-node path.

Both take ``session_cls`` so the audit layer can inject instrumented
sessions (the ESS107 sabotage test smuggles a fetch into a decode round
through this hook).
"""

from __future__ import annotations

from typing import Optional

from repro.cluster import kv_transfer as KT
from repro.serving import engine as E
from repro.serving.scheduler import Request, WorkerLoad


class PrefillSessionMixin:
    """Session overrides for the prefill side of a PD split.

    * ``do_warmup`` sessions do **not** replay LRU-warmup locally — the
      Sparse Memory Pool lives with decode, so the tails are stashed
      and shipped in the packet instead;
    * the legacy warmup promotion path defers its host-resolved first
      token into ``_pending_first`` (like the compiled path does with
      the device scalar) so pack/install own delivery — a request that
      stops at its first token still migrates and finishes on the
      decode side, one code path.
    """

    def _warmup_slot(self, slot, tails, prompt_len):
        if not hasattr(self, "migration_tails"):
            self.migration_tails = {}
        self.migration_tails[slot] = tails

    def _finish_prefill(self, slot, task, t0):
        req = task.req
        self.sched.promote(slot)
        self._rounds_since_promote[slot] = 0
        del self._prefill[slot]
        self._pending_first.append((slot, req, t0))


def make_prefill_session(base=E.ServeSession):
    """Subclass ``base`` with the prefill-side overrides (idempotent)."""
    if issubclass(base, PrefillSessionMixin):
        return base
    return type("PrefillSession", (PrefillSessionMixin, base), {})


class PrefillWorker:
    """One prefill node: admits prompts, emits migration packets."""

    def __init__(self, params, cfg, *, num_slots: int, max_seq: int,
                 session_cls=None, **session_kw):
        cls = make_prefill_session(session_cls or E.ServeSession)
        self.session = cls(params, cfg, num_slots=num_slots,
                           max_seq=max_seq, **session_kw)
        self.migrations = 0

    def submit(self, req: Request) -> list:
        """Enqueue a request; returns immediately-drained events (an
        unservable request's terminal rejection surfaces here)."""
        self.session.submit(req)
        return self.session.drain_events()

    def abort(self, rid: int, *, reason: str = "abort") -> bool:
        ok = self.session.abort(rid, reason=reason)
        return ok

    def owns(self, rid: int) -> bool:
        s = self.session
        return rid in s.sched.running \
            or any(r.rid == rid for r in s.sched.queue)

    def step(self) -> tuple[list, list]:
        """One prefill round: admissions + one prompt chunk; promoted
        slots pack into migration packets and release immediately.
        Returns ``(events, packets)``."""
        s = self.session
        s.admit()
        s.prefill_round()
        packets = []
        pending, s._pending_first = s._pending_first, []
        for slot, req, t0 in pending:
            st = s.sched.slots[slot]
            if not (st.active and st.rid == req.rid):
                continue       # aborted between promotion and pack
            tails = getattr(s, "migration_tails", {}).pop(slot, None)
            pkt = KT.pack_migration(
                s, slot, req, t0, tails=tails,
                submit_time=s._submit_time.get(req.rid))
            s.sched.release_migrated(slot)
            s.report.events.append(
                f"round {s._round}: rid={req.rid} migrated out "
                f"({pkt.n_pages} pages, {pkt.wire_bytes} B)")
            packets.append(pkt)
            self.migrations += 1
        s._round += 1
        return s.drain_events(), packets


class DecodeWorker:
    """One decode node: installs migrated prompts, runs decode rounds."""

    def __init__(self, params, cfg, *, num_slots: int, max_seq: int,
                 session_cls=None, **session_kw):
        cls = session_cls or E.ServeSession
        self.session = cls(params, cfg, num_slots=num_slots,
                           max_seq=max_seq, **session_kw)
        self.installed = 0

    def can_accept(self, req: Request) -> bool:
        return KT.can_accept(self.session, req)

    def bytes_needed(self, req: Request) -> int:
        """Host bytes the request pins here (dtype-exact page bytes)."""
        return self.session.pages_needed(req) * self.session.host_page_bytes

    def load(self, index: int) -> WorkerLoad:
        """Byte-denominated admission headroom for router placement."""
        s = self.session
        free_pages = (1 << 30) if s.allocator is None \
            else s.allocator.free_pages
        return WorkerLoad(
            worker=index,
            free_host_bytes=free_pages * max(1, s.host_page_bytes),
            free_slots=sum(not sl.active for sl in s.sched.slots),
            queued=len(s.sched.running) + len(s.sched.queue))

    def install(self, packet: KT.MigrationPacket) -> int:
        self.installed += 1
        return KT.install_migration(self.session, packet)

    def owns(self, rid: int) -> bool:
        s = self.session
        return rid in s.sched.running \
            or any(r.rid == rid for r in s.sched.queue)

    def abort(self, rid: int, *, reason: str = "abort") -> bool:
        return self.session.abort(rid, reason=reason)

    def step(self) -> list:
        """One serve round (admit → local re-prefill chunk → decode)."""
        return self.session.step_round()
