"""EssCluster — the PD-disaggregated drop-in for :class:`EssEngine`.

One facade over ``num_prefill`` prefill workers, ``num_decode`` decode
workers, a :class:`Router` and an :class:`InterNodeChannel`, exposing
the exact single-node surface — ``submit`` / ``step`` / ``stream`` /
``generate`` / ``abort`` / ``output`` / ``metrics`` — so existing
callers and the serve bench drive a 1-prefill + N-decode topology
unchanged.  ``EssEngine`` remains the single-node entry point; this
class is what the deployment story in the paper's Figure 3 looks like
when the "Load" arrow crosses nodes.

One cluster step =

1. every prefill worker runs one round (admission + one prompt chunk);
   freshly promoted slots pack into migration packets (ESS107: one
   fetch each) and enter the channel;
2. the channel ticks; arrived packets are placed by the router (most
   free host bytes; full workers routed around, unplaceable packets
   held for the next step) and installed (block-table remap, raw page
   scatter, first-token delivery);
3. every decode worker runs one round (local re-prefill of preempted
   requests + one decode/verify step).

Greedy streams are bitwise identical to a single engine serving the
same prompts: the migration moves the complete per-request state
(pages/scales verbatim in storage dtype, ikeys, first token, MTP
hidden) and the decode round's per-slot math is independent of slot
index and co-residents.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Iterator, Optional, Sequence, Union

from repro.cluster import kv_transfer as KT
from repro.cluster.router import Router
from repro.cluster.workers import DecodeWorker, PrefillWorker
from repro.serving.api import (RequestOutput, SamplingParams, TokenEvent,
                               latency_stats)
from repro.serving.scheduler import Request


class EssCluster:
    """Prefill/decode-disaggregated serving cluster facade."""

    def __init__(self, params, cfg, *, num_prefill: int = 1,
                 num_decode: int = 1, num_slots: int = 2, max_seq: int,
                 prefill_slots: Optional[int] = None,
                 decode_slots: Optional[int] = None,
                 channel: Optional[KT.InterNodeChannel] = None,
                 prefill_session_cls=None, decode_session_cls=None,
                 decode_overrides: Optional[Sequence[Optional[dict]]] = None,
                 **session_kw):
        self._user_prompt_fn = session_kw.pop("prompt_fn", None)
        kw = dict(session_kw, prompt_fn=self._prompt_for)
        self.prefill = [
            PrefillWorker(params, cfg,
                          num_slots=prefill_slots or num_slots,
                          max_seq=max_seq, session_cls=prefill_session_cls,
                          **kw)
            for _ in range(num_prefill)]
        self.decode = []
        for i in range(num_decode):
            wkw = dict(kw)
            if decode_overrides and decode_overrides[i]:
                wkw.update(decode_overrides[i])
            self.decode.append(
                DecodeWorker(params, cfg,
                             num_slots=decode_slots or num_slots,
                             max_seq=max_seq,
                             session_cls=decode_session_cls, **wkw))
        self.router = Router(self.prefill, self.decode)
        self.channel = channel or KT.InterNodeChannel()
        self._next_rid = 0
        self._prompts: dict[int, Any] = {}
        self._plens: dict[int, int] = {}
        self._buffers: dict[int, deque] = {}
        self._outputs: dict[int, list] = {}
        self._terminal: dict[int, str] = {}
        self._ttft_s: dict[int, float] = {}
        self._submit_time: dict[int, float] = {}
        self._event_log: list[TokenEvent] = []
        self._pending_place: list[KT.MigrationPacket] = []
        self._aborted_in_transit = 0
        self._steps = 0

    # -- request lifecycle ---------------------------------------------------

    def _prompt_for(self, req: Request):
        p = self._prompts.get(req.rid)
        if p is not None:
            return p
        if self._user_prompt_fn is not None:
            return self._user_prompt_fn(req)
        # deterministic synthetic prompt, identical on every worker (and
        # to a single engine serving the same rid)
        return self.prefill[0].session._default_prompt(req)

    def submit(self, prompt: Union[int, Sequence[int]],
               params: Optional[SamplingParams] = None) -> int:
        """Enqueue one request on a prefill worker (round-robin);
        returns its rid.  Mirrors :meth:`EssEngine.submit`."""
        params = params or SamplingParams()
        rid = self._next_rid
        self._next_rid += 1
        if isinstance(prompt, int):
            plen = prompt
        else:
            import jax.numpy as jnp
            toks = jnp.asarray(prompt, jnp.int32)[None, :]
            self._prompts[rid] = toks
            plen = int(toks.shape[1])
        self._plens[rid] = plen
        self._buffers.setdefault(rid, deque())
        self._submit_time[rid] = time.perf_counter()
        req = Request(
            rid=rid, prompt_len=plen, max_new_tokens=params.max_tokens,
            temperature=params.temperature, top_k=params.top_k,
            top_p=params.top_p, seed=params.seed,
            eos_token_ids=tuple(params.eos_token_ids),
            stop_token_ids=tuple(params.stop_token_ids),
            priority=params.priority)
        w = self.router.route_prefill(req)
        self._distribute(self.prefill[w].submit(req))
        return rid

    def abort(self, rid: int, *, reason: str = "abort") -> bool:
        """Abort wherever the request currently lives: a prefill queue
        or slot, the inter-node channel (mid-handoff — the packet is
        dropped; prefill pages were already freed at pack, the decode
        side never saw it), or a decode worker."""
        if rid in self._terminal:
            return False
        for w in self.prefill:
            if w.owns(rid):
                ok = w.abort(rid, reason=reason)
                self._distribute(w.session.drain_events())
                return ok
        dropped = self.channel.cancel(rid)
        held = [p for p in self._pending_place if p.rid == rid]
        if dropped or held:
            self._pending_place = [p for p in self._pending_place
                                   if p.rid != rid]
            req = (dropped or held)[0].req
            req.finished = True
            req.finish_reason = reason
            self._aborted_in_transit += 1
            self._distribute([TokenEvent(
                rid=rid, token=None, index=0, finish_reason=reason,
                t=time.perf_counter())])
            return True
        for w in self.decode:
            if w.owns(rid):
                ok = w.abort(rid, reason=reason)
                self._distribute(w.session.drain_events())
                return ok
        return False

    def step(self) -> list:
        """One cluster step: prefill rounds → channel tick + placement →
        decode rounds.  Returns (and buffers) the step's TokenEvents."""
        evs: list[TokenEvent] = []
        for w in self.prefill:
            wevs, packets = w.step()
            evs += wevs
            for pkt in packets:
                self.channel.send(pkt)
        pending = self._pending_place + self.channel.tick()
        self._pending_place = []
        for pkt in pending:
            tgt = self.router.place(pkt.req)
            if tgt is None:
                self._pending_place.append(pkt)   # route around: retry
                continue
            self.decode[tgt].install(pkt)
        for w in self.decode:
            evs += w.step()
        self._distribute(evs)
        self._steps += 1
        return evs

    def _distribute(self, evs) -> None:
        for ev in evs:
            self._event_log.append(ev)
            self._buffers.setdefault(ev.rid, deque()).append(ev)
            if ev.is_terminal:
                self._terminal[ev.rid] = ev.finish_reason
            elif ev.token is not None:
                out = self._outputs.setdefault(ev.rid, [])
                # a preempted request's re-admission regenerates its
                # stream from index 0 — truncate and replay
                del out[ev.index:]
                out.append(ev.token)
                if ev.index == 0 and ev.rid in self._submit_time:
                    self._ttft_s.setdefault(
                        ev.rid, ev.t - self._submit_time[ev.rid])

    # -- results -------------------------------------------------------------

    def is_finished(self, rid: int) -> bool:
        return rid in self._terminal

    def finish_reason(self, rid: int) -> Optional[str]:
        return self._terminal.get(rid)

    def has_work(self) -> bool:
        if self.channel.in_flight or self._pending_place:
            return True
        return any(w.session.sched.running or w.session.sched.queue
                   for w in self.prefill + self.decode)

    def stream(self, rid: int) -> Iterator[TokenEvent]:
        """Incremental results for one rid, driving cluster steps as
        needed; single-consumer per rid (same contract as
        :meth:`EssEngine.stream`)."""
        buf = self._buffers[rid]
        while True:
            while buf:
                ev = buf.popleft()
                yield ev
                if ev.is_terminal:
                    return
            if self.is_finished(rid):
                return
            if not self.has_work():
                raise RuntimeError(
                    f"rid={rid} stream stalled: cluster idle with no "
                    f"terminal event")
            self.step()

    def output(self, rid: int) -> RequestOutput:
        assert rid in self._terminal, f"rid={rid} has not finished"
        return RequestOutput(
            rid=rid, prompt_len=self._plens.get(rid, 0),
            tokens=list(self._outputs.get(rid, [])),
            finish_reason=self._terminal[rid],
            ttft_s=self._ttft_s.get(rid))

    def generate(self, prompts: Sequence,
                 params: Union[SamplingParams, Sequence[SamplingParams],
                               None] = None, *,
                 max_rounds: int = 200) -> list:
        """Batch convenience mirroring :meth:`EssEngine.generate`."""
        if params is None or isinstance(params, SamplingParams):
            params = [params or SamplingParams()] * len(prompts)
        assert len(params) == len(prompts)
        rids = [self.submit(p, sp) for p, sp in zip(prompts, params)]
        budget = max_rounds
        while any(not self.is_finished(r) for r in rids):
            self.step()
            budget -= 1
            if budget <= 0:
                for r in rids:
                    if not self.is_finished(r):
                        self.abort(r, reason="budget")
                break
        return [self.output(r) for r in rids]

    def metrics(self) -> dict:
        """Cluster-wide counters: per-worker report sums + handoff and
        channel accounting + latency percentiles over the global event
        log."""
        reps = [w.session.report for w in self.prefill + self.decode]
        dreps = [w.session.report for w in self.decode]
        m = {
            "cluster_steps": self._steps,
            "num_prefill_workers": len(self.prefill),
            "num_decode_workers": len(self.decode),
            "rounds": sum(r.rounds for r in dreps),
            "spec_rounds": sum(r.spec_rounds for r in dreps),
            "decode_tokens": sum(r.decode_tokens for r in dreps),
            "prefill_tokens": sum(r.prefill_tokens for r in reps),
            "prefill_chunks": sum(r.prefill_chunks for r in reps),
            "migrations": sum(w.migrations for w in self.prefill),
            "installed": sum(w.installed for w in self.decode),
            "packets_in_flight": len(self.channel.in_flight),
            "packets_held": len(self._pending_place),
            "wire_bytes": self.channel.payload_bytes,
            "sim_transfer_s": self.channel.sim_transfer_s,
            "rejected": sum(r.rejected for r in reps),
            "aborted": (sum(r.aborted for r in reps)
                        + self._aborted_in_transit),
            "h2d_rows": sum(r.h2d_rows for r in dreps),
            "d2h_rows": sum(r.d2h_rows for r in reps),
            "finish_reasons": dict(self._terminal),
        }
        m.update(latency_stats(self._event_log, self._submit_time))
        return m
