"""Request routing for the PD-disaggregated cluster.

Two decisions, both deterministic:

* **prefill placement** — round-robin over the prefill workers (prompts
  are compute-bound and stateless before admission, so rotation is the
  even-load policy);
* **decode placement** — :func:`repro.serving.scheduler.pick_decode_worker`
  over the workers' byte-denominated :class:`WorkerLoad`s: the worker
  with the most free host bytes among those that can admit *now*.  A
  full or byte-exhausted worker is routed around, never rejected; when
  no worker fits the migration is held and retried after the next
  cluster step frees resources.
"""

from __future__ import annotations

from typing import Optional

from repro.serving import scheduler as SCH
from repro.serving.scheduler import Request


class Router:
    def __init__(self, prefill_workers: list, decode_workers: list):
        self.prefill = prefill_workers
        self.decode = decode_workers
        self._rr = 0

    def route_prefill(self, req: Request) -> int:
        """Round-robin prefill placement; returns the worker index."""
        i = self._rr % len(self.prefill)
        self._rr += 1
        return i

    def place(self, req: Request) -> Optional[int]:
        """Decode placement for a migrated request, or ``None`` to hold.

        ``need_bytes`` is the conservative (max across workers) byte
        need, so a mixed-dtype fleet never over-places; the final
        ``can_accept`` double-check covers the remaining per-worker
        resources (pool-entry reservations)."""
        loads = [w.load(i) for i, w in enumerate(self.decode)]
        need = max(w.bytes_needed(req) for w in self.decode)
        pick = SCH.pick_decode_worker(loads, need)
        if pick is not None and not self.decode[pick].can_accept(req):
            return None
        return pick
