"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* the first jax init).

``make_mesh`` is the version-guarded entry point: newer JAX wants explicit
``axis_types`` (Auto) for meshes that feed ``shard_map``; JAX <= 0.4.x has
neither ``jax.sharding.AxisType`` nor the ``axis_types`` kwarg, so the
helper passes it only when the installed JAX understands it.
"""

from __future__ import annotations

import inspect
from typing import Sequence

import jax


def _axis_types_kwargs(n_axes: int) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    try:
        if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
            return {}
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_types_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return make_mesh((n // mp, mp), ("data", "model"))
