"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* the first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh(
        (n // mp, mp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
