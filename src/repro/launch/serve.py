"""Serving launcher: the compiled continuous-batching ESS serve loop.

Laptop-scale demo of the full pipeline — chunked decode-interleaved
prefill, MTP speculative rounds, TBO, paged host tier — driven through
``ServeSession``'s donated StepPrograms (``--eager`` switches to the
op-by-op debugging path; the streams are identical, the rounds/s are
not).

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v32-exp-ess-smoke \
      --requests 4 --prompt-len 48 --new-tokens 16 --mtp-depth 2 --tbo
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving.scheduler import Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v32-exp-ess-smoke")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--mtp-depth", type=int, default=0)
    ap.add_argument("--tbo", action="store_true")
    ap.add_argument("--eager", action="store_true",
                    help="op-by-op debugging path (compiled=False)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    assert cfg.ess.enabled, "serve.py demonstrates the ESS path"
    if args.mtp_depth > cfg.mtp_depth:
        cfg = dataclasses.replace(cfg, mtp_depth=args.mtp_depth)
    params = init_params(jax.random.key(args.seed), T.model_def(cfg))

    session = E.ServeSession(
        params, cfg, num_slots=args.slots, max_seq=args.max_seq,
        prefill_chunk=args.prefill_chunk, mtp_depth=args.mtp_depth,
        tbo=args.tbo, compiled=not args.eager)
    reqs = [Request(rid=i, prompt_len=args.prompt_len,
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]

    t0 = time.time()
    report = session.run(reqs, max_rounds=4 * (args.new_tokens
                                               + args.prompt_len))
    dt = time.time() - t0
    mode = "eager" if args.eager else "compiled"
    print(f"[{mode}] {len(report.finished_rids)}/{len(reqs)} requests in "
          f"{report.rounds} decode rounds ({report.spec_rounds} "
          f"speculative), {dt:.2f}s wall")
    print(f"  {report.tokens_per_s:.1f} accepted-tok/s, "
          f"{report.rounds_per_s:.1f} rounds/s, "
          f"accept rate {report.accept_rate:.2f}; "
          f"prefill {report.prefill_tokens} toks in "
          f"{report.prefill_chunks} chunks, "
          f"mean ttft {report.mean_ttft_s:.3f}s")
    for rid in sorted(session.outputs):
        stream = session.outputs[rid]
        print(f"  rid{rid}: {len(stream)} tokens  {stream[:8]}"
              f"{'...' if len(stream) > 8 else ''}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
