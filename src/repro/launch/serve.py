"""Serving launcher: ESS decode loop with continuous batching.

Laptop-scale demo of the full pipeline: prefill (+LRU-Warmup) → MTP
speculative decode rounds through the offload-centric engine, with
hit/miss statistics per step — the live counterpart of the simulator's
Figure-4/5 numbers.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v32-exp-ess-smoke \
      --batch 2 --prompt-len 48 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving import mtp as MTP
from repro.serving.sampling import greedy


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v32-exp-ess-smoke")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--use-mtp", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    assert cfg.ess.enabled, "serve.py demonstrates the ESS path"
    params = init_params(jax.random.key(args.seed), T.model_def(cfg))
    B, S = args.batch, args.prompt_len
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    t0 = time.time()
    logits, caches = E.ess_prefill(params, cfg, toks, pos, args.max_seq)
    print(f"prefill {S} tokens (+LRU-Warmup {cfg.ess.warmup_windows} "
          f"windows): {time.time()-t0:.2f}s")

    tok = greedy(logits[:, -1])
    hidden = None
    t0 = time.time()
    n_out = 0
    while n_out < args.new_tokens:
        if args.use_mtp and cfg.mtp_depth and hidden is not None:
            spec = MTP.speculative_step(
                lambda p_, c_, t_, po_, ca_: E.ess_decode(p_, c_, t_, po_, ca_),
                params, cfg, caches, tok, hidden)
            caches = spec.caches
            # continue from the last *emitted* token (accepted prefix +
            # bonus), not position depth — tokens beyond n_accepted were
            # rolled back; re-seed the next draft from the verify hidden
            tok = jnp.take_along_axis(spec.tokens,
                                      spec.n_accepted[:, None] - 1,
                                      axis=1)[:, 0]
            hidden = spec.hidden
            n_out += int(spec.n_accepted.min())
            print(f"spec round: accepted+bonus/seq "
                  f"{np.array(spec.n_accepted)}")
        else:
            out = E.ess_decode(params, cfg, tok[:, None],
                               caches.lens[:, None], caches)
            caches = out.caches
            tok = greedy(out.logits[:, -1])
            hidden = out.stats["hidden"][:, -1]
            n_out += 1
            print(f"step {n_out}: misses/seq "
                  f"{np.array(out.stats['misses'])} "
                  f"hits {np.array(out.stats['hits'])}")
    dt = time.time() - t0
    print(f"decode {n_out} tokens x {B} seqs in {dt:.2f}s "
          f"({B * n_out / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
