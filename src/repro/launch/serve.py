"""Serving launcher: the public `EssEngine` front-end over the compiled
continuous-batching ESS serve loop.

Laptop-scale demo of the full pipeline — chunked decode-interleaved
prefill, MTP speculative rounds, TBO, paged host tier — driven through
``EssEngine.generate`` (``--eager`` switches the underlying StepPrograms
to the op-by-op debugging path; the streams are identical, the rounds/s
are not).  Per-request knobs ride on ``SamplingParams``
(``--temperature/--top-k/--top-p``, ``--stop-token`` for early exit);
``metrics()`` reports the TokenEvent-derived latency percentiles.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v32-exp-ess-smoke \
      --requests 4 --prompt-len 48 --new-tokens 16 --mtp-depth 2 --tbo
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.api import EssEngine, SamplingParams


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v32-exp-ess-smoke")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--mtp-depth", type=int, default=0)
    ap.add_argument("--tbo", action="store_true")
    ap.add_argument("--eager", action="store_true",
                    help="op-by-op debugging path (compiled=False)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--top-p", type=float, default=None)
    ap.add_argument("--stop-token", type=int, default=None,
                    help="terminate a stream early at this token id")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    assert cfg.ess.enabled, "serve.py demonstrates the ESS path"
    if args.mtp_depth > cfg.mtp_depth:
        cfg = dataclasses.replace(cfg, mtp_depth=args.mtp_depth)
    params = init_params(jax.random.key(args.seed), T.model_def(cfg))

    engine = EssEngine(
        params, cfg, num_slots=args.slots, max_seq=args.max_seq,
        prefill_chunk=args.prefill_chunk, mtp_depth=args.mtp_depth,
        tbo=args.tbo, compiled=not args.eager)
    sp = SamplingParams(
        max_tokens=args.new_tokens, temperature=args.temperature,
        top_k=args.top_k, top_p=args.top_p,
        stop_token_ids=() if args.stop_token is None
        else (args.stop_token,))

    t0 = time.time()
    outs = engine.generate([args.prompt_len] * args.requests, sp,
                           max_rounds=4 * (args.new_tokens
                                           + args.prompt_len))
    dt = time.time() - t0
    report = engine.session.report
    m = engine.metrics()
    mode = "eager" if args.eager else "compiled"
    served = sum(o.finish_reason in ("length", "stop") for o in outs)
    print(f"[{mode}] {served}/{len(outs)} requests in "
          f"{report.rounds} decode rounds ({report.spec_rounds} "
          f"speculative), {dt:.2f}s wall")
    print(f"  {report.tokens_per_s:.1f} accepted-tok/s, "
          f"{report.rounds_per_s:.1f} rounds/s, "
          f"accept rate {report.accept_rate:.2f}; "
          f"prefill {report.prefill_tokens} toks in "
          f"{report.prefill_chunks} chunks")
    def fmt(v, spec):
        # a percentile is None when no event backs it (e.g. no
        # inter-token gaps at --new-tokens 1)
        return "n/a" if v is None else format(v, spec)
    print(f"  ttft p50/p95 {fmt(m['ttft_p50_s'], '.3f')}/"
          f"{fmt(m['ttft_p95_s'], '.3f')}s, "
          f"inter-token p50/p95 {fmt(m['itl_p50_s'], '.4f')}/"
          f"{fmt(m['itl_p95_s'], '.4f')}s")
    for o in outs:
        print(f"  rid{o.rid}: {o.n_generated} tokens "
              f"({o.finish_reason})  {o.tokens[:8]}"
              f"{'...' if o.n_generated > 8 else ''}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
