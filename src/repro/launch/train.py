"""Training launcher.

Laptop-scale by default (runs on this CPU container); at fleet scale the
same entry point runs under a multi-host mesh — everything below the CLI
is mesh-agnostic.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b-smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/repro_ckpt
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models.params import init_params
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import LoopConfig, train_loop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, param_dtype=jnp.float32)
    params = init_params(jax.random.key(args.seed), T.model_def(cfg))
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 10))
    opt_state = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, accum_steps=args.accum),
                   donate_argnums=(0, 1))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size,
                          global_batch=args.batch, seq_len=args.seq,
                          seed=args.seed)
    loop = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir)
    train_loop(step, params, opt_state, data_cfg, loop)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
