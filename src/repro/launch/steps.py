"""Step factories + abstract input specs for every (arch × shape) cell.

``input_specs(cfg, cell)`` returns weak-type-correct ShapeDtypeStruct
stand-ins (with NamedShardings under the active sharding context) for the
step function chosen by the cell kind:

* train   -> ``make_train_step``  (loss + grad + AdamW update)
* prefill -> ``make_prefill_step``
* decode  -> ``make_decode_step`` (one new token vs a seq_len cache) — the
  ESS-enabled DSA arch routes through the offload-centric engine.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.cache import latent_cache as LC
from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.models.params import abstract_params, param_pspecs
from repro.serving import engine as E
from repro.training.optimizer import (AdamWConfig, OptState, adamw_update,
                                      init_opt_state)


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _dev(shape, dtype, *axes):
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=ctx.sharding_for(shape, axes))


def seq_axis_name(cell: ShapeCell) -> str | None:
    """long_500k (batch=1) shards the *sequence* over the data axis."""
    return "seq" if cell.global_batch == 1 else None


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict[str, Any]:
    """Abstract inputs for the cell's step function."""
    B, S = cell.global_batch, cell.seq_len
    batch_ax = "batch" if B > 1 else None
    toks_i32 = functools.partial(_dev, dtype=jnp.int32)

    if cell.kind == "train":
        specs: dict[str, Any] = {}
        if cfg.embedding_inputs and cfg.family != "audio":
            specs["inputs"] = _dev((B, S, cfg.d_model), jnp.bfloat16,
                                   batch_ax, None, None)
        else:
            specs["inputs"] = _dev((B, S), jnp.int32, batch_ax, None)
        specs["labels"] = _dev((B, S), jnp.int32, batch_ax, None)
        specs["positions"] = _dev((B, S), jnp.int32, batch_ax, None)
        if cfg.family == "audio":
            specs["enc_inputs"] = _dev((B, cfg.encdec.encoder_seq, cfg.d_model),
                                       jnp.bfloat16, batch_ax, None, None)
        if cfg.mrope_sections is not None:
            specs["mrope_positions"] = _dev((B, S, 3), jnp.int32,
                                            batch_ax, None, None)
        return specs

    if cell.kind == "prefill":
        specs = {}
        if cfg.embedding_inputs and cfg.family != "audio":
            specs["inputs"] = _dev((B, S, cfg.d_model), jnp.bfloat16,
                                   batch_ax, "seq", None)
        else:
            specs["inputs"] = _dev((B, S), jnp.int32, batch_ax, "seq")
        specs["positions"] = _dev((B, S), jnp.int32, batch_ax, "seq")
        if cfg.family == "audio":
            specs["enc_inputs"] = _dev((B, cfg.encdec.encoder_seq, cfg.d_model),
                                       jnp.bfloat16, batch_ax, None, None)
        if cfg.mrope_sections is not None:
            specs["mrope_positions"] = _dev((B, S, 3), jnp.int32,
                                            batch_ax, None, None)
        return specs

    # decode: one new token against a seq_len cache
    sq = seq_axis_name(cell)
    specs = {"caches": abstract_caches(cfg, B, S, seq_ax=sq)}
    if cfg.embedding_inputs and cfg.family != "audio":
        specs["inputs"] = _dev((B, 1, cfg.d_model), jnp.bfloat16,
                               batch_ax, None, None)
    else:
        specs["inputs"] = _dev((B, 1), jnp.int32, batch_ax, None)
    specs["positions"] = _dev((B, 1), jnp.int32, batch_ax, None)
    return specs


def abstract_caches(cfg: ArchConfig, B: int, S: int,
                    seq_ax: str | None = None) -> Any:
    """ShapeDtypeStruct cache tree with shardings (decode dry-run inputs).

    Sharding policy (production decode):
    * batch over the data axes (pod, data) when B > 1;
    * KV heads over ``model`` when divisible, else the cache *sequence* dim
      shards over ``model`` (flash-decoding style seq split — the partial
      softmax merge lowers to a psum over the model axis);
    * B == 1 (long_500k): sequence takes the data axes too;
    * MLA latent/ikeys are head-shared (MQA) -> always seq-sharded over
      ``model``; with ESS the full latent lives in host memory instead and
      only the Sparse Memory Pool stays in HBM (the paper's design).
    """
    if cfg.ess.enabled and cfg.attn_kind == "mla":
        return LC.abstract_ess_caches(cfg, B, S)
    concrete = jax.eval_shape(lambda: T.cache_spec(cfg, B, S))
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), concrete)

    names = set(ctx.mesh.axis_names)
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    model = "model" if "model" in names else None
    batch_entry = data_axes if B > 1 else None
    seq_data = data_axes if B == 1 else ()

    def seq_entry(extra_model: bool):
        ax = tuple(seq_data) + ((model,) if extra_model and model else ())
        if not ax:
            return None
        return ax if len(ax) > 1 else ax[0]

    def annotate(x):
        nd = x.ndim
        if nd == 1:                                     # lens
            ax = (batch_entry,)
        elif nd == 5:
            if x.shape[2] == S:                         # kv cache
                kv_ok = model and x.shape[3] % sizes[model] == 0
                ax = (None, batch_entry, seq_entry(not kv_ok),
                      model if kv_ok else None, None)
            elif cfg.encdec is not None and \
                    x.shape[2] == cfg.encdec.encoder_seq:
                kv_ok = model and x.shape[3] % sizes[model] == 0
                ax = (None, batch_entry, None,
                      model if kv_ok else None, None)
            else:                                       # ssm state [L,B,H,P,N]
                h_ok = model and x.shape[2] % sizes[model] == 0
                ax = (None, batch_entry, model if h_ok else None, None, None)
        elif nd == 4:
            if x.shape[2] == S:                         # latent/ikeys [L,B,S,D]
                ax = (None, batch_entry, seq_entry(True), None)
            else:                                       # conv state [L,B,W,C]
                c_ok = model and x.shape[3] % sizes[model] == 0
                ax = (None, batch_entry, None, model if c_ok else None)
        elif nd == 3:
            ax = (None, batch_entry, None)
        else:
            ax = (None,) * nd
        spec = shd.prune_spec(P(*ax), x.shape, ctx.mesh)
        return jax.ShapeDtypeStruct(
            x.shape, x.dtype,
            sharding=jax.sharding.NamedSharding(ctx.mesh, spec))

    return jax.tree.map(annotate, concrete)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Next-token CE, fp32, mean over tokens."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig | None = None,
                    accum_steps: int = 1) -> Callable:
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, batch):
        out = T.forward(params, cfg, batch["inputs"], batch["positions"],
                        mode="train",
                        mrope_positions=batch.get("mrope_positions"),
                        enc_inputs=batch.get("enc_inputs"))
        loss = lm_loss(out.logits, batch["labels"])
        loss = loss + 0.01 * out.aux.get("moe_lb", 0.0)
        return loss, out.aux

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            # gradient accumulation: scan over microbatches, accumulate in
            # the grad dtype (bf16 grads keep memory flat at scale)
            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
        params2, opt_state2, om = adamw_update(opt_cfg, params, grads,
                                               opt_state)
        metrics = {"loss": loss, **om}
        return params2, opt_state2, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        out = T.forward(params, cfg, batch["inputs"], batch["positions"],
                        mode="prefill",
                        mrope_positions=batch.get("mrope_positions"),
                        enc_inputs=batch.get("enc_inputs"))
        return out.logits, out.caches
    return prefill_step


def make_decode_step(cfg: ArchConfig, use_kernel: bool = False) -> Callable:
    if cfg.ess.enabled and cfg.attn_kind == "mla":
        def decode_step(params, batch):
            out = E.ess_decode(params, cfg, batch["inputs"],
                               batch["positions"], batch["caches"],
                               use_kernel=use_kernel, slot_mask=None)
            return out.logits, out.caches
        return decode_step

    def decode_step(params, batch):
        out = T.forward(params, cfg, batch["inputs"], batch["positions"],
                        mode="decode", caches=batch["caches"])
        return out.logits, out.caches
    return decode_step


def dp_degree() -> int:
    """Product of mesh-axis sizes the "batch" logical axis maps to."""
    ctx = shd.current()
    if ctx is None or ctx.mesh is None:
        return 1
    r = ctx.rules.get("batch")
    if r is None:
        return 1
    axes = r if isinstance(r, tuple) else (r,)
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


MICRO_SEQS = 4   # target sequences per device per microbatch


def auto_accum(cell: ShapeCell) -> int:
    b_loc = max(1, cell.global_batch // dp_degree())
    return int(min(8, max(1, b_loc // MICRO_SEQS)))


def make_step(cfg: ArchConfig, cell: ShapeCell) -> Callable:
    if cell.kind == "train":
        return make_train_step(cfg, accum_steps=auto_accum(cell))
    if cell.kind == "prefill":
        return make_prefill_step(cfg)
    return make_decode_step(cfg)


def abstract_state(cfg: ArchConfig, cell: ShapeCell):
    """Abstract (params[, opt_state]) with shardings for the dry-run."""
    ctx = shd.current()
    defs = T.model_def(cfg)
    mesh = ctx.mesh if ctx else None
    rules = ctx.rules if ctx else {}
    params = abstract_params(defs, mesh, rules)
    if cell.kind != "train":
        return params, None
    m = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
        s.shape, jnp.float32, sharding=getattr(s, "sharding", None)), params)
    v = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
        s.shape, jnp.float32, sharding=getattr(s, "sharding", None)), params)
    opt = OptState(m=m, v=v, step=jax.ShapeDtypeStruct((), jnp.int32))
    return params, opt
