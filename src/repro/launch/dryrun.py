import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
cell — proof that the distribution config is coherent without hardware.

For each cell this prints/records:
  * compiled.memory_analysis()  — per-device bytes (does it fit?)
  * compiled.cost_analysis()    — FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO — the §Roofline third term

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.json
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any

import jax
import numpy as np

from repro.configs import ASSIGNED, SHAPES, get_config
from repro.configs.base import ShapeCell
from repro.distributed.sharding import PROFILES, use_sharding
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh

# ---------------------------------------------------------------------------
# Cell enumeration + skip table (documented in DESIGN.md §6)
# ---------------------------------------------------------------------------

LONG_OK = {"mamba2-780m", "zamba2-7b", "deepseek-v3-671b"}
SKIPS: dict[tuple[str, str], str] = {
    ("whisper-large-v3", "long_500k"): "enc-dec, full attention decoder",
    ("gemma2-27b", "long_500k"): "global layers are full attention",
    ("gemma3-27b", "long_500k"): "global layers are full attention",
    ("qwen3-0.6b", "long_500k"): "pure full attention",
    ("qwen1.5-110b", "long_500k"): "pure full attention",
    ("dbrx-132b", "long_500k"): "pure full attention",
    ("qwen2-vl-7b", "long_500k"): "pure full attention",
}


def enumerate_cells() -> list[tuple[str, str, str | None]]:
    """[(arch, shape, skip_reason|None)] — 40 cells total."""
    out = []
    for arch in ASSIGNED:
        for shape in SHAPES:
            out.append((arch, shape, SKIPS.get((arch, shape))))
    return out


def cell_config(arch: str, shape: str):
    """Arch config for a cell; deepseek long/ess cells use the paper's
    V3.2-Exp + ESS variant (DSA makes 500k sub-quadratic)."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    if arch == "deepseek-v3-671b" and shape == "long_500k":
        cfg = get_config("deepseek-v32-exp-ess")
    return cfg, cell


# ---------------------------------------------------------------------------
# HLO collective accounting (§Roofline collective term)
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"(\w[\w\.\-]*) = (\S+?) (all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
                "s32": 4, "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                "u64": 8, "f64": 8, "s16": 2, "u16": 2}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Sum output-shape bytes of every collective op in the optimized HLO."""
    per_kind: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        b = _shape_bytes(m.group(2))
        per_kind[kind] = per_kind.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "count_by_kind": count,
            "total_bytes": sum(per_kind.values())}


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: str, *, multi_pod: bool,
             verbose: bool = True, profile: str | None = None
             ) -> dict[str, Any]:
    cfg, cell = cell_config(arch, shape)
    skip = SKIPS.get((arch, shape))
    if skip:
        return {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": skip}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    seq_data = cell.global_batch == 1
    prof = profile or cfg.sharding_profile
    if (profile is None and cell.kind == "decode"
            and cfg.sharding_profile == "2d" and not cfg.ess.enabled):
        # §Perf: weights-stationary decode (10-17x fewer collective bytes);
        # reproduce the paper-faithful baseline with --sharding-profile 2d
        prof = "2d_ws"
    rules = PROFILES[prof](multi_pod, seq_data=seq_data)
    rec: dict[str, Any] = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "profile": prof}
    try:
        with use_sharding(mesh, rules):
            specs = ST.input_specs(cfg, cell)
            params, opt = ST.abstract_state(cfg, cell)
            step = ST.make_step(cfg, cell)
            shd_of = lambda tree: jax.tree.map(lambda x: x.sharding, tree)
            ctx = None
            from repro.distributed import sharding as _shd
            ctx = _shd.current()
            if cell.kind == "train":
                # donate params+opt (in-place update); outputs keep the
                # input shardings so aliasing is exact
                out_sh = (shd_of(params), shd_of(opt),
                          {"loss": ctx.sharding(), "grad_norm": ctx.sharding(),
                           "lr": ctx.sharding()})
                lowered = jax.jit(step, donate_argnums=(0, 1),
                                  out_shardings=out_sh).lower(
                    params, opt, specs)
            else:
                # decode: donate the batch (caches alias in place); output
                # shardings stay inferred — explicit out_shardings with
                # mixed memory kinds trips an SPMD RET_CHECK in this XLA
                lowered = jax.jit(step).lower(params, specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # JAX <= 0.4.x: list per program
            ca = ca[0] if ca else {}
        coll = collective_bytes(compiled.as_text())
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "collectives": coll,
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "host_argument_bytes": ma.host_argument_size_in_bytes,
                "host_temp_bytes": ma.host_temp_size_in_bytes,
            },
        })
        if verbose:
            print(f"[ok] {arch} × {shape} × {rec['mesh']} "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s) "
                  f"flops={rec['flops']:.3e} "
                  f"coll={coll['total_bytes']:.3e}B "
                  f"temp/dev={ma.temp_size_in_bytes/2**30:.2f}GiB")
            print(f"     memory_analysis: {ma}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[ERR] {arch} × {shape} × {rec['mesh']}: {e}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--out", default=None)
    ap.add_argument("--ess", action="store_true",
                    help="use the ESS-enabled deepseek variant for decode")
    ap.add_argument("--sharding-profile", default=None,
                    help="override the arch sharding profile (perf variants)")
    args = ap.parse_args(argv)

    meshes = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]
    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a, s, _ in enumerate_cells()]
    else:
        archs = [args.arch] if args.arch else ASSIGNED
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(a, s) for a in archs for s in shapes]

    results = []
    for arch, shape in cells:
        a = arch
        if args.ess and arch == "deepseek-v3-671b":
            a = "deepseek-v32-exp-ess"
        for mp in meshes:
            results.append(run_cell(a, shape, multi_pod=mp,
                                    profile=args.sharding_profile))

    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\n=== dry-run: {ok} ok, {sk} skipped, {err} errors "
          f"/ {len(results)} cells ===")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 1 if err else 0


if __name__ == "__main__":
    sys.exit(main())
