"""Model stacks: scan-over-layers decoder (dense/MoE/MLA), SSM stack,
Zamba-style hybrid, and encoder-decoder.  Plus the train/prefill/decode
step entry points used by the launcher, serving engine and dry-run.

Cache convention: a dict pytree

    {"lens": [B] int32,                    # tokens already in the cache
     "kv":   GQACache stacked [L, ...],    # gqa archs
     "mla":  MLACache stacked [L, ...],    # mla archs
     "ssm":  SSMState stacked [L, ...],    # ssm / hybrid archs
     "shared_kv": GQACache [napp, ...],    # zamba shared-attn applications
     "enc_kv": (k, v) stacked [L, ...]}    # encdec cross-attention

All stacks run under ``jax.lax.scan`` with stacked parameters unless
``cfg.scan_layers=False`` (ESS decode prefers the unrolled form so the
per-layer host fetches stay visible to the scheduler).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import attention as A
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import mla as M
from repro.models import ssm as S
from repro.models.params import ParamDef, init_params, stack_defs


# ---------------------------------------------------------------------------
# Stack plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StackPlan:
    """How cfg.num_layers decompose into homogeneous scan groups."""
    kind: str                      # lm | ssm | hybrid | encdec
    dense_layers: int = 0          # leading dense layers (deepseek)
    main_layers: int = 0           # main scanned group
    hybrid_groups: int = 0         # zamba: full groups of cfg.hybrid.attn_every
    hybrid_rem: int = 0


def stack_plan(cfg: ArchConfig) -> StackPlan:
    if cfg.family == "encdec" or cfg.family == "audio":
        return StackPlan("encdec", main_layers=cfg.num_layers)
    if cfg.family == "ssm":
        return StackPlan("ssm", main_layers=cfg.num_layers)
    if cfg.family == "hybrid":
        g = cfg.hybrid.attn_every
        return StackPlan("hybrid", hybrid_groups=cfg.num_layers // g,
                         hybrid_rem=cfg.num_layers % g)
    dense = cfg.moe.first_dense_layers if cfg.moe else 0
    return StackPlan("lm", dense_layers=dense,
                     main_layers=cfg.num_layers - dense)


def _layer_block_def(cfg: ArchConfig, *, moe: bool, dense_ff: int | None = None):
    if cfg.attn_kind == "mla":
        return B.mla_block_def(cfg, moe=moe, dense_ff=dense_ff)
    return B.gqa_block_def(cfg, moe=moe)


def maybe_remat(fn, cfg: ArchConfig, mode: str):
    """Activation checkpointing for train-time layer bodies."""
    if mode != "train" or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Parameter definitions for the whole model
# ---------------------------------------------------------------------------

def model_def(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    plan = stack_plan(cfg)
    defs: dict[str, Any] = {}
    if not cfg.embedding_inputs or plan.kind == "encdec":
        defs["embed"] = L.embed_def(cfg.vocab_size, cfg.d_model, dt)
    defs["final_norm"] = L.rmsnorm_def(cfg.d_model, dt)
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((cfg.vocab_size, cfg.d_model), dt, "embed",
                                   axes=("vocab", "embed"))

    if plan.kind == "lm":
        if plan.dense_layers:
            dd = _layer_block_def(cfg, moe=False,
                                  dense_ff=cfg.moe.dense_d_ff or cfg.d_ff)
            defs["dense_layers"] = stack_defs(dd, plan.dense_layers)
        md = _layer_block_def(cfg, moe=cfg.moe is not None)
        defs["layers"] = stack_defs(md, plan.main_layers)
        if cfg.mtp_depth:
            mtp = {"ln_h": L.rmsnorm_def(cfg.d_model, dt),
                   "ln_e": L.rmsnorm_def(cfg.d_model, dt),
                   "proj": ParamDef((2 * cfg.d_model, cfg.d_model), dt,
                                    "normal", axes=(None, "embed")),
                   "block": _layer_block_def(cfg, moe=cfg.moe is not None)}
            defs["mtp"] = stack_defs(mtp, cfg.mtp_depth)
    elif plan.kind == "ssm":
        defs["layers"] = stack_defs(B.ssm_block_def(cfg), plan.main_layers)
    elif plan.kind == "hybrid":
        defs["layers"] = stack_defs(B.ssm_block_def(cfg), cfg.num_layers)
        shared = B.gqa_block_def(cfg, moe=False)
        defs["shared_attn"] = stack_defs(shared, cfg.hybrid.num_shared_attn,
                                         axis_name=None)
    elif plan.kind == "encdec":
        ed = cfg.encdec
        enc = B.gqa_block_def(cfg, moe=False)
        defs["encoder"] = stack_defs(enc, ed.encoder_layers)
        dec = B.gqa_block_def(cfg, moe=False, cross=True)
        defs["decoder"] = stack_defs(dec, cfg.num_layers)
        defs["enc_norm"] = L.rmsnorm_def(cfg.d_model, dt)
    return defs


# ---------------------------------------------------------------------------
# Cache allocation (abstract or concrete via like=jnp.zeros)
# ---------------------------------------------------------------------------

def cache_spec(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    """Shapes of the decode cache pytree (concrete zeros)."""
    plan = stack_plan(cfg)
    c: dict[str, Any] = {"lens": jnp.zeros((batch,), jnp.int32)}
    Lh = cfg.num_layers
    if plan.kind == "lm":
        if cfg.attn_kind == "mla":
            Di = cfg.dsa.index_dim if cfg.dsa else 1
            c["mla"] = B.MLACache(
                jnp.zeros((Lh, batch, max_seq, cfg.mla.latent_dim), dtype),
                jnp.zeros((Lh, batch, max_seq, Di), dtype))
        else:
            c["kv"] = B.GQACache(
                jnp.zeros((Lh, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
                jnp.zeros((Lh, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype))
    elif plan.kind == "ssm":
        st = S.init_state(cfg, batch)
        c["ssm"] = jax.tree.map(
            lambda x: jnp.zeros((Lh,) + x.shape, x.dtype), st)
    elif plan.kind == "hybrid":
        st = S.init_state(cfg, batch)
        c["ssm"] = jax.tree.map(
            lambda x: jnp.zeros((Lh,) + x.shape, x.dtype), st)
        napp = cfg.num_layers // cfg.hybrid.attn_every
        c["shared_kv"] = B.GQACache(
            jnp.zeros((napp, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((napp, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype))
    elif plan.kind == "encdec":
        c["kv"] = B.GQACache(
            jnp.zeros((Lh, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((Lh, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype))
        c["enc_kv"] = (
            jnp.zeros((Lh, batch, cfg.encdec.encoder_seq, cfg.num_kv_heads,
                       cfg.head_dim), dtype),
            jnp.zeros((Lh, batch, cfg.encdec.encoder_seq, cfg.num_kv_heads,
                       cfg.head_dim), dtype))
    return c


# ---------------------------------------------------------------------------
# Per-layer traced metadata (local/global pattern, rope theta)
# ---------------------------------------------------------------------------

def layer_meta(cfg: ArchConfig, n: int, offset: int = 0):
    """Arrays [n]: (is_local f32, rope_theta f32) for scanned layers."""
    kinds = [cfg.pattern_at(offset + i) for i in range(n)]
    is_local = jnp.array([1.0 if k == "local" else 0.0 for k in kinds],
                         jnp.float32)
    theta = jnp.array([(cfg.local_rope_theta or cfg.rope_theta)
                       if k == "local" else cfg.rope_theta for k in kinds],
                      jnp.float32)
    return is_local, theta


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

class ForwardOut(NamedTuple):
    logits: jax.Array | None
    hidden: jax.Array
    caches: dict | None
    aux: dict


def _embed_in(params, cfg: ArchConfig, inputs) -> jax.Array:
    if cfg.embedding_inputs:
        x = inputs
    else:
        x = L.embed(params["embed"], inputs)
    if cfg.scale_embeddings:
        x = x * math.sqrt(cfg.d_model)
    x = x.astype(jnp.bfloat16 if cfg.param_dtype == jnp.bfloat16
                 else cfg.param_dtype)
    return shard(x, "batch", "seq_sp", "embed_act")


def _unembed(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    w = params.get("unembed", params.get("embed"))
    logits = L.unembed(w, x, cap=cfg.logit_softcap)
    return shard(logits, "batch", "seq_sp", "vocab")


def forward(params: dict, cfg: ArchConfig, inputs, positions: jax.Array,
            *, mode: str = "train", caches: dict | None = None,
            mrope_positions: jax.Array | None = None,
            enc_inputs: jax.Array | None = None,
            want_logits: bool = True) -> ForwardOut:
    """Run the stack.  inputs: token ids [B,S] or embeddings [B,S,d]."""
    plan = stack_plan(cfg)
    aux: dict[str, Any] = {"moe_lb": jnp.zeros((), jnp.float32),
                           "moe_dropped": jnp.zeros((), jnp.float32)}
    train = mode == "train"
    x = _embed_in(params, cfg, inputs)
    lens = caches["lens"] if caches is not None else None
    new_caches = dict(caches) if caches is not None else None

    if plan.kind == "lm":
        x, new_caches, aux = _forward_lm(params, cfg, plan, x, positions, mode,
                                         caches, new_caches, aux,
                                         mrope_positions)
    elif plan.kind == "ssm":
        x, new_caches = _forward_ssm(params, cfg, x, mode, caches, new_caches)
    elif plan.kind == "hybrid":
        x, new_caches = _forward_hybrid(params, cfg, x, positions, mode,
                                        caches, new_caches)
    elif plan.kind == "encdec":
        x, new_caches = _forward_encdec(params, cfg, x, positions, mode,
                                        caches, new_caches, enc_inputs)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if new_caches is not None and "lens" in (new_caches or {}):
        q = (inputs.shape[1] if not cfg.embedding_inputs else inputs.shape[1])
        if mode == "decode":
            new_caches["lens"] = lens + q
        elif mode == "prefill":
            new_caches["lens"] = jnp.full_like(lens, q) if lens is not None \
                else jnp.full((x.shape[0],), q, jnp.int32)
    logits = _unembed(params, cfg, x) if want_logits else None
    return ForwardOut(logits, x, new_caches, aux)


# --- LM stack (dense / moe / mla) ------------------------------------------

def _forward_lm(params, cfg, plan, x, positions, mode, caches, new_caches,
                aux, mrope_positions):
    lens = caches["lens"] if caches is not None else None
    is_mla = cfg.attn_kind == "mla"
    moe_on = cfg.moe is not None

    def run_group(x, pdefs, n, offset, moe, cache_sl):
        """Scan (or unroll) a homogeneous group of n layers."""
        is_local, theta = layer_meta(cfg, n, offset)

        def body(carry, per_layer):
            xx, = carry
            lp, loc, th, csl = per_layer
            y, new_c, maux = maybe_remat(_apply_layer, cfg, mode)(
                lp, xx, loc, th, csl)
            y = shard(y, "batch", "seq_sp", "embed_act")  # SP / WS resid
            return (y,), (new_c, maux)

        def _apply_layer(lp, xx, loc, th, csl):
            if is_mla:
                y, nc, ma = B.mla_block(lp, cfg, xx, positions, mode=mode,
                                        cache=csl, lens=lens, moe=moe,
                                        train=(mode == "train"))
            else:
                kind = "local" if cfg.sliding_window else "global"
                # traced local/global: window folded via loc flag
                y, nc, ma = _gqa_traced(lp, cfg, xx, positions, mode, csl,
                                        lens, loc, th, mrope_positions, moe)
            return y, nc, ma

        if cfg.scan_layers and n > 1:
            (x_out,), (cstack, mauxs) = jax.lax.scan(
                body, (x,), (pdefs, is_local, theta, cache_sl))
            maux = jax.tree.map(lambda a: a.mean(), mauxs)
            return x_out, cstack, maux
        else:
            ncs, mas = [], []
            for i in range(n):
                lp = jax.tree.map(lambda a: a[i], pdefs)
                csl = jax.tree.map(lambda a: a[i], cache_sl) \
                    if cache_sl is not None else None
                x, nc, ma = _apply_layer(lp, x, is_local[i], theta[i], csl)
                ncs.append(nc)
                mas.append(ma)
            cstack = jax.tree.map(lambda *a: jnp.stack(a), *ncs) \
                if ncs[0] is not None else None
            maux = None
            if moe and mas[0] is not None:
                maux = jax.tree.map(lambda *a: jnp.stack(a).mean(), *mas)
            return x, cstack, maux

    cache_key = "mla" if is_mla else "kv"
    full_cache = caches[cache_key] if caches is not None else None

    off = 0
    if plan.dense_layers:
        dc = jax.tree.map(lambda a: a[:plan.dense_layers], full_cache) \
            if full_cache is not None else None
        x, dstack, _ = run_group(x, params["dense_layers"], plan.dense_layers,
                                 0, False, dc)
        off = plan.dense_layers
    else:
        dstack = None

    mc = jax.tree.map(lambda a: a[off:], full_cache) \
        if full_cache is not None else None
    x, mstack, maux = run_group(x, params["layers"], plan.main_layers, off,
                                moe_on, mc)
    if maux is not None:
        aux["moe_lb"] = maux.load_balance_loss
        aux["moe_dropped"] = maux.dropped_fraction

    if new_caches is not None and mstack is not None:
        if dstack is not None:
            full = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                dstack, mstack)
        else:
            full = mstack
        new_caches[cache_key] = full
    elif mode == "prefill":
        # prefill without pre-allocated caches: build fresh
        if dstack is not None:
            full = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                dstack, mstack)
        else:
            full = mstack
        if new_caches is None:
            new_caches = {}
        new_caches[cache_key] = full
        new_caches["lens"] = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    return x, new_caches, aux


def _gqa_traced(lp, cfg, xx, positions, mode, csl, lens, loc, th,
                mrope_positions, moe):
    """gqa_block; mixed local/global stacks pass a traced window override
    (huge value == global) so one scan body serves both layer kinds."""
    wov = None
    if cfg.layer_pattern is not None and cfg.sliding_window is not None:
        wov = jnp.where(loc > 0.5, jnp.float32(cfg.sliding_window),
                        jnp.float32(2 ** 30))
    kind = "local" if (cfg.layer_pattern is None and cfg.sliding_window) \
        else "global"
    return B.gqa_block(lp, cfg, xx, positions, mode=mode, kind=kind,
                       cache=csl, lens=lens,
                       cache_positions=_cache_positions(csl),
                       rope_theta=th, mrope_positions=mrope_positions,
                       window_override=wov, moe=moe, train=(mode == "train"))


def _cache_positions(csl):
    if csl is None:
        return None
    S = csl.k.shape[1]
    B_ = csl.k.shape[0]
    return jnp.broadcast_to(jnp.arange(S)[None, :], (B_, S))


# --- SSM stack ---------------------------------------------------------------

def _forward_ssm(params, cfg, x, mode, caches, new_caches):
    st = caches["ssm"] if caches is not None else None

    def body(carry, per_layer):
        xx, = carry
        lp, stl = per_layer
        y, st2 = B.ssm_block(lp, cfg, xx, mode=mode, state=stl)
        return (y,), st2

    n = cfg.num_layers
    if st is None and mode != "train":
        st = jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype),
                          S.init_state(cfg, x.shape[0]))
    if mode == "train":
        def body_t(carry, lp):
            xx, = carry
            fn = maybe_remat(
                lambda l, z: B.ssm_block(l, cfg, z, mode="train", state=None),
                cfg, "train")
            y, _ = fn(lp, xx)
            return (y,), None
        (x,), _ = jax.lax.scan(body_t, (x,), params["layers"])
        return x, new_caches
    (x,), st_new = jax.lax.scan(body, (x,), (params["layers"], st))
    if new_caches is None:
        new_caches = {"lens": jnp.full((x.shape[0],), x.shape[1], jnp.int32)}
    new_caches["ssm"] = st_new
    return x, new_caches


# --- Hybrid (Zamba2) ---------------------------------------------------------

def _forward_hybrid(params, cfg, x, positions, mode, caches, new_caches):
    g = cfg.hybrid.attn_every
    ngroups = cfg.num_layers // g
    rem = cfg.num_layers % g
    lens = caches["lens"] if caches is not None else None
    st = caches["ssm"] if caches is not None else None
    skv = caches["shared_kv"] if caches is not None else None
    if st is None and mode != "train":
        st = jax.tree.map(lambda a: jnp.zeros((cfg.num_layers,) + a.shape,
                                              a.dtype),
                          S.init_state(cfg, x.shape[0]))

    nsel = cfg.hybrid.num_shared_attn

    def group_body(carry, per_group):
        xx, = carry
        gi, lps, stl, kvs = per_group
        # g ssm layers (unrolled inside; g is small)
        st_outs = []
        for i in range(g):
            lp = jax.tree.map(lambda a: a[i], lps)
            s_i = jax.tree.map(lambda a: a[i], stl) if stl is not None else None
            xx, st2 = B.ssm_block(lp, cfg, xx, mode=mode, state=s_i)
            st_outs.append(st2)
        st_new = jax.tree.map(lambda *a: jnp.stack(a), *st_outs) \
            if st_outs[0] is not None else None
        # shared attention block (alternating weights)
        parity = (gi % nsel).astype(jnp.int32)
        sel = jax.tree.map(
            lambda a: jnp.take(a, parity, axis=0), params["shared_attn"])
        y, kv_new, _ = B.gqa_block(sel, cfg, xx, positions, mode=mode,
                                   kind="global", cache=kvs, lens=lens,
                                   cache_positions=(_cache_positions(kvs)
                                                    if kvs is not None else None))
        return (y,), (st_new, kv_new)

    lps_g = jax.tree.map(lambda a: a[:ngroups * g].reshape((ngroups, g) +
                                                           a.shape[1:]),
                         params["layers"])
    st_g = jax.tree.map(lambda a: a[:ngroups * g].reshape((ngroups, g) +
                                                          a.shape[1:]), st) \
        if st is not None else None
    gi = jnp.arange(ngroups)
    (x,), (st_new, kv_new) = jax.lax.scan(
        group_body, (x,), (gi, lps_g, st_g, skv))

    # remainder ssm layers
    st_rem_out = None
    if rem:
        def rem_body(carry, per_layer):
            xx, = carry
            lp, stl = per_layer
            y, st2 = B.ssm_block(lp, cfg, xx, mode=mode, state=stl)
            return (y,), st2
        lps_r = jax.tree.map(lambda a: a[ngroups * g:], params["layers"])
        st_r = jax.tree.map(lambda a: a[ngroups * g:], st) \
            if st is not None else None
        (x,), st_rem_out = jax.lax.scan(rem_body, (x,), (lps_r, st_r))

    if mode != "train":
        if new_caches is None:
            new_caches = {"lens": jnp.full((x.shape[0],), x.shape[1],
                                           jnp.int32)}
        full_st = st_new
        full_st = jax.tree.map(lambda a: a.reshape((ngroups * g,) + a.shape[2:]),
                               full_st)
        if rem and st_rem_out is not None:
            full_st = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                   full_st, st_rem_out)
        new_caches["ssm"] = full_st
        new_caches["shared_kv"] = kv_new
    return x, new_caches


# --- Encoder-decoder (Whisper backbone) --------------------------------------

def _forward_encdec(params, cfg, x, positions, mode, caches, new_caches,
                    enc_inputs):
    lens = caches["lens"] if caches is not None else None

    if mode != "decode":
        # run encoder on enc_inputs (precomputed frame embeddings, stub)
        assert enc_inputs is not None
        e = enc_inputs.astype(x.dtype)
        epos = jnp.broadcast_to(jnp.arange(e.shape[1])[None, :],
                                e.shape[:2])

        def enc_layer(lp, xx):
            h = L.rmsnorm(lp["ln1"], xx, cfg.norm_eps)
            q, k, v = A.project_qkv(lp["attn"], cfg, h, epos)
            q = shard(q, "batch", "seq_sp", None, None)
            kk = A.repeat_kv(k, cfg.num_heads // cfg.num_kv_heads)
            vv = A.repeat_kv(v, cfg.num_heads // cfg.num_kv_heads)
            o = A.mha_dense(q, kk, vv, jnp.zeros((), jnp.float32),
                            cfg.head_dim ** -0.5, None)
            xx = xx + jnp.einsum("bqhk,hkd->bqd", o, lp["attn"]["wo"])
            h2 = L.rmsnorm(lp["ln2"], xx, cfg.norm_eps)
            xx = xx + L.mlp(lp["ffn"], h2, cfg.act)
            return shard(xx, "batch", "seq_sp", None)

        def enc_body(carry, lp):
            xx, = carry
            return (maybe_remat(enc_layer, cfg, mode)(lp, xx),), None

        (e,), _ = jax.lax.scan(enc_body, (e,), params["encoder"])
        e = L.rmsnorm(params["enc_norm"], e, cfg.norm_eps)

        # per-decoder-layer cross KV
        def ckv_body(_, lp):
            k, v = A.cross_kv(lp["cross"], cfg, e)
            return None, (k, v)
        _, enc_kv = jax.lax.scan(ckv_body, None, params["decoder"])
    else:
        enc_kv = caches["enc_kv"]

    kv = caches["kv"] if caches is not None else None

    def dec_layer(lp, xx, csl, ek, ev):
        y, nc, _ = B.gqa_block(lp, cfg, xx, positions, mode=mode, kind="global",
                               cache=csl, lens=lens,
                               cache_positions=(_cache_positions(csl)
                                                if csl is not None else None),
                               enc_kv=(ek, ev))
        return shard(y, "batch", "seq_sp", None), nc

    def dec_body(carry, per_layer):
        xx, = carry
        lp, csl, ek, ev = per_layer
        y, nc = maybe_remat(dec_layer, cfg, mode)(lp, xx, csl, ek, ev)
        return (y,), nc

    (x,), kv_new = jax.lax.scan(dec_body, (x,),
                                (params["decoder"], kv, enc_kv[0], enc_kv[1]))
    if mode != "train":
        if new_caches is None:
            new_caches = {"lens": jnp.full((x.shape[0],), x.shape[1],
                                           jnp.int32)}
        new_caches["kv"] = kv_new
        new_caches["enc_kv"] = enc_kv
    return x, new_caches
