"""Mamba2 (SSD — state-space duality) blocks, pure JAX.

Chunked SSD for train/prefill (quadratic within chunk, linear across
chunks), recurrent step for decode.  Separate z/x/B/C/dt projections keep
TP sharding of the inner dim clean (heads and d_inner both split over the
``model`` axis).

References: Mamba-2 [arXiv:2405.21060] minimal SSD; Zamba2 hybrid
[arXiv:2411.15242] consumes these blocks via models/blocks.py.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.params import ParamDef


def ssm_def(cfg: ArchConfig) -> dict:
    s = cfg.ssm
    dt = cfg.param_dtype
    d = cfg.d_model
    din = s.d_inner(d)
    H = s.nheads(d)
    g = s.ngroups
    return {
        "w_z": ParamDef((d, din), dt, "normal", axes=("embed", "ff")),
        "w_x": ParamDef((d, din), dt, "normal", axes=("embed", "ff")),
        "w_B": ParamDef((d, g * s.state_dim), dt, "normal", axes=("embed", None)),
        "w_C": ParamDef((d, g * s.state_dim), dt, "normal", axes=("embed", None)),
        "w_dt": ParamDef((d, H), dt, "normal", axes=("embed", "heads")),
        "dt_bias": ParamDef((H,), jnp.float32, "zeros", axes=("heads",)),
        "A_log": ParamDef((H,), jnp.float32, "zeros", axes=("heads",)),
        "D": ParamDef((H,), jnp.float32, "ones", axes=("heads",)),
        "conv_x": ParamDef((s.conv_width, din), dt, "normal", axes=(None, "ff")),
        "conv_B": ParamDef((s.conv_width, g * s.state_dim), dt, "normal"),
        "conv_C": ParamDef((s.conv_width, g * s.state_dim), dt, "normal"),
        "norm": ParamDef((din,), dt, "zeros", axes=("ff",)),
        "w_out": ParamDef((din, d), dt, "normal", axes=("ff", "embed")),
    }


class SSMState(NamedTuple):
    """Decode-time recurrent state (per layer)."""
    h: jax.Array          # [B, H, P, N] SSM state
    conv_x: jax.Array     # [B, W-1, din]
    conv_B: jax.Array     # [B, W-1, g*N]
    conv_C: jax.Array     # [B, W-1, g*N]


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> SSMState:
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    H = s.nheads(cfg.d_model)
    W = s.conv_width
    return SSMState(
        jnp.zeros((batch, H, s.head_dim, s.state_dim), dtype),
        jnp.zeros((batch, W - 1, din), dtype),
        jnp.zeros((batch, W - 1, s.ngroups * s.state_dim), dtype),
        jnp.zeros((batch, W - 1, s.ngroups * s.state_dim), dtype),
    )


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x [B,S,C], w [W,C] -> [B,S,C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    # sum of shifted slices — cheap for W=4, fuses into a few adds
    out = jnp.zeros_like(x, dtype=jnp.float32)
    S = x.shape[1]
    for i in range(W):
        out = out + xp[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _segsum(a: jax.Array) -> jax.Array:
    """a [..., L] -> lower-triangular pairwise sums [..., L, L]."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :] + a[..., None, :] * 0.0
    # segsum[i,j] = sum_{k=j+1..i} a_k = cs_i - cs_j   (i >= j)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x: jax.Array, a_dt: jax.Array, B: jax.Array, C: jax.Array,
                chunk: int, h0: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """SSD scan.  x [b,s,h,p], a_dt [b,s,h] (= dt*A, negative),
    B,C [b,s,g,n] (g broadcast over heads).  Returns (y [b,s,h,p],
    final state [b,h,p,n])."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    ac = a_dt.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)     # [b,h,nc,l]
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)                             # [b,nc,l,h,n]
    Ch = jnp.repeat(Cc, rep, axis=3)

    Lmat = jnp.exp(_segsum(ac))                                  # [b,h,nc,l,l]
    # intra-chunk (diagonal blocks)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        Ch.astype(jnp.float32), Bh.astype(jnp.float32),
                        Lmat, xc.astype(jnp.float32))
    # chunk summary states
    a_cum = jnp.cumsum(ac, axis=-1)                              # [b,h,nc,l]
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)              # [b,h,nc,l]
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn",
                        Bh.astype(jnp.float32), decay_states,
                        xc.astype(jnp.float32))                  # [b,nc,h,p,n]
    # inter-chunk recurrence (sequential over nc)
    chunk_decay = jnp.exp(a_cum[..., -1])                        # [b,h,nc]

    def step(hprev, inp):
        st, dec = inp                                            # [b,h,p,n],[b,h]
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev                                       # emit state *before* chunk

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    st_seq = states.transpose(1, 0, 2, 3, 4)                     # [nc,b,h,p,n]
    dec_seq = chunk_decay.transpose(2, 0, 1)                     # [nc,b,h]
    h_final, h_prevs = jax.lax.scan(step, h0, (st_seq, dec_seq))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                   # [b,nc,h,p,n]
    # inter-chunk contribution
    state_decay = jnp.exp(a_cum)                                 # [b,h,nc,l]
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       Ch.astype(jnp.float32), h_prevs, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, h_final


def ssd_sequential(x, a_dt, B, C, h0=None):
    """O(S) sequential reference (oracle for tests). Same shapes as above."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(hprev, inp):
        xt, at, Bt, Ct = inp
        hnew = hprev * jnp.exp(at)[..., None, None] + \
            jnp.einsum("bhp,bhn->bhpn", xt.astype(jnp.float32),
                       Bt.astype(jnp.float32))
        yt = jnp.einsum("bhpn,bhn->bhp", hnew, Ct.astype(jnp.float32))
        return hnew, yt

    xs = (x.transpose(1, 0, 2, 3), a_dt.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    hf, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), hf


def ssm_forward(p: dict, cfg: ArchConfig, u: jax.Array,
                state: SSMState | None = None, *, mode: str = "train"
                ) -> tuple[jax.Array, SSMState | None]:
    """Full Mamba2 block.  u [B,S,d] -> (y [B,S,d], new decode state).

    mode="train"/"prefill": chunked SSD over the sequence (state returned
    for decode continuation when ``state`` is not None or mode="prefill").
    mode="decode": recurrent update using ``state`` (S = q_len tokens,
    processed sequentially — S is 1..4 in practice).
    """
    s = cfg.ssm
    B_, S, d = u.shape
    din = s.d_inner(d)
    H = s.nheads(d)
    P = s.head_dim
    g, N = s.ngroups, s.state_dim

    z = u @ p["w_z"]
    xr = u @ p["w_x"]
    Br = u @ p["w_B"]
    Cr = u @ p["w_C"]
    dt_raw = u.astype(jnp.float32) @ p["w_dt"].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])                  # [B,S,H]
    A = -jnp.exp(p["A_log"])                                     # [H]

    if mode == "decode":
        assert state is not None
        # roll conv states token by token
        def one(st: SSMState, t):
            xt, Bt, Ct, dtt = t
            cx = jnp.concatenate([st.conv_x, xt[:, None]], axis=1)
            cB = jnp.concatenate([st.conv_B, Bt[:, None]], axis=1)
            cC = jnp.concatenate([st.conv_C, Ct[:, None]], axis=1)
            xt = jax.nn.silu(jnp.einsum(
                "bwc,wc->bc", cx.astype(jnp.float32),
                p["conv_x"].astype(jnp.float32)))
            Btc = jax.nn.silu(jnp.einsum(
                "bwc,wc->bc", cB.astype(jnp.float32),
                p["conv_B"].astype(jnp.float32)))
            Ctc = jax.nn.silu(jnp.einsum(
                "bwc,wc->bc", cC.astype(jnp.float32),
                p["conv_C"].astype(jnp.float32)))
            xh = xt.reshape(B_, H, P)
            Bh = jnp.repeat(Btc.reshape(B_, g, N), H // g, axis=1)
            Ch = jnp.repeat(Ctc.reshape(B_, g, N), H // g, axis=1)
            a = jnp.exp(dtt * A)                                 # [B,H]
            hnew = st.h * a[..., None, None] + jnp.einsum(
                "bhp,bhn,bh->bhpn", xh, Bh, dtt)
            yt = jnp.einsum("bhpn,bhn->bhp", hnew, Ch)
            yt = yt + p["D"][None, :, None] * xh
            st2 = SSMState(hnew, cx[:, 1:], cB[:, 1:], cC[:, 1:])
            return st2, yt.reshape(B_, din)

        ts = (xr.transpose(1, 0, 2), Br.transpose(1, 0, 2),
              Cr.transpose(1, 0, 2), dt.transpose(1, 0, 2))
        state, ys = jax.lax.scan(one, state, ts)
        y = ys.transpose(1, 0, 2)
    else:
        xc = jax.nn.silu(_causal_conv(xr, p["conv_x"]))
        Bc = jax.nn.silu(_causal_conv(Br, p["conv_B"]))
        Cc = jax.nn.silu(_causal_conv(Cr, p["conv_C"]))
        xh = xc.reshape(B_, S, H, P)
        xh = shard(xh, "batch", None, "heads", None)
        Bh = Bc.reshape(B_, S, g, N)
        Ch = Cc.reshape(B_, S, g, N)
        a_dt = dt * A                                            # [B,S,H]
        chunk = min(s.chunk, S)
        pad = (-S) % chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            a_dt = jnp.pad(a_dt, ((0, 0), (0, pad), (0, 0)))
            Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        h0 = state.h if state is not None else None
        y4, hf = ssd_chunked(xh * dt[..., None] if pad == 0 else
                             xh * jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))[..., None],
                             a_dt, Bh, Ch, chunk, h0)
        y4 = y4[:, :S]
        y4 = y4 + p["D"][None, None, :, None] * xh[:, :S].astype(jnp.float32)
        y = y4.reshape(B_, S, din).astype(u.dtype)
        if mode == "prefill" or state is not None:
            W = s.conv_width
            tail = lambda r: jnp.pad(r, ((0, 0), (max(0, W - 1 - S), 0), (0, 0))
                                     )[:, -(W - 1):]
            state = SSMState(hf, tail(xr), tail(Br), tail(Cr))
        else:
            state = None

    # gated RMSNorm + out projection
    y = L.rmsnorm(p["norm"], y.astype(u.dtype) * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["w_out"], state
