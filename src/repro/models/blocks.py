"""Per-layer blocks: (attention | MLA | SSM) + (MLP | MoE), pre/post norms.

Each block kind provides ``*_def`` (ParamDef tree) and an apply function
taking ``mode`` ∈ {train, prefill, decode} plus the relevant cache slice.
Caches are threaded functionally: apply returns (y, new_cache_slice).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as M
from repro.models import moe as MoE
from repro.models import ssm as S
from repro.models.params import ParamDef


# ---------------------------------------------------------------------------
# GQA transformer block (dense or MoE ffn)
# ---------------------------------------------------------------------------

def gqa_block_def(cfg: ArchConfig, *, moe: bool = False,
                  cross: bool = False) -> dict:
    dt = cfg.param_dtype
    d = cfg.d_model
    p = {
        "ln1": L.rmsnorm_def(d, dt),
        "attn": A.attn_def(cfg),
        "ln2": L.rmsnorm_def(d, dt),
    }
    p["ffn"] = MoE.moe_def(cfg) if moe else L.mlp_def(d, cfg.d_ff, dt)
    if cfg.post_block_norm:
        p["ln1_post"] = L.rmsnorm_def(d, dt)
        p["ln2_post"] = L.rmsnorm_def(d, dt)
    if cross:
        p["ln_cross"] = L.rmsnorm_def(d, dt)
        p["cross"] = A.attn_def(cfg, cross=True)
    return p


class GQACache(NamedTuple):
    k: jax.Array          # [B, S, KV, hd]
    v: jax.Array
    # positions/len live at stack level (shared across layers)


def _write_cache(cache: GQACache, k_new: jax.Array, v_new: jax.Array,
                 lens: jax.Array) -> GQACache:
    """Scatter Q new tokens at per-sequence offsets ``lens`` (decode append)."""
    B, Q = k_new.shape[0], k_new.shape[1]
    idx = lens[:, None] + jnp.arange(Q)[None, :]                  # [B,Q]
    bi = jnp.arange(B)[:, None]
    k = cache.k.at[bi, idx].set(k_new.astype(cache.k.dtype), mode="drop")
    v = cache.v.at[bi, idx].set(v_new.astype(cache.v.dtype), mode="drop")
    return GQACache(k, v)


def gqa_block(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
              *, mode: str, kind: str = "global",
              cache: GQACache | None = None, lens: jax.Array | None = None,
              cache_positions: jax.Array | None = None,
              rope_theta: jax.Array | float | None = None,
              mrope_positions: jax.Array | None = None,
              enc_kv: tuple[jax.Array, jax.Array] | None = None,
              window_override: jax.Array | float | None = None,
              moe: bool = False, train: bool = False):
    """Returns (y, new_cache, moe_aux|None)."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if mode == "decode":
        # write the new tokens into the cache FIRST so they attend to
        # themselves (and to each other, causally, for MTP q_len > 1)
        q, k, v = A.project_qkv(p["attn"], cfg, h, positions,
                                rope_theta=rope_theta,
                                mrope_positions=mrope_positions)
        cache = _write_cache(cache, k, v, lens)
        groups = cfg.num_heads // cfg.num_kv_heads
        kk = A.repeat_kv(cache.k, groups)
        vv = A.repeat_kv(cache.v, groups)
        window = window_override if window_override is not None else \
            (cfg.sliding_window if kind == "local" else None)
        # keep cache operands in their storage dtype (fp32 casts would
        # materialize a full cache copy that the partitioner then reshards);
        # accumulate in fp32 via preferred_element_type
        s = jnp.einsum("bqhk,bshk->bhqs", q, kk,
                       preferred_element_type=jnp.float32) * \
            (cfg.query_scale or cfg.head_dim ** -0.5)
        # consume the cache's sharding: seq-split when kv heads don't
        # divide the model axis (annotate() in launch/steps.py), else
        # head-split — avoids involuntary cache replication
        from repro.distributed.sharding import logical_axis_size
        kv_ok = cfg.num_kv_heads % max(1, logical_axis_size("kv")) == 0 \
            and cfg.num_kv_heads >= logical_axis_size("kv")
        if kv_ok:
            s = shard(s, "batch", "heads", None, None)
        else:
            s = shard(s, "batch", None, None, "seq_sp")
        s = L.softcap(s, cfg.attn_softcap)
        s = s + A.causal_mask_bias(positions[:, None, :],
                                   cache_positions[:, None, :], window)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshk->bqhk", w.astype(vv.dtype), vv,
                       preferred_element_type=jnp.float32)
        attn_out = jnp.einsum("bqhk,hkd->bqd", o.astype(x.dtype),
                              p["attn"]["wo"])
    else:
        ao = A.attention(p["attn"], cfg, h, positions, kind=kind, mode=mode,
                         rope_theta=rope_theta, mrope_positions=mrope_positions,
                         window_override=window_override)
        if mode == "prefill":
            cache = GQACache(ao.k, ao.v)
        attn_out = ao.out
    if cfg.post_block_norm:
        attn_out = L.rmsnorm(p["ln1_post"], attn_out, cfg.norm_eps)
    x = x + attn_out

    if enc_kv is not None:
        hc = L.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + A.cross_attention(p["cross"], cfg, hc, enc_kv[0], enc_kv[1])

    h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = None
    if moe:
        f, aux = MoE.moe_apply(p["ffn"], cfg, h2, train=train)
    else:
        f = L.mlp(p["ffn"], h2, cfg.act)
    if cfg.post_block_norm:
        f = L.rmsnorm(p["ln2_post"], f, cfg.norm_eps)
    return x + f, cache, aux


# ---------------------------------------------------------------------------
# MLA block (DeepSeek): MLA attention + (dense | MoE) ffn + optional DSA
# ---------------------------------------------------------------------------

def mla_block_def(cfg: ArchConfig, *, moe: bool, dense_ff: int | None = None
                  ) -> dict:
    dt = cfg.param_dtype
    d = cfg.d_model
    p = {
        "ln1": L.rmsnorm_def(d, dt),
        "mla": M.mla_def(cfg),
        "ln2": L.rmsnorm_def(d, dt),
    }
    if cfg.dsa is not None:
        p["indexer"] = M.indexer_def(cfg)
    if moe:
        p["ffn"] = MoE.moe_def(cfg)
    else:
        p["ffn"] = L.mlp_def(d, dense_ff or cfg.d_ff, dt)
    return p


class MLACache(NamedTuple):
    latent: jax.Array     # [B, S, latent_dim]
    ikeys: jax.Array      # [B, S, index_dim] (zeros when no DSA)


def mla_write_cache(cfg: ArchConfig, p: dict, cache: MLACache, x_norm: jax.Array,
                    positions: jax.Array, lens: jax.Array) -> MLACache:
    """Append new latent entries (and indexer keys) at per-seq offsets."""
    new_lat = M.latent_entries(p["mla"], cfg, x_norm, positions)
    B, Q = new_lat.shape[:2]
    idx = lens[:, None] + jnp.arange(Q)[None, :]
    bi = jnp.arange(B)[:, None]
    lat = cache.latent.at[bi, idx].set(new_lat.astype(cache.latent.dtype),
                                       mode="drop")
    ik = cache.ikeys
    if "indexer" in p:
        new_k = M.indexer_keys(p["indexer"], x_norm)
        ik = ik.at[bi, idx].set(new_k.astype(ik.dtype), mode="drop")
    return MLACache(lat, ik)


def mla_block(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
              *, mode: str, cache: MLACache | None = None,
              lens: jax.Array | None = None, moe: bool = False,
              train: bool = False):
    """Returns (y, new_cache, moe_aux|None)."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    pi = p.get("indexer")
    if mode == "decode":
        # append first so new tokens can attend to themselves
        cache = mla_write_cache(cfg, p, cache, h, positions, lens)
        new_len = lens + h.shape[1]
        if pi is not None:
            out, _ = M.sparse_mla_decode(p["mla"], pi, cfg, h, positions,
                                         cache.latent, cache.ikeys, new_len)
        else:
            out = mla_dense_decode(p, cfg, h, positions, cache, new_len)
        attn_out = out
    elif mode == "prefill":
        out, lat, ikeys = M.mla_prefill_attend(p["mla"], pi, cfg, h, positions)
        if ikeys is None:
            ikeys = jnp.zeros(lat.shape[:2] + (1,), lat.dtype)
        cache = MLACache(lat, ikeys)
        attn_out = out
    else:
        attn_out = M.mla_train_attend(p["mla"], pi, cfg, h, positions)
    x = x + attn_out

    h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = None
    if moe:
        f, aux = MoE.moe_apply(p["ffn"], cfg, h2, train=train)
    else:
        f = L.mlp(p["ffn"], h2, cfg.act)
    return x + f, cache, aux


def mla_dense_decode(p: dict, cfg: ArchConfig, h: jax.Array,
                     positions: jax.Array, cache: MLACache, new_len: jax.Array
                     ) -> jax.Array:
    """Full (non-sparse) MLA decode over the latent cache (V3 baseline)."""
    q = M.absorbed_query(p["mla"], cfg, h, positions)
    S = cache.latent.shape[1]
    valid = jnp.arange(S)[None, :] < new_len[:, None]
    part = M.partial_sparse_attend(q, cache.latent, valid, cfg)
    o_lat = M.finalize_partial(part, h.dtype)
    return M.output_proj(p["mla"], cfg, o_lat)


# ---------------------------------------------------------------------------
# SSM (Mamba2) block
# ---------------------------------------------------------------------------

def ssm_block_def(cfg: ArchConfig) -> dict:
    return {"ln": L.rmsnorm_def(cfg.d_model, cfg.param_dtype),
            "ssm": S.ssm_def(cfg)}


def ssm_block(p: dict, cfg: ArchConfig, x: jax.Array, *, mode: str,
              state: S.SSMState | None = None):
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    y, st = S.ssm_forward(p["ssm"], cfg, h, state, mode=mode)
    return x + y, st
