"""Common neural layers (pure JAX): norms, rotary embeddings, MLPs, heads."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.distributed.sharding import shard


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_def(dim: int, dtype=jnp.float32) -> ParamDef:
    return ParamDef((dim,), dtype, "zeros", axes=("embed",))  # gemma-style (1+w)


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6,
            zero_centered: bool = True) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if zero_centered else w.astype(jnp.float32)
    return (xf * scale).astype(dt)


def layernorm_def(dim: int, dtype=jnp.float32) -> dict:
    return {"w": ParamDef((dim,), dtype, "ones", axes=("embed",)),
            "b": ParamDef((dim,), dtype, "zeros", axes=("embed",))}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None or cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE + NTK/YaRN-lite scaling)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0,
               scaling: float | None = None) -> jax.Array:
    """Inverse frequencies [head_dim//2] (fp32)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if scaling and scaling != 1.0:  # simple linear position-interpolation
        freqs = freqs / scaling
    return freqs


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float = 10000.0,
                 scaling: float | None = None) -> tuple[jax.Array, jax.Array]:
    """positions [...,] int -> cos,sin [..., head_dim//2] fp32."""
    freqs = rope_freqs(head_dim, theta, scaling)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               interleaved: bool = False) -> jax.Array:
    """x [..., H, D]; cos/sin broadcastable to [..., 1, D/2]."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if interleaved:
        x1 = xf[..., 0::2]
        x2 = xf[..., 1::2]
        o1 = x1 * cos - x2 * sin
        o2 = x2 * cos + x1 * sin
        out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    else:
        half = x.shape[-1] // 2
        x1, x2 = xf[..., :half], xf[..., half:]
        o1 = x1 * cos - x2 * sin
        o2 = x2 * cos + x1 * sin
        out = jnp.concatenate([o1, o2], axis=-1)
    return out.astype(dt)


def mrope_cos_sin(positions: jax.Array, head_dim: int, sections: tuple[int, ...],
                  theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """Multimodal RoPE (Qwen2-VL).

    positions: [..., 3] (temporal, height, width) position triples.
    sections: per-component number of *frequency pairs*, summing to head_dim//2
              (e.g. (16, 24, 24) for head_dim=128).
    Text tokens carry identical (t,h,w) so M-RoPE == RoPE on them.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(head_dim, theta)  # [half]
    # component id per frequency slot
    comp = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                            for i, s in enumerate(sections)])
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(comp, positions.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1)  # [..., half]
    ang = pos * freqs
    return jnp.cos(ang), jnp.sin(ang)


# ---------------------------------------------------------------------------
# Dense / GLU MLPs
# ---------------------------------------------------------------------------

def mlp_def(d_model: int, d_ff: int, dtype, gated: bool = True,
            act: str = "silu") -> dict:
    d = {"wo": ParamDef((d_ff, d_model), dtype, "normal", axes=("ff", "embed"))}
    if gated:
        d["wi_gate"] = ParamDef((d_model, d_ff), dtype, "normal", axes=("embed", "ff"))
        d["wi_up"] = ParamDef((d_model, d_ff), dtype, "normal", axes=("embed", "ff"))
    else:
        d["wi"] = ParamDef((d_model, d_ff), dtype, "normal", axes=("embed", "ff"))
    return d


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "gelu_tanh":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    if "wi_gate" in p:
        g = _act(act, x @ p["wi_gate"])
        u = x @ p["wi_up"]
        h = g * u
    else:
        h = _act(act, x @ p["wi"])
    h = shard(h, "batch", None, "ff") if h.ndim == 3 else h
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------

def embed_def(vocab: int, d_model: int, dtype) -> ParamDef:
    return ParamDef((vocab, d_model), dtype, "embed", axes=("vocab", "embed"))


def embed(w: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(w, tokens, axis=0)


def unembed(w: jax.Array, x: jax.Array, *, tied: bool = True,
            cap: float | None = None) -> jax.Array:
    """x [..., d] -> logits [..., vocab] (fp32 accumulation; operands stay
    in storage dtype so no fp32 weight copy is materialized/resharded)."""
    logits = jnp.einsum("...d,vd->...v", x, w,
                        preferred_element_type=jnp.float32)
    return softcap(logits, cap)


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    params: Any = jnp.bfloat16
    compute: Any = jnp.bfloat16
    accum: Any = jnp.float32

    @staticmethod
    def from_name(name: str) -> "DTypePolicy":
        if name == "bf16":
            return DTypePolicy()
        if name == "fp32":
            return DTypePolicy(jnp.float32, jnp.float32, jnp.float32)
        if name == "train_mixed":  # fp32 master params, bf16 compute
            return DTypePolicy(jnp.float32, jnp.bfloat16, jnp.float32)
        raise ValueError(name)
