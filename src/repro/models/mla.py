"""Multi-head Latent Attention (DeepSeek-V3) + DSA lightning indexer
(DeepSeek-V3.2-Exp).

Cache layout (per layer): one **latent entry** per token =
``concat(rmsnorm(c_kv) [kv_lora_rank], rope(k_pe) [qk_rope_head_dim])``
— 576 dims for the 671B config.  Decode uses the *absorbed* (FlashMLA)
formulation: attention becomes MQA of per-head 576-dim queries against the
shared latent cache, which is exactly the object ESS offloads.

The DSA indexer keeps its own per-token key (``index_dim`` dims).  It is
**never offloaded** (paper §3: full computation each step, 16.8 % of bytes).

Decode entry points are split so that ``repro.core.overlap`` can run Attn0
(pool hits) concurrently with the host fetch and merge Attn1 (misses)
exactly — see ``partial_sparse_attend`` / ``merge_partials``.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.params import ParamDef

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def mla_def(cfg: ArchConfig) -> dict:
    m = cfg.mla
    dt = cfg.param_dtype
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "w_dq": ParamDef((d, m.q_lora_rank), dt, "normal", axes=("embed", "lora")),
        "q_norm": ParamDef((m.q_lora_rank,), dt, "zeros", axes=("lora",)),
        "w_uq": ParamDef((m.q_lora_rank, H, qk), dt, "normal",
                         axes=("lora", "heads", None)),
        "w_dkv": ParamDef((d, m.kv_lora_rank), dt, "normal", axes=("embed", "lora")),
        "kv_norm": ParamDef((m.kv_lora_rank,), dt, "zeros", axes=("lora",)),
        "w_kr": ParamDef((d, m.qk_rope_head_dim), dt, "normal", axes=("embed", None)),
        "w_uk": ParamDef((m.kv_lora_rank, H, m.qk_nope_head_dim), dt, "normal",
                         axes=("lora", "heads", None)),
        "w_uv": ParamDef((m.kv_lora_rank, H, m.v_head_dim), dt, "normal",
                         axes=("lora", "heads", None)),
        "wo": ParamDef((H, m.v_head_dim, d), dt, "normal",
                       axes=("heads", None, "embed")),
    }
    return p


def indexer_def(cfg: ArchConfig) -> dict:
    i = cfg.dsa
    dt = cfg.param_dtype
    d = cfg.d_model
    return {
        "w_iq": ParamDef((d, i.index_heads, i.index_dim), dt, "normal",
                         axes=("embed", "idx", None)),
        "w_ik": ParamDef((d, i.index_dim), dt, "normal", axes=("embed", None)),
        "w_iw": ParamDef((d, i.index_heads), dt, "normal", axes=("embed", "idx"),
                         scale=0.02),
    }


def mla_scale(cfg: ArchConfig) -> float:
    m = cfg.mla
    return (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5


# ---------------------------------------------------------------------------
# Latent construction (prefill / train / per-step append)
# ---------------------------------------------------------------------------

def latent_entries(p: dict, cfg: ArchConfig, x: jax.Array,
                   positions: jax.Array) -> jax.Array:
    """x [B,S,d] -> latent cache entries [B,S,latent_dim] (rope baked in)."""
    m = cfg.mla
    c_kv = L.rmsnorm(p["kv_norm"], x @ p["w_dkv"], cfg.norm_eps)
    k_pe = (x @ p["w_kr"])[:, :, None, :]              # [B,S,1,rope]
    cos, sin = L.rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    k_pe = L.apply_rope(k_pe, cos[:, :, None, :], sin[:, :, None, :])[:, :, 0, :]
    return jnp.concatenate([c_kv, k_pe.astype(c_kv.dtype)], axis=-1)


def absorbed_query(p: dict, cfg: ArchConfig, x: jax.Array,
                   positions: jax.Array) -> jax.Array:
    """x [B,Q,d] -> MQA query over latent space [B,Q,H,latent_dim]."""
    m = cfg.mla
    cq = L.rmsnorm(p["q_norm"], x @ p["w_dq"], cfg.norm_eps)
    q = jnp.einsum("bql,lhk->bqhk", cq, p["w_uq"])      # [B,Q,H,nope+rope]
    q_nope, q_pe = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    cos, sin = L.rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_pe = L.apply_rope(q_pe, cos[:, :, None, :], sin[:, :, None, :])
    # absorb W_uk:  q_lat = q_nope @ W_uk^T  (per head)
    q_lat = jnp.einsum("bqhk,lhk->bqhl", q_nope, p["w_uk"])
    return jnp.concatenate([q_lat, q_pe.astype(q_lat.dtype)], axis=-1)


def output_proj(p: dict, cfg: ArchConfig, o_lat: jax.Array) -> jax.Array:
    """o_lat [B,Q,H,kv_lora_rank] -> [B,Q,d] (absorbed W_uv then W_o)."""
    o = jnp.einsum("bqhl,lhv->bqhv", o_lat, p["w_uv"])
    return jnp.einsum("bqhv,hvd->bqd", o, p["wo"])


# ---------------------------------------------------------------------------
# Indexer (DSA)
# ---------------------------------------------------------------------------

def indexer_keys(pi: dict, x: jax.Array) -> jax.Array:
    """Per-token indexer key [B,S,index_dim] — the Indexer-Cache entry."""
    return x @ pi["w_ik"]


class IndexerQuery(NamedTuple):
    q: jax.Array       # [B,Q,Hi,Di]
    w: jax.Array       # [B,Q,Hi]


def indexer_query(pi: dict, x: jax.Array) -> IndexerQuery:
    return IndexerQuery(jnp.einsum("bqd,dhk->bqhk", x, pi["w_iq"]),
                        jnp.einsum("bqd,dh->bqh", x, pi["w_iw"]))


def indexer_scores(iq: IndexerQuery, keys: jax.Array) -> jax.Array:
    """score[b,q,s] = sum_h w[b,q,h] * relu(q[b,q,h] . k[b,s])  (fp32)."""
    dots = jnp.einsum("bqhk,bsk->bqhs", iq.q.astype(jnp.float32),
                      keys.astype(jnp.float32))
    return jnp.einsum("bqh,bqhs->bqs", iq.w.astype(jnp.float32),
                      jax.nn.relu(dots))


def topk_ids(scores: jax.Array, k: int, valid_mask: jax.Array | None = None
             ) -> jax.Array:
    """Top-k cache indices per query row. scores [B,Q,S] -> ids [B,Q,k]."""
    if valid_mask is not None:
        scores = jnp.where(valid_mask, scores, NEG_INF)
    _, ids = jax.lax.top_k(scores, k)
    return ids


# ---------------------------------------------------------------------------
# Sparse attention over gathered latents (decode) — partials + exact merge
# ---------------------------------------------------------------------------

class Partial(NamedTuple):
    """Un-normalized attention partial (flash-decoding statistics)."""
    o: jax.Array       # [B,Q,H,latent_rank]  sum_j exp(s_j - m) * v_j
    m: jax.Array       # [B,Q,H]              running max
    l: jax.Array       # [B,Q,H]              sum_j exp(s_j - m)


def partial_sparse_attend(q_comb: jax.Array, latents: jax.Array,
                          valid: jax.Array, cfg: ArchConfig) -> Partial:
    """Attend q [B,Q,H,D] to gathered latents [B,K,D] with validity mask.

    Returns unnormalized partials so hit/miss halves merge exactly.
    This is the pure-jnp oracle for ``kernels/sparse_mla``.
    """
    rank = cfg.mla.kv_lora_rank
    s = jnp.einsum("bqhd,bkd->bqhk", q_comb, latents,
                   preferred_element_type=jnp.float32) * mla_scale(cfg)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    o = jnp.einsum("bqhk,bkv->bqhv", p.astype(latents.dtype),
                   latents[..., :rank], preferred_element_type=jnp.float32)
    l = p.sum(axis=-1)
    return Partial(o, m, l)


def merge_partials(a: Partial, b: Partial) -> Partial:
    m = jnp.maximum(a.m, b.m)
    ca = jnp.exp(a.m - m)
    cb = jnp.exp(b.m - m)
    return Partial(a.o * ca[..., None] + b.o * cb[..., None],
                   m, a.l * ca + b.l * cb)


def finalize_partial(pt: Partial, dtype=jnp.bfloat16) -> jax.Array:
    return (pt.o / jnp.maximum(pt.l, 1e-30)[..., None]).astype(dtype)


def sparse_mla_decode(p: dict, pi: dict, cfg: ArchConfig, x: jax.Array,
                      positions: jax.Array, latent_cache: jax.Array,
                      idx_keys: jax.Array, cache_len: jax.Array,
                      use_kernel: bool = False) -> tuple[jax.Array, jax.Array]:
    """Monolithic (non-ESS) DSA decode reference.

    x [B,Q,d]; latent_cache [B,S,D]; idx_keys [B,S,Di]; cache_len [B].
    Returns (out [B,Q,d], topk ids [B,Q,K]).  ESS replaces the gather with
    the pool/host split (see repro.core.overlap) but computes the same math.
    """
    S = latent_cache.shape[1]
    valid = jnp.arange(S)[None, :] < cache_len[:, None]          # [B,S]
    iq = indexer_query(pi, x)
    sc = indexer_scores(iq, idx_keys)                            # [B,Q,S]
    k = min(cfg.dsa.index_topk, S)
    ids = topk_ids(sc, k, valid[:, None, :])                     # [B,Q,K]
    # decode: Q small; gather per batch row using the *last* query's ids
    # (Q>1 MTP drafts share the union via per-q gather)
    q_comb = absorbed_query(p, cfg, x, positions)                # [B,Q,H,D]
    if use_kernel:
        from repro.kernels.sparse_mla import ops as sk_ops
        out_lat = sk_ops.sparse_mla_gather_attend(
            q_comb, latent_cache, ids, valid, mla_scale(cfg),
            cfg.mla.kv_lora_rank)
    else:
        B, Q, K = ids.shape
        gl = jnp.take_along_axis(latent_cache[:, None], ids[..., None], axis=2)
        gv = jnp.take_along_axis(valid[:, None], ids, axis=2)    # [B,Q,K]
        s = jnp.einsum("bqhd,bqkd->bqhk", q_comb.astype(jnp.float32),
                       gl.astype(jnp.float32)) * mla_scale(cfg)
        s = jnp.where(gv[:, :, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out_lat = jnp.einsum(
            "bqhk,bqkv->bqhv", w,
            gl[..., :cfg.mla.kv_lora_rank].astype(jnp.float32)
        ).astype(x.dtype)
    return output_proj(p, cfg, out_lat), ids


# ---------------------------------------------------------------------------
# Prefill / train: chunked masked attention with DSA selection
# ---------------------------------------------------------------------------

def dsa_threshold(sc: jax.Array, k: int, valid: jax.Array) -> jax.Array:
    """Per-row k-th largest indexer score (selection threshold). [B,Q]"""
    sc = jnp.where(valid, sc, NEG_INF)
    kk = min(k, sc.shape[-1])
    vals, _ = jax.lax.top_k(sc, kk)
    return vals[..., -1]


def dsa_keep_mask(sc: jax.Array, k: int, valid: jax.Array) -> jax.Array:
    """Exact top-k membership mask [..., S] with ``lax.top_k`` tie
    semantics (lowest index wins among equal scores).

    A ``sc >= threshold`` mask admits *every* tie at the k-th score — and
    the relu'd indexer produces many exact-0.0 ties — so thresholding
    attends to more than k entries while the decode/serve paths gather
    exactly k.  All DSA paths (train, prefill, decode) select through this
    same top-k set so their outputs agree up to fp reassociation."""
    sc = jnp.where(valid, sc, NEG_INF)
    kk = min(k, sc.shape[-1])
    _, ids = jax.lax.top_k(sc, kk)
    keep = jnp.zeros(sc.shape, bool)
    keep = jnp.put_along_axis(keep, ids, True, axis=-1, inplace=False)
    return keep & valid


def mla_train_attend(p: dict, pi: Optional[dict], cfg: ArchConfig,
                     x: jax.Array, positions: jax.Array) -> jax.Array:
    """Dense differentiable MLA (+DSA top-k mask) for train_4k shapes."""
    m = cfg.mla
    B, S, _ = x.shape
    lat = latent_entries(p, cfg, x, positions)                   # [B,S,D]
    q_comb = absorbed_query(p, cfg, x, positions)                # [B,S,H,D]
    q_comb = shard(q_comb, "batch", None, "heads", None)
    s = jnp.einsum("bqhd,bkd->bhqk", q_comb.astype(jnp.float32),
                   lat.astype(jnp.float32)) * mla_scale(cfg)
    causal = positions[:, None, :, None] >= positions[:, None, None, :]
    bias = jnp.where(causal, 0.0, NEG_INF)
    if pi is not None and cfg.dsa is not None and cfg.dsa.index_topk < S:
        iq = indexer_query(pi, x)
        sc = indexer_scores(iq, indexer_keys(pi, x))             # [B,Q,S]
        keep = dsa_keep_mask(sc, cfg.dsa.index_topk, causal[:, 0])
        bias = bias + jnp.where(keep[:, None], 0.0, NEG_INF)
    w = jax.nn.softmax(s + bias, axis=-1)
    o_lat = jnp.einsum("bhqk,bkv->bqhv", w,
                       lat[..., :m.kv_lora_rank].astype(jnp.float32))
    return output_proj(p, cfg, o_lat.astype(x.dtype))


def mla_prefill_attend(p: dict, pi: Optional[dict], cfg: ArchConfig,
                       x: jax.Array, positions: jax.Array,
                       kv_block: int = 2048
                       ) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """Chunked-flash MLA prefill (+DSA threshold mask).

    Returns (out [B,S,d], latent cache [B,S,D], indexer keys or None).
    Two passes when DSA is on: (1) chunked indexer top-k threshold,
    (2) chunked online-softmax attention with the >=threshold mask.
    """
    m = cfg.mla
    B, S, _ = x.shape
    kv_block = min(kv_block, S)
    pad = (-S) % kv_block
    Sp = S + pad
    lat = latent_entries(p, cfg, x, positions)
    q_comb = absorbed_query(p, cfg, x, positions)
    q_comb = shard(q_comb, "batch", None, "heads", None)
    H = q_comb.shape[2]

    ikeys = None
    thr = None
    n_tie = None
    iq = None
    if pi is not None and cfg.dsa is not None and cfg.dsa.index_topk < S:
        ikeys = indexer_keys(pi, x)
        iq = indexer_query(pi, x)
        # pass 1: streaming top-k threshold via per-block running top-k
        k = cfg.dsa.index_topk

        def tb(carry, blk):
            topv = carry
            kc, pc = blk
            sc = indexer_scores(iq, kc)                          # [B,S,kb]
            okc = pc[None, None, :] <= positions[:, :, None]
            sc = jnp.where(okc, sc, NEG_INF)
            allv = jnp.concatenate([topv, sc], axis=-1)
            topv, _ = jax.lax.top_k(allv, k)
            return topv, None

        nb = Sp // kv_block
        ik_p = jnp.pad(ikeys, ((0, 0), (0, pad), (0, 0))) if pad else ikeys
        pos_p1 = jnp.pad(positions, ((0, 0), (0, pad)),
                         constant_values=2 ** 30) if pad else positions
        kb_keys = ik_p.reshape(B, nb, kv_block, -1).transpose(1, 0, 2, 3)
        kb_pos = pos_p1.reshape(B, nb, kv_block).transpose(1, 0, 2)[:, 0]
        top0 = jnp.full((B, S, cfg.dsa.index_topk), NEG_INF, jnp.float32)
        topv, _ = jax.lax.scan(tb, top0, (kb_keys, kb_pos))
        thr = topv[..., -1]                                      # [B,S]
        # exact top-k, lax.top_k tie semantics: besides every score > thr,
        # keep only the first (index order) n_tie scores == thr — a plain
        # ">= thr" mask would admit *all* ties (the relu'd indexer emits
        # many exact-0.0 scores) and diverge from the decode-side gather
        n_tie = k - (topv > thr[..., None]).sum(-1)              # [B,S]

    # pass 2: chunked online-softmax over latent blocks
    nb = Sp // kv_block
    lat_p = jnp.pad(lat, ((0, 0), (0, pad), (0, 0))) if pad else lat
    pos_p = jnp.pad(positions, ((0, 0), (0, pad)),
                    constant_values=2 ** 30) if pad else positions
    ik_p2 = (jnp.pad(ikeys, ((0, 0), (0, pad), (0, 0)))
             if (ikeys is not None and pad) else ikeys)
    lat_b = lat_p.reshape(B, nb, kv_block, -1).transpose(1, 0, 2, 3)
    pos_b = pos_p.reshape(B, nb, kv_block).transpose(1, 0, 2)
    ik_b = (ik_p2.reshape(B, nb, kv_block, -1).transpose(1, 0, 2, 3)
            if ik_p2 is not None else jnp.zeros((nb, B, kv_block, 1), x.dtype))

    def body(carry, blk):
        mx, l, acc, tie_seen = carry
        lc, pc, kc = blk
        s = jnp.einsum("bqhd,bkd->bhqk", q_comb.astype(jnp.float32),
                       lc.astype(jnp.float32)) * mla_scale(cfg)
        ok = pc[:, None, None, :] <= positions[:, None, :, None]
        if thr is not None:
            sc = indexer_scores(iq, kc)                          # [B,S,kb]
            okq = pc[:, None, :] <= positions[:, :, None]        # [B,S,kb]
            gt = (sc > thr[..., None]) & okq
            eq = (sc == thr[..., None]) & okq
            # running index-order rank of threshold ties across blocks
            rank = tie_seen[..., None] + \
                jnp.cumsum(eq.astype(jnp.int32), axis=-1) - eq
            keep = gt | (eq & (rank < n_tie[..., None]))
            tie_seen = tie_seen + eq.sum(axis=-1)
            ok &= keep[:, None]
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(mx, s.max(axis=-1))
        pw = jnp.exp(s - m_new[..., None])
        pw = jnp.where(ok, pw, 0.0)
        corr = jnp.exp(mx - m_new)
        l_new = l * corr + pw.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkv->bhqv", pw, lc[..., :m.kv_lora_rank].astype(jnp.float32))
        return (m_new, l_new, acc_new, tie_seen), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, m.kv_lora_rank), jnp.float32)
    t0 = jnp.zeros((B, S), jnp.int32)
    (mx, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, t0),
                                      (lat_b, pos_b, ik_b))
    o_lat = (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(0, 2, 1, 3)
    out = output_proj(p, cfg, o_lat.astype(x.dtype))
    return out, lat, ikeys
