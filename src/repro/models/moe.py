"""Mixture-of-Experts with fixed-capacity einsum dispatch (EP-shardable).

The dispatch/combine are expressed as one-hot einsums (Mesh-TF / GShard
style) so the SPMD partitioner shards experts over the ``model`` mesh axis
and emits the EP collectives automatically.  Supports:

* softmax top-k routing (DBRX: 16 experts, top-4),
* DeepSeek-V3 sigmoid routing with aux-loss-free bias + routed scaling,
* shared (always-on) experts, leading dense layers,
* capacity-factor token dropping with residual passthrough,
* switch-style load-balance aux loss (training).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.params import ParamDef


def moe_def(cfg: ArchConfig) -> dict:
    mo = cfg.moe
    dt = cfg.param_dtype
    d, E, f = cfg.d_model, mo.num_experts, mo.d_expert
    p = {
        "router": ParamDef((d, E), jnp.float32, "normal", axes=("embed", "experts")),
        "w_gate": ParamDef((E, d, f), dt, "normal", axes=("experts", "embed", "ff")),
        "w_up": ParamDef((E, d, f), dt, "normal", axes=("experts", "embed", "ff")),
        "w_down": ParamDef((E, f, d), dt, "normal", axes=("experts", "ff", "embed")),
    }
    if mo.router_bias:
        p["router_bias"] = ParamDef((E,), jnp.float32, "zeros", axes=("experts",))
    if mo.num_shared:
        p["shared"] = L.mlp_def(d, f * mo.num_shared, dt)
    return p


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_entropy: jax.Array
    dropped_fraction: jax.Array


def router_probs(p: dict, cfg: ArchConfig, x2: jax.Array):
    """x2 [T,d] -> (selection scores [T,E], combine weights base [T,E])."""
    mo = cfg.moe
    logits = x2.astype(jnp.float32) @ p["router"]
    if mo.router_bias:
        gates = jax.nn.sigmoid(logits)
        sel = gates + p["router_bias"][None, :]     # bias only for selection
        return sel, gates
    probs = jax.nn.softmax(logits, axis=-1)
    return probs, probs


def moe_apply(p: dict, cfg: ArchConfig, x: jax.Array, *, train: bool = False
              ) -> tuple[jax.Array, MoEAux]:
    """x [B,S,d] -> (y [B,S,d], aux)."""
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = mo.num_experts, mo.top_k
    x2 = x.reshape(T, d)

    sel, gates = router_probs(p, cfg, x2)                    # [T,E]
    top_vals, top_ids = jax.lax.top_k(sel, K)                # [T,K]
    # combine weights come from the *unbiased* gate values
    w = jnp.take_along_axis(gates, top_ids, axis=-1)         # [T,K]
    if mo.norm_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-20)
    w = w * mo.routed_scale

    capacity = max(1, int(math.ceil(T * K / E * mo.capacity_factor)))
    capacity = min(capacity, T)

    # one-hot expert assignment [T,K,E] and position-in-expert via cumsum
    onehot = jax.nn.one_hot(top_ids, E, dtype=jnp.float32)   # [T,K,E]
    flat = onehot.reshape(T * K, E)
    pos = (jnp.cumsum(flat, axis=0) - flat).reshape(T, K, E) # slot index
    pos = jnp.einsum("tke,tke->tk", pos, onehot)             # [T,K]
    keep = pos < capacity
    w = jnp.where(keep, w, 0.0)

    # dispatch tensor [T,E,C]
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
    disp = jnp.einsum("tke,tkc->tec", onehot * keep[..., None], pos_oh)
    comb = jnp.einsum("tke,tkc,tk->tec", onehot, pos_oh, w)

    xin = jnp.einsum("tec,td->ecd", disp, x2.astype(jnp.float32))
    xin = shard(xin, "experts", None, None).astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xin, p["w_up"])
    h = shard(h, "experts", None, "ff")
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    y = jnp.einsum("tec,ecd->td", comb, out_e.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(B, S, d)

    if mo.num_shared:
        y = y + L.mlp(p["shared"], x, cfg.act)

    # aux stats
    me = onehot.mean(axis=(0, 1)) * E                        # mean routed frac * E
    ce = (sel / jnp.maximum(sel.sum(-1, keepdims=True), 1e-20)).mean(0) * E
    lb = jnp.mean(me * ce)
    ent = -jnp.mean(jnp.sum(jnp.where(gates > 0, gates * jnp.log(gates + 1e-20),
                                      0.0), axis=-1))
    dropped = 1.0 - jnp.sum(keep) / (T * K)
    return y, MoEAux(lb, ent, dropped)
