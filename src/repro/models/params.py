"""Parameter definition system.

Pure-functional substitute for flax: every module describes its parameters as
a pytree of :class:`ParamDef` leaves (shape, dtype, initializer, *logical
axes*).  From one definition tree we derive

* ``init_params``      — materialized arrays (for real runs / smoke tests),
* ``abstract_params``  — ``jax.ShapeDtypeStruct`` stand-ins (for the dry-run;
  no allocation ever happens),
* ``param_pspecs``     — ``PartitionSpec`` tree via logical-axis → mesh-axis
  rules (the sharding side-channel used by ``jax.jit`` in/out shardings).

Logical axis names used across the model zoo:

``embed``   model width (d_model)            ``ff``      feed-forward width
``heads``   query heads                      ``kv``      kv heads
``qk``/``v`` per-head dims                   ``vocab``   vocabulary
``experts`` MoE expert dim                   ``layers``  stacked scan dim
``state``   SSM state dim                    ``conv``    conv channel dim
``lora``    MLA low-rank dims                ``idx``     DSA indexer dims
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """One parameter leaf: shape + dtype + init + logical sharding axes."""

    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # normal | zeros | ones | embed | scaled
    axes: tuple[str | None, ...] = ()
    scale: float | None = None    # stddev override for "normal"/"scaled"

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank")


def _fan_in(shape: tuple[int, ...]) -> int:
    # contraction dims are all but the last
    if len(shape) <= 1:
        return max(1, int(np.prod(shape[:-1])) if len(shape) else 1)
    return int(np.prod(shape[:-1]))


def materialize(key: jax.Array, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        std = d.scale if d.scale is not None else 0.02
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)
    # normal / scaled: truncated-normal-ish fan-in scaling
    std = d.scale if d.scale is not None else 1.0 / math.sqrt(max(1, _fan_in(d.shape)))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def init_params(key: jax.Array, defs: PyTree) -> PyTree:
    """Materialize a ParamDef tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [materialize(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs: PyTree, mesh=None, rules: dict[str, str | tuple] | None = None,
                    memory_kind: str | None = None) -> PyTree:
    """ShapeDtypeStruct tree; optionally carries NamedShardings for dry-run."""
    def one(d: ParamDef):
        if mesh is not None:
            from repro.distributed.sharding import prune_spec
            spec = prune_spec(axes_to_pspec(d.axes, rules or {}), d.shape,
                              mesh)
            kw = {"memory_kind": memory_kind} if memory_kind else {}
            sh = jax.sharding.NamedSharding(mesh, spec, **kw)
            return jax.ShapeDtypeStruct(d.shape, d.dtype, sharding=sh)
        return jax.ShapeDtypeStruct(d.shape, d.dtype)
    return jax.tree.map(one, defs, is_leaf=is_def)


def axes_to_pspec(axes: Sequence[str | None], rules: dict[str, str | tuple]) -> P:
    """Map logical axes to a PartitionSpec under `rules`.

    A rule value may be a mesh axis name, a tuple of mesh axes, or None.
    Mesh axes already consumed by an earlier dim are dropped (a mesh axis may
    appear at most once in a PartitionSpec).
    """
    if not axes:
        return P()
    used: set[str] = set()
    out = []
    for ax in axes:
        r = rules.get(ax) if ax is not None else None
        if r is None:
            out.append(None)
            continue
        cand = r if isinstance(r, tuple) else (r,)
        keep = tuple(m for m in cand if m not in used)
        used.update(keep)
        if len(keep) == 0:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspecs(defs: PyTree, rules: dict[str, str | tuple]) -> PyTree:
    return jax.tree.map(lambda d: axes_to_pspec(d.axes, rules), defs, is_leaf=is_def)


def stack_defs(defs: PyTree, n: int, axis_name: str | None = "layers") -> PyTree:
    """Add a leading stacked dim (for scan-over-layers parameter stacking)."""
    def one(d: ParamDef) -> ParamDef:
        return ParamDef(shape=(n,) + d.shape, dtype=d.dtype, init=d.init,
                        axes=(axis_name,) + (d.axes or (None,) * len(d.shape)),
                        scale=d.scale)
    return jax.tree.map(one, defs, is_leaf=is_def)


def count_params(defs: PyTree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


def param_bytes(defs: PyTree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves)
