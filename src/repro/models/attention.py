"""Attention substrate: GQA/MQA/MHA with causal, sliding-window and
local/global patterns, soft-capping, qk-norm, RoPE/M-RoPE, biases.

Three execution paths, chosen by shape regime:

* ``mha_dense``    — materialized scores; differentiable; used by train_4k.
* ``mha_chunked``  — online-softmax ``lax.scan`` over KV blocks (flash-style,
  O(block²) memory); used by prefill_32k (inference).
* ``decode path``  — q_len ∈ {1..4} against a cache; scores are [B,H,q,S]
  which is small; plain einsum + masked softmax.

All softmax statistics are fp32 regardless of compute dtype.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.params import ParamDef

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def attn_def(cfg: ArchConfig, *, cross: bool = False) -> dict:
    dt = cfg.param_dtype
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": ParamDef((d, H, hd), dt, "normal", axes=("embed", "heads", None)),
        "wk": ParamDef((d, KV, hd), dt, "normal", axes=("embed", "kv", None)),
        "wv": ParamDef((d, KV, hd), dt, "normal", axes=("embed", "kv", None)),
        "wo": ParamDef((H, hd, d), dt, "normal", axes=("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamDef((H, hd), dt, "zeros", axes=("heads", None))
        p["bk"] = ParamDef((KV, hd), dt, "zeros", axes=("kv", None))
        p["bv"] = ParamDef((KV, hd), dt, "zeros", axes=("kv", None))
    if cfg.qk_norm:
        p["q_norm"] = ParamDef((hd,), dt, "zeros", axes=(None,))
        p["k_norm"] = ParamDef((hd,), dt, "zeros", axes=(None,))
    return p


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def project_qkv(p: dict, cfg: ArchConfig, x: jax.Array,
                positions: jax.Array | None,
                *, rope_theta: float | None = None,
                mrope_positions: jax.Array | None = None):
    """x [B,S,d] -> q [B,S,H,hd], k,v [B,S,KV,hd] (roped, normed)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    if cfg.mrope_sections is not None and mrope_positions is not None:
        cos, sin = L.mrope_cos_sin(mrope_positions, cfg.head_dim,
                                   cfg.mrope_sections, theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    else:
        assert positions is not None
        cos, sin = L.rope_cos_sin(positions, cfg.head_dim, theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    q = L.apply_rope(q, cos, sin, cfg.rope_interleaved)
    k = L.apply_rope(k, cos, sin, cfg.rope_interleaved)
    return q, k, v


def repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """[B,S,KV,hd] -> [B,S,KV*groups,hd] for dense GQA math."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)
                            ).reshape(b, s, kv * groups, hd)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def causal_mask_bias(q_pos: jax.Array, k_pos: jax.Array,
                     window=None) -> jax.Array:
    """Additive fp32 bias [*, Sq, Sk]; window = sliding-window size
    (int, or traced scalar for mixed local/global scan bodies)."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    apply_window = window is not None and \
        (isinstance(window, jax.Array) or window > 0)
    if apply_window:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _scale(cfg: ArchConfig) -> float:
    if getattr(cfg, "query_scale", None):
        return cfg.query_scale
    return cfg.head_dim ** -0.5


def mha_dense(q: jax.Array, k: jax.Array, v: jax.Array, bias: jax.Array,
              scale: float, attn_cap: Optional[float]) -> jax.Array:
    """q [B,Sq,H,hd], k/v [B,Sk,H,hd], bias [B|1,1|H,Sq,Sk] -> [B,Sq,H,hd]."""
    s = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    s = L.softcap(s, attn_cap) + bias
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshk->bqhk", w.astype(v.dtype), v)


def mha_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                q_pos: jax.Array, k_pos: jax.Array, scale: float,
                attn_cap: Optional[float], window: Optional[int],
                kv_block: int = 1024) -> jax.Array:
    """Flash-style online-softmax over KV blocks (inference path).

    q [B,Sq,H,hd]; k,v [B,Sk,H,hd] (already GQA-repeated); positions absolute.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    nb = -(-Sk // kv_block)
    pad = nb * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    kb = k.reshape(B, nb, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(B, nb, kv_block).transpose(1, 0, 2)
    qf = q.astype(jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk
        s = jnp.einsum("bqhk,bshk->bhqs", qf, kc.astype(jnp.float32)) * scale
        s = L.softcap(s, attn_cap)
        s = s + causal_mask_bias(q_pos[:, None, :], pc[:, None, :], window)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqs,bshk->bhqk", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


class AttnOutput(NamedTuple):
    out: jax.Array
    k: jax.Array | None = None     # new k/v for cache append (decode/prefill)
    v: jax.Array | None = None


def attention(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
              *, kind: str = "global", mode: str = "train",
              cache_k: jax.Array | None = None,
              cache_v: jax.Array | None = None,
              cache_positions: jax.Array | None = None,
              rope_theta: float | None = None,
              mrope_positions: jax.Array | None = None,
              window_override: jax.Array | float | None = None) -> AttnOutput:
    """Unified attention entry.

    mode: "train" (dense, differentiable), "prefill" (chunked flash),
          "decode" (q against cache_k/v; caller appends to the cache).
    kind: "global" or "local" (sliding window cfg.sliding_window).
    window_override: traced per-layer window (mixed local/global scans);
          a huge value (>= 2**30) means effectively global.
    """
    if window_override is not None:
        window = window_override
    else:
        window = cfg.sliding_window if kind == "local" else None
    scale = _scale(cfg)
    groups = cfg.num_heads // cfg.num_kv_heads
    q, k, v = project_qkv(p, cfg, x, positions, rope_theta=rope_theta,
                          mrope_positions=mrope_positions)
    from repro.distributed.sharding import logical_axis_size
    heads_ok = cfg.num_heads % max(1, logical_axis_size("heads")) == 0
    if heads_ok:
        q = shard(q, "batch", None, "heads", None)
        k = shard(k, "batch", None, "kv", None)
        v = shard(v, "batch", None, "kv", None)
    else:
        # heads don't divide the model axis (e.g. whisper's 20 heads on a
        # 16-wide mesh): shard the query sequence instead so the score
        # matrix stays partitioned (k/v all-gather, Megatron-SP style)
        q = shard(q, "batch", "seq_sp", None, None)

    if mode == "decode":
        assert cache_k is not None and cache_v is not None
        kk = repeat_kv(cache_k, groups)
        vv = repeat_kv(cache_v, groups)
        s = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                       kk.astype(jnp.float32)) * scale
        s = L.softcap(s, cfg.attn_softcap)
        s = s + causal_mask_bias(positions[:, None, :],
                                 cache_positions[:, None, :], window)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshk->bqhk", w, vv.astype(jnp.float32))
        o = o.astype(x.dtype)
    elif mode == "prefill":
        kk = repeat_kv(k, groups)
        vv = repeat_kv(v, groups)
        o = mha_chunked(q, kk, vv, positions, positions, scale,
                        cfg.attn_softcap, window)
    else:  # train
        kk = repeat_kv(k, groups)
        vv = repeat_kv(v, groups)
        # positions are identical across the batch in training -> build the
        # mask once [1,1,S,S] and let it broadcast (batch-sized fp32 masks
        # dominated the remat working set otherwise)
        bias = causal_mask_bias(positions[:1, None, :],
                                positions[:1, None, :], window)
        o = mha_dense(q, kk, vv, bias, scale, cfg.attn_softcap)

    o = shard(o, "batch", None, "heads", None)
    out = jnp.einsum("bqhk,hkd->bqd", o, p["wo"])
    return AttnOutput(out, k, v)


def cross_attention(p: dict, cfg: ArchConfig, x: jax.Array,
                    enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (whisper)."""
    groups = cfg.num_heads // cfg.num_kv_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    kk = repeat_kv(enc_k, groups)
    vv = repeat_kv(enc_v, groups)
    s = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * _scale(cfg)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshk->bqhk", w, vv.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bqhk,hkd->bqd", o, p["wo"])


def cross_kv(p: dict, cfg: ArchConfig, enc_out: jax.Array):
    """Precompute encoder K/V once per request (whisper serving)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k, v
