"""Machine-readable findings + the checked-in baseline.

A :class:`Finding` is one violation of a statically checked contract —
produced by the AST lint (:mod:`repro.analysis.lint`) or the jaxpr
auditor (:mod:`repro.analysis.jaxpr_audit`).  Findings serialize to JSON
for tooling and compare against a **baseline** file so pre-existing
(acknowledged) violations are tracked without failing CI, while any NEW
violation does fail.

Baseline entries are line-number-free fingerprints
(``rule|path|scope|snippet``): moving code within a file never churns
the baseline; editing the flagged line (or fixing it) does.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                  # "ESS001".."ESS004" or an audit rule id
    path: str                  # repo-relative posix path (or target name)
    line: int                  # 1-based; 0 for whole-program audit findings
    scope: str                 # enclosing qualname ("<module>" at top level)
    message: str               # human-readable, one line
    snippet: str = ""          # stripped source line (fingerprint anchor)

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.path}|{self.scope}|{self.snippet}"

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} [{self.scope}] {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def findings_to_json(findings: Iterable[Finding]) -> str:
    fs = sorted(findings, key=lambda f: (f.path, f.line, f.rule))
    return json.dumps({"findings": [f.to_dict() for f in fs],
                       "count": len(fs)}, indent=2) + "\n"


def load_baseline(path) -> set[str]:
    """Read a baseline file -> set of fingerprints (empty if missing)."""
    try:
        with open(path) as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return set()
    return set(data.get("fingerprints", []))


def write_baseline(path, findings: Iterable[Finding]) -> None:
    fps = sorted({f.fingerprint for f in findings})
    with open(path, "w") as fh:
        json.dump({"comment": "esslint baseline: acknowledged pre-existing "
                              "findings (see ANALYSIS.md). Regenerate with "
                              "python -m repro.analysis --update-baseline.",
                   "fingerprints": fps}, fh, indent=2)
        fh.write("\n")


def split_against_baseline(findings: Iterable[Finding], baseline: set[str]
                           ) -> tuple[list[Finding], list[Finding],
                                      set[str]]:
    """-> (new, known, stale): findings not in / in the baseline, and
    baseline fingerprints no longer produced (fixed or moved — prune them
    with ``--update-baseline``)."""
    new, known, seen = [], [], set()
    for f in findings:
        (known if f.fingerprint in baseline else new).append(f)
        seen.add(f.fingerprint)
    return new, known, baseline - seen
