"""esslint layer 1 — AST rules compiled from the serve loop's bug history.

Rules (catalog + motivation in ANALYSIS.md):

* **ESS001** — cache-mutating helpers must be called with their gating
  argument (``slot_mask=`` / ``n_valid=``) spelled explicitly, even when
  the intended value is ``None``.  Relying on a default is how the
  page-0 aliasing bug shipped: an ungated scatter wrote retired slots'
  rows over live ones.
* **ESS002** — no hidden host syncs in serving/core/cache code:
  ``jax.device_get``, ``.item()``, and ``int()/float()/bool()`` applied
  to a computed (device) value all block the dispatch pipeline.  The
  one allowlisted fetch site is ``ServeSession.decode_round``'s packed
  fetch (the one-fetch contract).
* **ESS003** — no Python ``if``/``while`` branching on traced arrays
  inside traced round bodies; that's a retrace (or a
  ``TracerBoolConversionError``) per novel value.
* **ESS004** — ``jax.jit`` applied to a function taking the engine
  state must declare donation; forgetting it doubles peak cache memory.

Suppression: ``# esslint: disable=ESS001[,ESS002...]`` on any line the
flagged node spans.  Pre-existing findings live in the checked-in
baseline (see :mod:`repro.analysis.findings`).
"""

from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Iterable, Optional

from repro.analysis import contracts as C
from repro.analysis.findings import Finding

ALL_RULES = ("ESS001", "ESS002", "ESS003", "ESS004")

_DISABLE_RE = re.compile(r"#\s*esslint:\s*disable=([A-Z0-9,\s]+)")

_HOST_CASTS = {"int", "float", "bool"}

# attribute-method calls on arrays that force a host sync / concretization
_SYNC_METHODS = {"item", "tolist"}

# builtins whose results are host scalars by construction — int() over a
# composition of only these is host math, not a device sync
_HOST_SAFE_CALLS = {"round", "len", "min", "max", "abs", "sum", "sorted",
                    "divmod", "ord", "pow"}

# roots whose calls produce traced arrays (after alias resolution these
# all live under jax.*)
_TRACED_PREFIXES = ("jax.",)
_TRACED_ROOTS = {"jax", "jnp", "lax"}

# reduction-style methods whose result in a test expression means the
# test is data-dependent
_TRACED_TEST_METHODS = {"any", "all", "item", "sum", "max", "min"}


@dataclasses.dataclass
class LintConfig:
    """Rule scoping.  ``default_config()`` wires the repo's contracts;
    tests use ``fixture_config()`` to force every rule onto standalone
    snippets that live outside the ``repro`` package."""
    ess001_targets: dict = dataclasses.field(
        default_factory=lambda: dict(C.ESS001_TARGETS))
    ess002_prefixes: tuple = C.ESS002_MODULE_PREFIXES
    ess003_scopes: dict = dataclasses.field(
        default_factory=lambda: dict(C.ESS003_TRACED_SCOPES))
    ess003_host_functions: frozenset = frozenset(C.ESS003_HOST_FUNCTIONS)
    fetch_sites: frozenset = frozenset(C.FETCH_SITES)
    rules: tuple = ALL_RULES
    # fixtures: treat the whole file as in scope for ESS002/ESS003
    force_scope: bool = False


def default_config() -> LintConfig:
    return LintConfig()


def fixture_config(**overrides) -> LintConfig:
    cfg = LintConfig(force_scope=True)
    for k, v in overrides.items():
        setattr(cfg, k, v)
    return cfg


def _repro_relpath(relpath: str) -> str:
    """Normalize a path so lookups match the ``repro/...`` keys used in
    :mod:`repro.analysis.contracts` (drop leading ``src/`` etc.)."""
    parts = pathlib.PurePosixPath(relpath.replace("\\", "/")).parts
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    return "/".join(parts)


def _module_name(relpath: str) -> str:
    rel = _repro_relpath(relpath)
    parts = pathlib.PurePosixPath(rel).parts
    if parts and parts[-1].endswith(".py"):
        parts = parts[:-1] + (parts[-1][:-3],)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _collect_disables(source: str) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _DISABLE_RE.search(tok.string)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                out.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenError:
        pass
    return out


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` -> "a.b.c"; None for anything not a plain name chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


class _ModuleLinter(ast.NodeVisitor):
    def __init__(self, tree: ast.Module, source: str, relpath: str,
                 config: LintConfig):
        self.cfg = config
        self.relpath = _repro_relpath(relpath)
        self.module = _module_name(relpath)
        self.lines = source.splitlines()
        self.disables = _collect_disables(source)
        self.findings: list[Finding] = []
        self.scope: list[str] = []            # qualname stack
        # alias -> fully qualified name prefix
        self.aliases: dict[str, str] = {}
        # every def in the file, by name (for ESS004 resolution)
        self.defs: dict[str, ast.AST] = {}
        for n in ast.walk(tree):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(n.name, n)
        for n in ast.walk(tree):
            if isinstance(n, ast.Import):
                for a in n.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(n, ast.ImportFrom) and n.module and n.level == 0:
                for a in n.names:
                    if a.name != "*":
                        self.aliases[a.asname or a.name] = (
                            f"{n.module}.{a.name}")
        # local top-level defs shadow nothing imported under the same name
        for n in tree.body:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.aliases.setdefault(n.name, f"{self.module}.{n.name}")
        self._tree = tree

    # -- helpers ----------------------------------------------------------

    def _qualname(self) -> str:
        return ".".join(self.scope) if self.scope else "<module>"

    def _resolve(self, node: ast.AST) -> Optional[str]:
        """Fully qualified name of a call target, via import aliases."""
        dotted = _dotted(node)
        if dotted is None:
            return None
        root, _, rest = dotted.partition(".")
        base = self.aliases.get(root, root)
        return f"{base}.{rest}" if rest else base

    def _suppressed(self, rule: str, node: ast.AST) -> bool:
        end = getattr(node, "end_lineno", None) or node.lineno
        return any(rule in self.disables.get(ln, ())
                   for ln in range(node.lineno, end + 1))

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        if rule not in self.cfg.rules or self._suppressed(rule, node):
            return
        ln = node.lineno
        snippet = (self.lines[ln - 1].strip()
                   if 0 < ln <= len(self.lines) else "")
        self.findings.append(Finding(
            rule=rule, path=self.relpath, line=ln,
            scope=self._qualname(), message=message, snippet=snippet))

    def _in_ess002_scope(self) -> bool:
        return self.cfg.force_scope or self.relpath.startswith(
            self.cfg.ess002_prefixes)

    def _in_ess003_scope(self) -> bool:
        if self.scope and self.scope[-1] in self.cfg.ess003_host_functions:
            return False
        if self.cfg.force_scope:
            return True
        if self.relpath not in self.cfg.ess003_scopes:
            return False
        names = self.cfg.ess003_scopes[self.relpath]
        if names is None:                       # whole module is traced
            return True
        return any(s in names for s in self.scope)

    # -- scope tracking ---------------------------------------------------

    def visit_FunctionDef(self, node):                    # noqa: N802
        self._check_ess004_decorators(node)
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):                       # noqa: N802
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    # -- ESS001 / ESS002 / ESS004 (calls) --------------------------------

    def visit_Call(self, node):                           # noqa: N802
        resolved = self._resolve(node.func)
        self._check_ess001(node, resolved)
        self._check_ess002(node, resolved)
        self._check_ess004_call(node, resolved)
        self.generic_visit(node)

    def _check_ess001(self, node: ast.Call, resolved: Optional[str]) -> None:
        if resolved not in self.cfg.ess001_targets:
            return
        required = self.cfg.ess001_targets[resolved]
        if any(kw.arg is None for kw in node.keywords):   # **kwargs: opaque
            return
        if any(kw.arg == required for kw in node.keywords):
            return
        self._emit("ESS001", node,
                   f"call to {resolved} without explicit {required}= "
                   f"(pass {required}=None to assert the ungated mode "
                   f"is intended)")

    def _check_ess002(self, node: ast.Call, resolved: Optional[str]) -> None:
        if not self._in_ess002_scope():
            return
        site = f"{self.relpath}::{self._qualname()}"
        if resolved == "jax.device_get":
            if site not in self.cfg.fetch_sites:
                self._emit("ESS002", node,
                           "jax.device_get outside the allowlisted fetch "
                           "site breaks the one-fetch contract")
            return
        fn = node.func
        if (isinstance(fn, ast.Attribute) and fn.attr in _SYNC_METHODS
                and not node.args and not node.keywords):
            self._emit("ESS002", node,
                       f".{fn.attr}() forces a device->host sync")
            return
        # int(f(x)) / float(model(...)): casting a computed value syncs.
        # Plain int(x[i]) over an already-fetched array is fine, as is
        # host math built only from _HOST_SAFE_CALLS (round/len/max...).
        if (isinstance(fn, ast.Name) and fn.id in _HOST_CASTS
                and fn.id not in self.aliases
                and len(node.args) == 1 and not node.keywords):
            inner = [sub for sub in ast.walk(node.args[0])
                     if isinstance(sub, ast.Call)]
            host_safe = all(
                isinstance(c.func, ast.Name)
                and c.func.id in _HOST_SAFE_CALLS
                and c.func.id not in self.aliases for c in inner)
            if not inner or host_safe:
                return
            self._emit("ESS002", node,
                       f"{fn.id}() on a computed value is an implicit "
                       f"device->host sync; fetch via the round's packed "
                       f"device_get instead")

    # -- ESS003 (traced-value branching) ---------------------------------

    def _traced_marker(self, expr: ast.AST) -> Optional[str]:
        for sub in ast.walk(expr):
            if not isinstance(sub, ast.Call):
                continue
            resolved = self._resolve(sub.func)
            if resolved and (resolved.startswith(_TRACED_PREFIXES)
                             or resolved.split(".")[0] in _TRACED_ROOTS):
                return resolved
            if (isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in _TRACED_TEST_METHODS):
                return f".{sub.func.attr}()"
        return None

    def _check_ess003(self, node: ast.AST, test: ast.AST, kind: str) -> None:
        if not self._in_ess003_scope():
            return
        marker = self._traced_marker(test)
        if marker is not None:
            self._emit("ESS003", test,
                       f"Python {kind} on a traced value ({marker}) "
                       f"inside a traced round body — use jnp.where / "
                       f"lax.cond")

    def visit_If(self, node):                             # noqa: N802
        self._check_ess003(node, node.test, "if-branch")
        self.generic_visit(node)

    def visit_While(self, node):                          # noqa: N802
        self._check_ess003(node, node.test, "while-loop")
        self.generic_visit(node)

    def visit_IfExp(self, node):                          # noqa: N802
        self._check_ess003(node, node.test, "conditional expression")
        self.generic_visit(node)

    # -- ESS004 (undeclared donation) ------------------------------------

    def _takes_engine_state(self, fn_node: ast.AST) -> bool:
        if isinstance(fn_node, ast.Lambda):
            args = fn_node.args
        elif isinstance(fn_node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = fn_node.args
        else:
            return False
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            if a.arg in ("state", "engine_state"):
                return True
            if a.annotation is not None:
                ann = ast.unparse(a.annotation)
                if "EngineState" in ann:
                    return True
        return False

    def _jit_has_donation(self, call: ast.Call) -> bool:
        return any(kw.arg in ("donate_argnums", "donate_argnames")
                   for kw in call.keywords)

    def _check_ess004_call(self, node: ast.Call,
                           resolved: Optional[str]) -> None:
        # direct form: jax.jit(fn, ...) / functools.partial(jax.jit, ...)
        target = None
        if resolved == "jax.jit" and node.args:
            target = node.args[0]
        elif resolved in ("functools.partial", "partial") and node.args:
            head = self._resolve(node.args[0].func) \
                if isinstance(node.args[0], ast.Call) else \
                self._resolve(node.args[0])
            if head == "jax.jit" and len(node.args) > 1:
                target = node.args[1]
        if target is None:
            return
        if self._jit_has_donation(node):
            return
        fn_node = target
        if isinstance(target, ast.Name):
            fn_node = self.defs.get(target.id)
            if fn_node is None:
                return                      # can't resolve — stay silent
        if self._takes_engine_state(fn_node):
            self._emit("ESS004", node,
                       "jax.jit over a function taking the engine state "
                       "without donate_argnums/donate_argnames — peak "
                       "cache memory doubles")

    def _check_ess004_decorators(self, node) -> None:
        for dec in node.decorator_list:
            resolved = None
            has_donation = False
            if isinstance(dec, ast.Call):
                head = self._resolve(dec.func)
                if head == "jax.jit":
                    resolved, has_donation = head, self._jit_has_donation(dec)
                elif head in ("functools.partial", "partial") and dec.args:
                    inner = self._resolve(dec.args[0])
                    if inner == "jax.jit":
                        resolved = inner
                        has_donation = self._jit_has_donation(dec)
            else:
                if self._resolve(dec) == "jax.jit":
                    resolved = "jax.jit"
            if (resolved and not has_donation
                    and self._takes_engine_state(node)):
                self._emit("ESS004", dec,
                           "@jax.jit on a function taking the engine "
                           "state without donate_argnums/donate_argnames "
                           "— peak cache memory doubles")


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def lint_source(source: str, relpath: str,
                config: Optional[LintConfig] = None) -> list[Finding]:
    config = config or default_config()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(rule="ESS000", path=_repro_relpath(relpath),
                        line=e.lineno or 0, scope="<module>",
                        message=f"syntax error: {e.msg}")]
    linter = _ModuleLinter(tree, source, relpath, config)
    linter.visit(tree)
    return linter.findings


def lint_file(path, root,
              config: Optional[LintConfig] = None) -> list[Finding]:
    path = pathlib.Path(path)
    rel = path.relative_to(root).as_posix() if root else path.as_posix()
    return lint_source(path.read_text(), rel, config)


def lint_tree(root, subdir: str = "src/repro",
              config: Optional[LintConfig] = None) -> list[Finding]:
    """Lint every ``*.py`` under ``root/subdir`` (repo-relative paths in
    the findings)."""
    root = pathlib.Path(root)
    findings: list[Finding] = []
    for path in sorted((root / subdir).rglob("*.py")):
        findings.extend(lint_file(path, root, config))
    return findings
