"""The serve loop's statically checked contracts — single source of truth.

Everything the two analysis layers enforce is *declared* here so the
checks, the docs (ANALYSIS.md) and the tests reference one table instead
of each hard-coding its own copy.
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# One-fetch contract (jaxpr/runtime audit + ESS002)
# ---------------------------------------------------------------------------

# Maximum host fetches (jax.device_get) per serve round.  A round's only
# fetch is the commit stage's packed (tokens, n_emit[, t0..., pf...])
# struct; the plan and compute stages, prefill chunks, admissions and
# scheduler bookkeeping perform none.  Async staging traffic (the
# prefetch slab refill) is traced *inside* the round program — it is
# device/host DMA scheduled by XLA, never a blocking host fetch, so it
# does not count against this budget.
FETCH_BUDGET_PER_ROUND = 1

# The allowlisted fetch sites: "<module path>::<qualname>" of functions
# that may call jax.device_get (ESS002).  Everything else needs an inline
# `# esslint: disable=ESS002`.  The pipelined round puts the packed fetch
# in the commit stage; plan/compute must stay fetch-free.
FETCH_SITES = {
    "repro/serving/engine.py::ServeSession._commit_round",
    # the PD handoff's serialization point: one packed device_get per
    # migration (pages + scale plane + indexer keys + first token + MTP
    # hidden) — see PACK_BUDGET_PER_MIGRATION / ESS107 below
    "repro/cluster/kv_transfer.py::pack_migration",
}

# ---------------------------------------------------------------------------
# Retrace budget (jaxpr audit)
# ---------------------------------------------------------------------------

# Round kinds traced by the StepPrograms; every (kind, signature) pair
# must trace exactly once per process however the workload interleaves
# admissions, preemptions, ragged chunks and MTP on/off.
ROUND_KINDS = ("decode", "spec", "prefill")

# Prefill shape buckets are powers of two up to prefill_chunk: at most
# log2(chunk)+1 buckets, times two trace keys (mid/last variants).
def max_prefill_trace_keys(prefill_chunk: int) -> int:
    n = 1
    b = 1
    while b < prefill_chunk:
        b <<= 1
        n += 1
    return 2 * n


# ---------------------------------------------------------------------------
# Donation contract (jaxpr audit)
# ---------------------------------------------------------------------------

# Every round program donates the EngineState pytree (argnum 1); lowering
# must alias *all* of its leaves into outputs (tf.aliasing_output) and
# emit no "donated buffers were not usable" warning.
DONATED_ARGNUM = 1

# ---------------------------------------------------------------------------
# Dtype contract (jaxpr audit)
# ---------------------------------------------------------------------------

# Latent/indexer-key tensors stay bf16 (cfg.param_dtype) end to end: each
# program's output state leaf dtypes equal its input leaf dtypes, and no
# convert_element_type widens a cache-sized bf16 operand to f32.
CACHE_DTYPE_INVARIANT = "state-out leaf dtypes == state-in leaf dtypes"

# ---------------------------------------------------------------------------
# ESS001: cache-mutating helpers require an explicit gating argument
# ---------------------------------------------------------------------------

# qualified callee -> keyword that must be passed explicitly (None is an
# accepted *explicit* value — the rule bans relying on a default, not the
# ungated mode itself).
ESS001_TARGETS = {
    "repro.core.offload.host_scatter_rows": "slot_mask",
    "repro.core.offload.host_scatter_rows_stacked": "slot_mask",
    "repro.core.offload.scatter_tier_rows": "slot_mask",
    "repro.core.offload.scatter_tier_rows_stacked": "slot_mask",
    "repro.core.lru_pool.lookup": "slot_mask",
    "repro.core.lru_pool.admit": "slot_mask",
    "repro.core.warmup.lru_warmup": "slot_mask",
    "repro.serving.engine.ess_decode": "slot_mask",
    "repro.serving.engine.ess_prefill_chunk": "n_valid",
    "repro.core.offload.gather_into_slab": "slot_mask",
    "repro.core.offload.scatter_from_slab": "slot_mask",
}

# ---------------------------------------------------------------------------
# ESS002 scope: serving/core/cache modules (training checkpoints etc. sync
# legitimately and are out of scope)
# ---------------------------------------------------------------------------

ESS002_MODULE_PREFIXES = ("repro/serving/", "repro/core/", "repro/cache/",
                          "repro/cluster/")

# ---------------------------------------------------------------------------
# ESS003 scope: traced round bodies (modules fully traced into the
# StepPrograms, plus the two traced entry points in engine.py)
# ---------------------------------------------------------------------------

# module relpath -> None (whole module traced) | set of function names
ESS003_TRACED_SCOPES = {
    "repro/core/lru_pool.py": None,
    "repro/core/overlap.py": None,
    "repro/core/warmup.py": None,
    "repro/serving/mtp.py": None,
    "repro/serving/tbo.py": None,
    "repro/serving/sampling.py": None,
    "repro/serving/step.py": None,
    "repro/serving/engine.py": {"ess_decode", "ess_prefill_chunk"},
    # transfer.py's traced halves (slab init / prefetch planning / slab
    # matching); the TransferEngine methods themselves are host-side
    # plumbing around them.
    "repro/core/transfer.py": {"empty_slab", "plan_prefetch",
                               "match_staged"},
}

# ESS003's host-side escape hatch: check_consistent is explicitly a
# host/debug helper inside an otherwise fully traced module
ESS003_HOST_FUNCTIONS = {"check_consistent"}

# ---------------------------------------------------------------------------
# ESS105: no blocking stage (pipeline-overlap audit)
# ---------------------------------------------------------------------------

# With the async-offload pipeline on, every round program must keep the
# staging slab off the token critical path:
#
#  (a) the slab a round *consumes* is the one staged by the previous
#      round — the ``staged_rows`` input leaf must feed the tokens
#      output (otherwise the pipeline never uses its prefetches and the
#      slab is dead weight), and
#  (b) the slab *refill* gather issued this round must be needed only
#      for the ``staged_rows`` output leaf, never for tokens — a refill
#      gather on the token path means the round blocks on a transfer it
#      should have overlapped into the next round's compute.
#
# The slab leaves are pinned to the END of EngineState (state.py keeps
# ``staged_ids``/``staged_scales``/``staged_rows`` as its last fields,
# rows last in *every* configuration — ``staged_scales`` is an empty
# pytree on a raw bf16 tier, so the rows index holds either way) so the
# audit can find them positionally in the flattened jaxpr
# invars/outvars.
ESS105_STAGED_ROWS_LEAF = -1  # EngineState leaf index, from the end

# ---------------------------------------------------------------------------
# ESS106: quantized tier dequantizes at gather width only
# ---------------------------------------------------------------------------

# With a quantized host latent tier (ess.host_cache_dtype != "bf16"), no
# StepProgram may widen a cache-tier-sized int8/fp8 tensor to
# bf16/f16/f32: dequantization happens strictly *after* the gather, at
# miss/slab width.  A tier-sized convert_element_type means some path
# materialized the whole decompressed tier — the exact
# memory-and-bandwidth blowup the compressed representation exists to
# avoid.  The threshold is the largest quantized state leaf (the host
# tier itself).
ESS106_NARROW_DTYPES = ("int8", "float8_e4m3fn", "float8_e5m2")
ESS106_WIDE_DTYPES = ("bfloat16", "float16", "float32")

# ---------------------------------------------------------------------------
# ESS107: one host-side page-pack per PD migration
# ---------------------------------------------------------------------------

# A prefill→decode handoff serializes a finished prompt's state exactly
# once: :func:`repro.cluster.kv_transfer.pack_migration` reads the
# slot's host pages, scale plane, indexer keys, first token and MTP
# hidden in ONE packed ``jax.device_get`` (the allowlisted pack site in
# FETCH_SITES).  The page inventory itself comes from the host-side
# allocator (``HostPageAllocator.owned``), so packing never needs a
# second fetch to discover *what* to move; and a decode worker's serve
# rounds keep the ordinary FETCH_BUDGET_PER_ROUND — installing a
# migration adds zero fetches on the decode side (the first token rides
# the packet).
PACK_BUDGET_PER_MIGRATION = 1
PACK_SITE = "repro/cluster/kv_transfer.py::pack_migration"
