"""``python -m repro.analysis`` — run esslint (AST rules + jaxpr audit)
and compare the findings against the checked-in baseline.

Exit status: 0 when no findings outside the baseline, 1 when any new
finding (or, with ``--strict-stale``, any stale baseline entry), 2 on
usage errors.  CI runs ``python -m repro.analysis --check``.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

from repro.analysis.findings import (findings_to_json, load_baseline,
                                     split_against_baseline, write_baseline)


def _default_root() -> pathlib.Path:
    # src/repro/analysis/__main__.py -> repo root
    return pathlib.Path(__file__).resolve().parents[3]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="esslint: static contract checks for the ESS serve "
                    "loop (see ANALYSIS.md)")
    p.add_argument("--check", action="store_true",
                   help="alias for the default mode (explicit in CI)")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite the baseline to the current findings")
    p.add_argument("--json", metavar="PATH",
                   help="also write all findings as JSON")
    p.add_argument("--skip-audit", action="store_true",
                   help="AST lint only (fast; skips jaxpr lowering)")
    p.add_argument("--skip-workload", action="store_true",
                   help="skip the session-driving audits (ESS102/ESS103); "
                        "keep the structural lowering audits")
    p.add_argument("--skip-lint", action="store_true",
                   help="jaxpr audit only")
    p.add_argument("--root", default=None,
                   help="repo root (default: inferred from the package)")
    p.add_argument("--baseline", default=None,
                   help="baseline path (default: <root>/esslint-baseline.json)")
    p.add_argument("--strict-stale", action="store_true",
                   help="also fail on stale baseline entries")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.skip_audit and args.skip_lint:
        print("nothing to do: both layers skipped", file=sys.stderr)
        return 2
    root = pathlib.Path(args.root) if args.root else _default_root()
    baseline_path = (pathlib.Path(args.baseline) if args.baseline
                     else root / "esslint-baseline.json")

    findings = []
    if not args.skip_lint:
        from repro.analysis.lint import lint_tree
        findings += lint_tree(root)
    if not args.skip_audit:
        from repro.analysis import jaxpr_audit
        findings += jaxpr_audit.run_all(workload=not args.skip_workload)

    if args.json:
        pathlib.Path(args.json).write_text(findings_to_json(findings))

    if args.update_baseline:
        write_baseline(baseline_path, findings)
        print(f"baseline updated: {len(findings)} finding(s) -> "
              f"{baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, known, stale = split_against_baseline(findings, baseline)
    for f in sorted(new, key=lambda f: (f.path, f.line, f.rule)):
        print(f.format())
    if known:
        print(f"[{len(known)} baselined finding(s) suppressed]")
    if stale:
        print(f"[{len(stale)} stale baseline entr(ies) — fixed or moved; "
              f"prune with --update-baseline]")
    if new:
        print(f"esslint: {len(new)} new finding(s)")
        return 1
    if stale and args.strict_stale:
        return 1
    print("esslint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
