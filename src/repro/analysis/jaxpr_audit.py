"""esslint layer 2 — lower every StepProgram and audit the serve
contracts (:mod:`repro.analysis.contracts`).

Seven audits, each a thin driver over a pure checker (the checkers take
plain data so tests can exercise failure paths without lowering):

* **ESS101 donation** — every round program donates the EngineState
  (argnum 1); lowering must alias *all* of its leaves into outputs
  (``tf.aliasing_output`` in the StableHLO) and emit no "donated
  buffers ... not usable" warning.  A missed alias doubles peak cache
  memory silently.
* **ESS102 one-fetch** — driving a real session over a mixed workload,
  every serve round performs at most :data:`FETCH_BUDGET_PER_ROUND`
  ``jax.device_get`` calls and the total equals ``report.rounds``.
* **ESS103 retrace** — tracing a mixed workload (admissions,
  preemption, ragged chunks, MTP on/off) twice yields exactly one trace
  per ``(round kind, shape bucket)``; a second trace is a silent
  recompile in production.
* **ESS104 dtype drift** — each program's output EngineState leaf
  dtypes equal its input leaf dtypes, and no ``convert_element_type``
  widens a cache-tier-sized bf16 tensor to f32.
* **ESS105 no-blocking-stage** — with the async-offload pipeline on
  (``prefetch > 0``), a backward slice of each decode/spec jaxpr must
  show (a) the staged slab a round *consumes* feeding its tokens
  output, and (b) the slab *refill* gather needed only for the
  ``staged_rows`` output — a refill gather on the token path means the
  round blocks on a transfer it should have overlapped into the next
  round.
* **ESS106 tier dequant** — with a quantized host tier
  (``ess.host_cache_dtype != "bf16"``), no program widens a
  cache-tier-sized int8/fp8 tensor to bf16/f16/f32: dequantization
  happens strictly after the gather, at miss/slab width.  A tier-sized
  convert means some path materialized the whole decompressed tier —
  the exact blowup the compressed representation exists to avoid.
* **ESS107 one-handoff** — driving a PD-disaggregated
  :class:`~repro.cluster.EssCluster` (1 prefill + 1 decode worker),
  every migration is exactly one host-side page-pack
  (:data:`PACK_BUDGET_PER_MIGRATION` fetches at the allowlisted pack
  site), prefill rounds fetch only to pack, install performs zero
  fetches, and decode rounds stay within the ESS102 one-fetch budget —
  a smuggled second ``device_get`` anywhere in a worker round is
  caught.

Abstract lowering (ESS101/ESS104) uses ``ShapeDtypeStruct`` trees — no
parameter memory is allocated.  The workload audits (ESS102/ESS103)
initialize the smoke model.  Every audit draws a fresh ``max_seq`` from
a process-wide counter so the lru-cached ``get_programs`` and the
process-wide ``TRACE_COUNTS`` start cold for its shape family.
"""

from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.analysis import contracts as C
from repro.analysis.findings import Finding

SMOKE_CONFIG = "deepseek-v32-exp-ess-smoke"

# each audit invocation claims a fresh shape family (max_seq) so
# lru-cached programs/trace counters never alias across audits or tests
_FRESH_SEQ = itertools.count(61)

_ALIAS_ATTR = "tf.aliasing_output"
_AUDIT_PATH = "<jaxpr>"


def _smoke_cfg(paged: bool = True, host_dtype: str = "bf16"):
    from repro.configs import get_config
    cfg = get_config(SMOKE_CONFIG)
    ess = dataclasses.replace(cfg.ess, max_miss_ratio=1.0,
                              host_cache_dtype=host_dtype,
                              **({} if paged else {"paged_host": False}))
    return dataclasses.replace(cfg, ess=ess, mtp_depth=2)


def _abstract_state(cfg, num_slots: int, max_seq: int,
                    prefetch: int = 0):
    from repro.cache import latent_cache as LC
    from repro.serving import state as ES

    paged = LC.uses_paged_host(cfg)
    num_pages = num_slots * LC.num_blocks(cfg, max_seq) if paged else None

    def build():
        caches = LC.init_ess_caches(cfg, num_slots, max_seq,
                                    cfg.param_dtype, num_pages=num_pages,
                                    map_slots=not paged)
        return ES.init_engine_state(cfg, caches, num_slots,
                                    prefetch_rows=prefetch)

    return jax.eval_shape(build)


def _abstract_params(cfg):
    from repro.models import transformer as T
    from repro.models.params import abstract_params
    return abstract_params(T.model_def(cfg))


@dataclasses.dataclass
class AuditTarget:
    kind: str                   # "decode" | "spec" | "prefill/C4last1" ...
    fn: Callable                # donated jitted round program
    args: tuple                 # abstract arguments (ShapeDtypeStructs)
    state: object               # abstract EngineState (args[1])


def build_targets(cfg=None, *, num_slots: int = 2,
                  max_seq: Optional[int] = None, mtp_depth: int = 2,
                  prefill_chunk: int = 8,
                  prefetch: int = 0) -> list[AuditTarget]:
    """Every round-program variant of one shape family, with abstract
    arguments ready for ``.lower()`` / ``jax.eval_shape``.
    ``prefetch > 0`` builds the pipelined variants (staging slab in
    state, prefetch-aware step programs)."""
    from repro.serving import step as SP
    cfg = cfg if cfg is not None else _smoke_cfg()
    max_seq = max_seq if max_seq is not None else next(_FRESH_SEQ)
    params = _abstract_params(cfg)
    state = _abstract_state(cfg, num_slots, max_seq, prefetch)
    programs = SP.get_programs(cfg, num_slots, max_seq, False, False,
                               mtp_depth, prefetch)
    i32 = lambda shape=(): jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    targets = [AuditTarget("decode", programs.decode(True),
                           (params, state), state)]
    if mtp_depth > 0:
        targets.append(AuditTarget("spec", programs.spec(True),
                                   (params, state), state))
    chunk = 1
    chunks = []
    while chunk < prefill_chunk:
        chunks.append(chunk)
        chunk <<= 1
    chunks.append(prefill_chunk)
    for c in chunks:
        for last in (False, True):
            targets.append(AuditTarget(
                f"prefill/C{c}last{int(last)}",
                programs.prefill(c, last, True),
                (params, state, jax.ShapeDtypeStruct((1, c), jnp.int32),
                 i32(), i32()), state))
    return targets


# ---------------------------------------------------------------------------
# ESS101: donation
# ---------------------------------------------------------------------------

def check_donation(kind: str, n_aliased: int, n_state_leaves: int,
                   warning_msgs: list[str]) -> list[Finding]:
    """Pure checker: aliasing attr count vs donated leaf count + any
    donation warnings captured during lowering."""
    out = []
    bad = [m for m in warning_msgs if "donat" in m.lower()]
    if bad:
        out.append(Finding(
            rule="ESS101", path=_AUDIT_PATH, line=0, scope=kind,
            message=f"unusable donation while lowering {kind}: {bad[0]}"))
    if n_aliased < n_state_leaves:
        out.append(Finding(
            rule="ESS101", path=_AUDIT_PATH, line=0, scope=kind,
            message=f"{kind}: only {n_aliased}/{n_state_leaves} donated "
                    f"EngineState leaves aliased into outputs — the rest "
                    f"are silently copied (peak memory doubles)"))
    return out


def audit_donation(cfg=None, *, targets=None, **kw) -> list[Finding]:
    findings = []
    for t in (targets if targets is not None
              else build_targets(cfg, **kw)):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            text = t.fn.lower(*t.args).as_text()
        findings += check_donation(
            t.kind, text.count(_ALIAS_ATTR),
            len(jax.tree.leaves(t.state)),
            [str(x.message) for x in w])
    return findings


# ---------------------------------------------------------------------------
# ESS102: one fetch per round
# ---------------------------------------------------------------------------

def check_fetch_counts(per_round: list[int], rounds: int,
                       budget: int = C.FETCH_BUDGET_PER_ROUND
                       ) -> list[Finding]:
    """Pure checker over per-serve-round device_get counts."""
    out = []
    for i, n in enumerate(per_round):
        if n > budget:
            out.append(Finding(
                rule="ESS102", path=_AUDIT_PATH, line=0,
                scope=f"round[{i}]",
                message=f"{n} device->host fetches in one serve round "
                        f"(budget {budget})"))
    total = sum(per_round)
    if total != rounds:
        out.append(Finding(
            rule="ESS102", path=_AUDIT_PATH, line=0, scope="total",
            message=f"{total} fetches over {rounds} decode rounds — the "
                    f"packed RoundOut fetch must be the only transfer "
                    f"(expected exactly {rounds})"))
    return out


def _mixed_requests():
    from repro.serving.scheduler import Request
    return [Request(rid=0, prompt_len=11, max_new_tokens=5),
            Request(rid=1, prompt_len=8, max_new_tokens=4),
            Request(rid=2, prompt_len=9, max_new_tokens=3,
                    temperature=0.9, seed=5),
            Request(rid=3, prompt_len=10, max_new_tokens=4)]


def audit_fetch_counts(cfg=None, *, session_cls=None, mtp_depth: int = 0,
                       max_seq: Optional[int] = None,
                       overlap: bool = False) -> list[Finding]:
    """Drive a real mixed workload counting ``jax.device_get`` per serve
    round.  ``session_cls`` is injectable so tests can demonstrate the
    audit catching a session that sneaks extra fetches.  ``overlap=True``
    drives the pipelined session — async staging must ride the same
    single packed fetch, not add host syncs."""
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving import engine as E
    cfg = cfg if cfg is not None else _smoke_cfg()
    session_cls = session_cls or E.ServeSession
    max_seq = max_seq if max_seq is not None else next(_FRESH_SEQ)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    session = session_cls(params, cfg, num_slots=2, max_seq=max_seq,
                          prefill_chunk=8, compiled=True,
                          mtp_depth=mtp_depth, overlap=overlap)
    for r in _mixed_requests():
        session.submit(r)
    counts = []
    real = jax.device_get
    calls = [0]

    def counting(*a, **k):
        calls[0] += 1
        return real(*a, **k)

    jax.device_get = counting
    try:
        guard = 100
        while (session.sched.running or session.sched.queue) and guard:
            before = calls[0]
            session.step_round()
            counts.append(calls[0] - before)
            guard -= 1
    finally:
        jax.device_get = real
    if not guard:
        return [Finding(rule="ESS102", path=_AUDIT_PATH, line=0,
                        scope="driver",
                        message="workload did not finish in 100 rounds")]
    return check_fetch_counts(counts, session.report.rounds)


# ---------------------------------------------------------------------------
# ESS103: retrace budget
# ---------------------------------------------------------------------------

def check_retrace(deltas: dict[str, int]) -> list[Finding]:
    """Pure checker over per-program trace-count deltas."""
    out = []
    if not deltas:
        return [Finding(rule="ESS103", path=_AUDIT_PATH, line=0,
                        scope="driver",
                        message="no programs traced — audit drove nothing")]
    for key, n in sorted(deltas.items()):
        if n != 1:
            out.append(Finding(
                rule="ESS103", path=_AUDIT_PATH, line=0, scope=key,
                message=f"traced {n}x (expected once): a retrace per "
                        f"round is a silent recompile in production"))
    kinds = {k.split("/")[0] for k in deltas}
    missing = set(C.ROUND_KINDS) - kinds
    if missing:
        out.append(Finding(
            rule="ESS103", path=_AUDIT_PATH, line=0, scope="coverage",
            message=f"round kinds never traced by the audit workload: "
                    f"{sorted(missing)}"))
    return out


def audit_retrace(cfg=None, *, max_seq: Optional[int] = None
                  ) -> list[Finding]:
    """Trace a mixed workload twice (admissions, a preemption, ragged
    final chunks, MTP off/on) in a fresh shape family; every program
    must trace exactly once."""
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving import engine as E
    from repro.serving import step as SP
    cfg = cfg if cfg is not None else _smoke_cfg()
    max_seq = max_seq if max_seq is not None else next(_FRESH_SEQ)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    sig = f"s{max_seq}tbo"
    before = {k: v for k, v in SP.TRACE_COUNTS.items() if sig in k}

    def drive(mtp_depth):
        s = E.ServeSession(params, cfg, num_slots=2, max_seq=max_seq,
                           prefill_chunk=8, compiled=True,
                           mtp_depth=mtp_depth)
        for r in _mixed_requests():
            s.submit(dataclasses.replace(r))
        s.step_round(); s.step_round(); s.step_round()
        s.preempt(0)
        s.run(max_rounds=100)

    drive(0)
    drive(2)          # same shape family, spec program added
    drive(0)          # third session: pure program-cache hits
    deltas = {k: v - before.get(k, 0)
              for k, v in SP.TRACE_COUNTS.items()
              if sig in k and v != before.get(k, 0)}
    return check_retrace(deltas)


# ---------------------------------------------------------------------------
# ESS104: dtype drift
# ---------------------------------------------------------------------------

def check_state_dtypes(kind: str, in_dtypes: list, out_dtypes: list
                       ) -> list[Finding]:
    """Pure checker: per-leaf dtype round-trip through a program."""
    out = []
    if len(in_dtypes) != len(out_dtypes):
        return [Finding(
            rule="ESS104", path=_AUDIT_PATH, line=0, scope=kind,
            message=f"{kind}: state leaf count changed "
                    f"{len(in_dtypes)} -> {len(out_dtypes)}")]
    for i, (a, b) in enumerate(zip(in_dtypes, out_dtypes)):
        if a != b:
            out.append(Finding(
                rule="ESS104", path=_AUDIT_PATH, line=0, scope=kind,
                message=f"{kind}: state leaf[{i}] dtype drifts "
                        f"{a} -> {b} across the round"))
    return out


def _jaxpr_subfuns(params):
    for v in params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jax.core.ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, jax.core.Jaxpr):
                    yield x


def _iter_eqns(jaxpr):
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            yield eqn
            stack.extend(_jaxpr_subfuns(eqn.params))


def find_big_upcasts(closed_jaxpr, threshold: int) -> list[tuple]:
    """(size, src_dtype, dst_dtype) for every convert_element_type that
    widens a bf16 tensor of >= ``threshold`` elements to f32."""
    hits = []
    for eqn in _iter_eqns(closed_jaxpr.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        (src,), (dst,) = eqn.invars, eqn.outvars
        saval, daval = src.aval, dst.aval
        if (getattr(saval, "dtype", None) == jnp.bfloat16
                and daval.dtype == jnp.float32
                and saval.size >= threshold):
            hits.append((int(saval.size), str(saval.dtype),
                         str(daval.dtype)))
    return hits


def audit_dtypes(cfg=None, *, targets=None, **kw) -> list[Finding]:
    findings = []
    for t in (targets if targets is not None
              else build_targets(cfg, **kw)):
        in_leaves = jax.tree.leaves(t.state)
        out_shapes = jax.eval_shape(t.fn, *t.args)
        out_state = out_shapes[0]       # every round fn returns (state, ...)
        findings += check_state_dtypes(
            t.kind, [str(x.dtype) for x in in_leaves],
            [str(x.dtype) for x in jax.tree.leaves(out_state)])
        # cache-tier threshold: the largest cache-tier state leaf.  On a
        # raw tier that is the bf16 host latent; on a quantized tier the
        # payload is int8/fp8 but its *element count* still defines
        # "tier-sized" — otherwise the threshold collapses to chunk-scale
        # bf16 leaves and legitimate per-step f32 math trips the audit.
        bf16_sizes = [x.size for x in in_leaves
                      if x.dtype == jnp.bfloat16
                      or str(x.dtype) in C.ESS106_NARROW_DTYPES]
        if not bf16_sizes:
            continue
        threshold = max(bf16_sizes)
        jaxpr = jax.make_jaxpr(t.fn)(*t.args)
        for size, sd, dd in find_big_upcasts(jaxpr, threshold):
            findings.append(Finding(
                rule="ESS104", path=_AUDIT_PATH, line=0, scope=t.kind,
                message=f"{t.kind}: convert_element_type {sd}->{dd} on a "
                        f"cache-tier-sized tensor ({size} elements) — "
                        f"silent 2x memory/bandwidth"))
    return findings


# ---------------------------------------------------------------------------
# ESS106: quantized tier dequantizes at gather width only
# ---------------------------------------------------------------------------

def find_big_dequants(closed_jaxpr, threshold: int) -> list[tuple]:
    """(size, src_dtype, dst_dtype) for every convert_element_type that
    widens an int8/fp8 tensor of >= ``threshold`` elements to a float
    type (:data:`contracts.ESS106_WIDE_DTYPES`)."""
    hits = []
    for eqn in _iter_eqns(closed_jaxpr.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        (src,), (dst,) = eqn.invars, eqn.outvars
        saval, daval = src.aval, dst.aval
        if (str(getattr(saval, "dtype", "")) in C.ESS106_NARROW_DTYPES
                and str(daval.dtype) in C.ESS106_WIDE_DTYPES
                and saval.size >= threshold):
            hits.append((int(saval.size), str(saval.dtype),
                         str(daval.dtype)))
    return hits


def check_tier_dequants(kind: str, hits: list[tuple],
                        threshold: int) -> list[Finding]:
    """Pure checker over one program's tier-sized dequant hits."""
    return [Finding(
        rule="ESS106", path=_AUDIT_PATH, line=0, scope=kind,
        message=f"{kind}: convert_element_type {sd}->{dd} on a "
                f"cache-tier-sized tensor ({size} >= {threshold} "
                f"elements) — the quantized tier must dequantize at "
                f"gather width, never materialize decompressed")
        for size, sd, dd in hits]


def audit_tier_dequant(cfg=None, *, targets=None, **kw) -> list[Finding]:
    """ESS106: with a quantized host tier, no StepProgram materializes a
    tier-sized bf16/f32 tensor from the int8/fp8 payload — dequant stays
    at miss/slab width inside the gather path."""
    findings = []
    for t in (targets if targets is not None
              else build_targets(cfg, **kw)):
        q_sizes = [x.size for x in jax.tree.leaves(t.state)
                   if str(x.dtype) in C.ESS106_NARROW_DTYPES]
        if not q_sizes:
            findings.append(Finding(
                rule="ESS106", path=_AUDIT_PATH, line=0, scope=t.kind,
                message=f"{t.kind}: no quantized state leaf — audit the "
                        f"quantized tier config (host_cache_dtype)"))
            continue
        threshold = max(q_sizes)
        jaxpr = jax.make_jaxpr(t.fn)(*t.args)
        findings += check_tier_dequants(
            t.kind, find_big_dequants(jaxpr, threshold), threshold)
    return findings


# ---------------------------------------------------------------------------
# ESS105: no blocking stage (pipeline overlap)
# ---------------------------------------------------------------------------

def _slice_jaxpr(jaxpr, out_positions: set) -> tuple[set, set]:
    """Backward slice: which invar positions and which gather equations
    (by ``id``) are needed to compute ``jaxpr.outvars[i]`` for the given
    positions.

    Descends *precisely* into arity-matched ``pjit`` calls (only the
    needed inner outputs propagate demand to the outer inputs) and
    *conservatively* into every other call-like primitive — cond / scan /
    while mark all their invars needed and count every gather in every
    branch.  Conservatism only widens the needed sets, so a clean
    verdict ("this gather is exclusive to the slab output") is sound.
    """
    needed = set()
    for i in out_positions:
        v = jaxpr.outvars[i]
        if not isinstance(v, jax.core.Literal):
            needed.add(v)
    gathers: set = set()
    for eqn in reversed(jaxpr.eqns):
        if not any(v in needed for v in eqn.outvars):
            continue
        sub = eqn.params.get("jaxpr") \
            if eqn.primitive.name == "pjit" else None
        if (sub is not None
                and len(sub.jaxpr.invars) == len(eqn.invars)
                and len(sub.jaxpr.outvars) == len(eqn.outvars)):
            sub_out = {i for i, v in enumerate(eqn.outvars) if v in needed}
            sub_in, sub_g = _slice_jaxpr(sub.jaxpr, sub_out)
            gathers |= sub_g
            for i in sub_in:
                v = eqn.invars[i]
                if not isinstance(v, jax.core.Literal):
                    needed.add(v)
        else:
            if eqn.primitive.name == "gather":
                gathers.add(id(eqn))
            for j in _jaxpr_subfuns(eqn.params):
                for se in _iter_eqns(j):
                    if se.primitive.name == "gather":
                        gathers.add(id(se))
            for v in eqn.invars:
                if not isinstance(v, jax.core.Literal):
                    needed.add(v)
    invar_positions = {i for i, v in enumerate(jaxpr.invars) if v in needed}
    return invar_positions, gathers


def check_pipeline_overlap(kind: str, *, consumes_staged: bool,
                           n_exclusive_gathers: int) -> list[Finding]:
    """Pure checker over the two sliced facts of one round program."""
    out = []
    if not consumes_staged:
        out.append(Finding(
            rule="ESS105", path=_AUDIT_PATH, line=0, scope=kind,
            message=f"{kind}: the staged_rows input never reaches the "
                    f"tokens output — the pipeline stages rows the round "
                    f"does not consume (dead prefetch)"))
    if n_exclusive_gathers < 1:
        out.append(Finding(
            rule="ESS105", path=_AUDIT_PATH, line=0, scope=kind,
            message=f"{kind}: no gather is exclusive to the staged_rows "
                    f"output — the slab refill sits on the token critical "
                    f"path, so the round blocks on its own prefetch "
                    f"instead of overlapping it into the next round"))
    return out


def audit_pipeline_overlap(cfg=None, *, targets=None, **kw
                           ) -> list[Finding]:
    """Slice each pipelined decode/spec program and verify the staging
    contract (:data:`contracts.ESS105_STAGED_ROWS_LEAF`): consumed slab
    on the token path, refill gather off it."""
    if targets is None:
        kw.setdefault("prefetch", 4)
        targets = build_targets(cfg, **kw)
    findings = []
    for t in targets:
        if t.kind not in ("decode", "spec"):
            continue
        if getattr(t.state, "staged_rows", None) is None:
            findings.append(Finding(
                rule="ESS105", path=_AUDIT_PATH, line=0, scope=t.kind,
                message=f"{t.kind}: no staging slab in EngineState — "
                        f"build targets with prefetch > 0"))
            continue
        n_params = len(jax.tree.leaves(t.args[0]))
        n_state = len(jax.tree.leaves(t.state))
        jaxpr = jax.make_jaxpr(t.fn)(*t.args).jaxpr
        # flattened invars = params then state; outvars = state then
        # RoundOut (tokens first).  staged_rows is pinned to the state
        # tail (contracts.ESS105_STAGED_ROWS_LEAF).
        rows_in = n_params + n_state + C.ESS105_STAGED_ROWS_LEAF
        tok_in, tok_g = _slice_jaxpr(jaxpr, {n_state})
        _, slab_g = _slice_jaxpr(
            jaxpr, {n_state + C.ESS105_STAGED_ROWS_LEAF})
        findings += check_pipeline_overlap(
            t.kind, consumes_staged=rows_in in tok_in,
            n_exclusive_gathers=len(slab_g - tok_g))
    return findings


# ---------------------------------------------------------------------------
# ESS107: one-handoff migration pack (PD cluster)
# ---------------------------------------------------------------------------

def check_migration_packs(pack_fetches: list[int],
                          packs_per_rid: dict[int, int],
                          prefill_extra: list[int],
                          decode_counts: list[int], decode_rounds: int,
                          stray: int = 0,
                          budget: int = C.PACK_BUDGET_PER_MIGRATION
                          ) -> list[Finding]:
    """Pure checker over the fetch accounting of one PD cluster run:
    every migration pack is exactly ``budget`` fetches, every migrated
    rid packs once, prefill rounds fetch only to pack, decode rounds
    stay within the one-fetch round budget, and nothing fetches outside
    a worker round (install is zero-fetch)."""
    out = []
    for i, n in enumerate(pack_fetches):
        if n != budget:
            out.append(Finding(
                rule="ESS107", path=_AUDIT_PATH, line=0,
                scope=f"pack[{i}]",
                message=f"{n} device->host fetches in one migration pack "
                        f"(budget {budget}: pages + scales + ikeys + "
                        f"hidden + t0 ride ONE packed fetch)"))
    for rid, n in sorted(packs_per_rid.items()):
        if n != 1:
            out.append(Finding(
                rule="ESS107", path=_AUDIT_PATH, line=0,
                scope=f"rid[{rid}]",
                message=f"rid={rid} packed {n} times — one handoff per "
                        f"migration"))
    for i, n in enumerate(prefill_extra):
        if n > 0:
            out.append(Finding(
                rule="ESS107", path=_AUDIT_PATH, line=0,
                scope=f"prefill_round[{i}]",
                message=f"{n} device->host fetches outside the pack site "
                        f"in a prefill worker round — prefill fetches "
                        f"only to pack"))
    for f in check_fetch_counts(decode_counts, decode_rounds):
        out.append(dataclasses.replace(
            f, rule="ESS107", scope=f"decode_{f.scope}"))
    if stray:
        out.append(Finding(
            rule="ESS107", path=_AUDIT_PATH, line=0, scope="cluster",
            message=f"{stray} device->host fetches outside any worker "
                    f"round (placement/install must perform zero "
                    f"fetches — the first token rides the packet)"))
    return out


def audit_migration_packs(cfg=None, *, decode_session_cls=None,
                          max_seq: Optional[int] = None) -> list[Finding]:
    """Drive a 1-prefill + 1-decode :class:`EssCluster` over the mixed
    workload, counting ``jax.device_get`` and bracketing every
    ``pack_migration`` call (the allowlisted ESS107 pack site,
    :data:`contracts.PACK_SITE`) and every worker round.
    ``decode_session_cls`` is injectable so tests can demonstrate the
    audit catching a decode round that smuggles a second fetch."""
    from repro.cluster import EssCluster
    from repro.cluster import kv_transfer as KT
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import SamplingParams
    cfg = cfg if cfg is not None else _smoke_cfg()
    max_seq = max_seq if max_seq is not None else next(_FRESH_SEQ)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    cluster = EssCluster(params, cfg, num_prefill=1, num_decode=1,
                         num_slots=2, max_seq=max_seq, prefill_chunk=8,
                         compiled=True,
                         decode_session_cls=decode_session_cls)
    real = jax.device_get
    calls = [0]

    def counting(*a, **k):
        calls[0] += 1
        return real(*a, **k)

    pack_fetches: list[int] = []
    packs_per_rid: dict[int, int] = {}
    real_pack = KT.pack_migration

    def counting_pack(session, slot, req, t0, **kw):
        before = calls[0]
        pkt = real_pack(session, slot, req, t0, **kw)
        pack_fetches.append(calls[0] - before)
        packs_per_rid[req.rid] = packs_per_rid.get(req.rid, 0) + 1
        return pkt

    prefill_extra: list[int] = []
    decode_counts: list[int] = []

    def wrap_prefill(w):
        orig = w.step

        def step():
            before, npk = calls[0], len(pack_fetches)
            out = orig()
            prefill_extra.append(calls[0] - before
                                 - sum(pack_fetches[npk:]))
            return out

        w.step = step

    def wrap_decode(w):
        orig = w.step

        def step():
            before = calls[0]
            out = orig()
            decode_counts.append(calls[0] - before)
            return out

        w.step = step

    for w in cluster.prefill:
        wrap_prefill(w)
    for w in cluster.decode:
        wrap_decode(w)

    jax.device_get = counting
    KT.pack_migration = counting_pack
    try:
        for r in _mixed_requests():
            cluster.submit(r.prompt_len, SamplingParams(
                max_tokens=r.max_new_tokens, temperature=r.temperature,
                seed=r.seed))
        guard = 100
        while cluster.has_work() and guard:
            cluster.step()
            guard -= 1
        total_calls = calls[0]
    finally:
        jax.device_get = real
        KT.pack_migration = real_pack
    if not guard:
        return [Finding(rule="ESS107", path=_AUDIT_PATH, line=0,
                        scope="driver",
                        message="cluster workload did not finish in "
                                "100 steps")]
    stray = (total_calls - sum(pack_fetches) - sum(prefill_extra)
             - sum(decode_counts))
    return check_migration_packs(
        pack_fetches, packs_per_rid, prefill_extra, decode_counts,
        sum(w.session.report.rounds for w in cluster.decode), stray)


# ---------------------------------------------------------------------------
# the full audit
# ---------------------------------------------------------------------------

def run_all(*, paged: bool = True, dense: bool = True,
            workload: bool = True) -> list[Finding]:
    """Lower + audit both host tiers; ``workload=False`` skips the
    session-driving audits (ESS102/ESS103) for a fast structural pass."""
    findings = []
    tiers = ([("paged", _smoke_cfg(paged=True))] if paged else []) + \
            ([("dense", _smoke_cfg(paged=False))] if dense else [])
    for name, cfg in tiers:
        targets = build_targets(cfg)
        for f in (audit_donation(targets=targets)
                  + audit_dtypes(targets=targets)):
            findings.append(dataclasses.replace(
                f, scope=f"{name}/{f.scope}"))
    if paged:
        # pipelined (async-offload) variant of the paged tier: the
        # staging slab joins the donated state, so ESS101/ESS104 must
        # hold over the extra leaves, and ESS105 checks the refill
        # gather stays off the token critical path.
        cfg = _smoke_cfg(paged=True)
        targets = build_targets(cfg, prefetch=4)
        for f in (audit_donation(targets=targets)
                  + audit_dtypes(targets=targets)
                  + audit_pipeline_overlap(targets=targets)):
            findings.append(dataclasses.replace(
                f, scope=f"paged+pf/{f.scope}"))
        # quantized host tier (int8 payload + f16 scale plane): the
        # scale leaves join the donated state (ESS101/ESS104 over the
        # wider tree) and ESS106 proves dequant stays at gather width.
        # Audited plain and pipelined — the staging slab carries the
        # compressed representation, so the overlap contract (ESS105)
        # must hold with quantization on too.
        qcfg = _smoke_cfg(paged=True, host_dtype="int8")
        targets = build_targets(qcfg)
        for f in (audit_donation(targets=targets)
                  + audit_dtypes(targets=targets)
                  + audit_tier_dequant(targets=targets)):
            findings.append(dataclasses.replace(
                f, scope=f"paged+q8/{f.scope}"))
        targets = build_targets(qcfg, prefetch=4)
        for f in (audit_donation(targets=targets)
                  + audit_tier_dequant(targets=targets)
                  + audit_pipeline_overlap(targets=targets)):
            findings.append(dataclasses.replace(
                f, scope=f"paged+q8+pf/{f.scope}"))
    if workload:
        cfg = _smoke_cfg()
        for f in (audit_fetch_counts(cfg)
                  + audit_fetch_counts(cfg, mtp_depth=2)
                  + audit_retrace(cfg)):
            findings.append(dataclasses.replace(
                f, scope=f"paged/{f.scope}"))
        for f in audit_fetch_counts(cfg, overlap=True):
            findings.append(dataclasses.replace(
                f, scope=f"paged+pf/{f.scope}"))
        # PD disaggregation: the migration pack joins the fetch
        # discipline — one packed fetch per handoff, zero on install.
        for f in audit_migration_packs(cfg):
            findings.append(dataclasses.replace(
                f, scope=f"cluster/{f.scope}"))
    return findings
