"""esslint — static contract checking for the ESS serve loop.

Two layers (see ANALYSIS.md for the rule catalog):

* :mod:`repro.analysis.lint` — AST rules ESS001–ESS004 compiled from the
  repo's bug history (slot-mask gating, hidden host syncs, traced-value
  branching, undeclared donation).  Pure stdlib; runs in milliseconds.
* :mod:`repro.analysis.jaxpr_audit` — lowers every StepProgram variant
  and audits the donation contract, the one-fetch contract, the retrace
  budget and dtype drift against :mod:`repro.analysis.contracts`.

CLI: ``python -m repro.analysis [--check]`` (see ``--help``).
"""

from repro.analysis.findings import Finding, load_baseline, write_baseline

__all__ = ["Finding", "load_baseline", "write_baseline"]
