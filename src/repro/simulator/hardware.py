"""Hardware profiles for the high-fidelity simulator (paper §4.1).

The paper's simulator is built from real-machine metadata; ours is built
from (a) physical datasheet constants and (b) the paper's own published
measurement points (FlashTrans 37/43 GB/s, cudaMemcpyAsync 0.79/0.23 GB/s,
Table 2 throughputs) which serve as the calibration metadata.  The same
machinery parameterized with TPU v5e constants produces the projections
used alongside the dry-run roofline.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MFUCurve:
    """GEMM efficiency vs. rows (arithmetic-intensity saturation).

    eff(rows) = eff_max * rows / (rows + rows_half)  — the Michaelis-Menten
    shape reproduces Figure 1's throughput-vs-batch saturation; the two
    parameters are calibrated against Table 2 (see costmodel.calibrate)."""
    eff_max: float = 0.62
    rows_half: float = 830.0

    def __call__(self, rows: float) -> float:
        return self.eff_max * rows / (rows + self.rows_half)


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    # per accelerator
    peak_flops: float            # effective dense peak (model dtype), FLOP/s
    hbm_bw: float                # bytes/s
    hbm_bytes: float
    # scale-out fabric (per device, usable)
    fabric_bw: float             # bytes/s for EP all-to-all / allreduce
    # host link
    h2d_bw: float                # FlashTrans-grade coalesced transfers
    d2h_bw: float
    h2d_naive_bw: float          # fragmented small-block baseline
    d2h_naive_bw: float
    host_mem_bytes: float
    mfu: MFUCurve = MFUCurve()
    # misc overheads (seconds)
    kernel_launch: float = 3e-6
    a2a_latency: float = 15e-6


# Paper's system: 4 nodes x 8 H800, TP=1, EP=32, PCIe 5, FlashMLA engine.
# H800: ~989 TF bf16 (paper serves fp8 weights; effective GEMM peak taken
# as bf16 tensor-core rate which the calibration absorbs), 80 GB @ 3.35 TB/s,
# NVLink intra-node + IB inter-node (fabric ~ 25 GB/s/GPU usable for EP a2a).
H800_EP32 = HardwareProfile(
    name="h800-4node-ep32",
    peak_flops=1979e12,        # fp8 tensor-core peak (paper serves fp8)
    hbm_bw=3.35e12,
    hbm_bytes=80e9,
    fabric_bw=50e9,            # 8x400Gb IB per node / 8 GPUs, usable
    h2d_bw=37e9,            # paper §3.1 (FlashTrans)
    d2h_bw=43e9,
    h2d_naive_bw=0.79e9,    # paper §3.1 (cudaMemcpyAsync, 656 B blocks)
    d2h_naive_bw=0.23e9,
    host_mem_bytes=2e12,
    # MFU curve calibrated against 4 Table-2 anchor rows (costmodel.calibrate
    # reproduces this fit): 32K improvement +74.9 % (paper +69.4 %), 128K
    # +102.2 % (paper +123 %), all Table-2 rows within ±11 %.
    mfu=MFUCurve(eff_max=0.95, rows_half=772.85),
)

# TPU v5e chip (deployment target of this repo; roofline constants match
# the dry-run analysis): 197 TF bf16, 16 GB @ 819 GB/s, ICI 3 links x
# ~50 GB/s, PCIe gen3-class host DMA.
TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16e9,
    fabric_bw=50e9,
    h2d_bw=16e9,
    d2h_bw=16e9,
    h2d_naive_bw=0.4e9,
    d2h_naive_bw=0.2e9,
    host_mem_bytes=512e9,
    mfu=MFUCurve(eff_max=0.55, rows_half=600.0),
)

PROFILES = {"h800": H800_EP32, "v5e": TPU_V5E}
