"""Empirical LRU pool simulation over locality traces — produces the
paper's miss-count figures (4, 5, 8, 9) from first principles.

A numpy LRU (timestamp array, identical semantics to
``repro.core.lru_pool``) replays Top-K traces; misses per step are counted
with/without LRU-Warmup at any Sparse-Memory-Ratio / context length."""

from __future__ import annotations

import numpy as np

from repro.simulator.locality import TOPK, make_trace


class NumpyLRU:
    def __init__(self, pool_entries: int, context: int):
        self.P = pool_entries
        self.slot_of = np.full(context, -1, np.int64)
        self.ids = np.full(pool_entries, -1, np.int64)
        self.last = np.full(pool_entries, -1, np.int64)
        self.step = 0

    def access(self, req: np.ndarray) -> int:
        """Touch the requested set; LRU-admit misses; return miss count."""
        slots = self.slot_of[req]
        hit = slots >= 0
        self.last[slots[hit]] = self.step
        miss_ids = req[~hit]
        n = miss_ids.size
        if n:
            evict = np.argpartition(self.last, n - 1)[:n]
            old = self.ids[evict]
            self.slot_of[old[old >= 0]] = -1
            self.ids[evict] = miss_ids
            self.slot_of[miss_ids] = evict
            self.last[evict] = self.step
        self.step += 1
        return int(n)


def run_lru(trace: np.ndarray, ratio: float, context: int,
            warmup_windows: int = 0) -> np.ndarray:
    """trace [T, K]; first ``warmup_windows`` rows preheat the pool (not
    counted).  Returns misses per counted step."""
    K = trace.shape[1]
    P = max(int(ratio * context), K)
    lru = NumpyLRU(P, context)
    for w in range(warmup_windows):
        lru.access(trace[w])
    out = []
    for t in range(warmup_windows, len(trace)):
        out.append(lru.access(trace[t]))
    return np.asarray(out)


def miss_profile(context: int, ratio: float, layers: int = 61,
                 steps: int = 96, warmup: bool = True, seed: int = 0
                 ) -> np.ndarray:
    """Average steady misses per layer (Figure 5/8)."""
    W = 32 if warmup else 0
    prof = []
    for l in range(layers):
        tr = make_trace(steps + W, context, layer=l, seed=seed)
        m = run_lru(tr, ratio, context, warmup_windows=W)
        prof.append(m[steps // 4:].mean())     # steady window
    return np.asarray(prof)


def early_miss_curve(context: int, ratio: float, layer: int = 8,
                     steps: int = 64, warmup: bool = True, mtp: int = 1,
                     seed: int = 3) -> np.ndarray:
    """Misses per decode step from step 0 (Figure 4)."""
    W = 32 if warmup else 0
    tr = make_trace(steps * mtp + W, context, layer=layer, seed=seed)
    m = run_lru(tr, ratio, context, warmup_windows=W)
    if mtp > 1:
        m = m[:steps * mtp].reshape(steps, mtp).sum(1)
    return m[:steps]
