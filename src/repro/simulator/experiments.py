"""Paper experiment reproductions (one function per table/figure).

Every function returns plain python structures; ``benchmarks/`` renders
them as CSV, and ``tests/test_paper_numbers.py`` asserts fidelity bands.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.simulator import locality, lru_sim
from repro.simulator.costmodel import (N_LAYERS, ServeConfig, layer_costs,
                                       max_feasible_batch,
                                       weights_bytes_per_gpu)
from repro.simulator.hardware import H800_EP32, HardwareProfile
from repro.simulator.pipeline import (layer_time, otps, simulate_step,
                                      throughput_node)

PAPER_TABLE2 = [
    # (mtp, accept, context, bs, ratio, offload, tbo, thr, otps)
    (2, 1.7, 32768, 52, 1.00, False, True, 9647.71, 23.19),
    (2, 1.7, 32768, 64, 0.82, True, True, 10693.31, 20.89),
    (2, 1.7, 32768, 96, 0.48, True, True, 13155.98, 17.13),
    (2, 1.7, 32768, 128, 0.31, True, True, 15620.14, 15.25),
    (2, 1.7, 32768, 160, 0.21, True, True, 16347.88, 12.77),
    (4, 2.8, 32768, 52, 1.00, False, True, 12168.02, 29.25),
    (4, 2.8, 32768, 64, 0.82, True, True, 13656.66, 26.67),
    (4, 2.8, 32768, 96, 0.48, True, True, 15814.07, 20.59),
    (4, 2.8, 32768, 128, 0.31, True, True, 17746.10, 17.33),
    (4, 2.8, 32768, 160, 0.21, True, True, 17601.03, 13.75),
    (4, 3.4, 32768, 52, 1.00, False, True, 14775.45, 35.52),
    (4, 3.4, 32768, 64, 0.82, True, True, 16583.08, 32.39),
    (4, 3.4, 32768, 96, 0.48, True, True, 19202.80, 25.00),
    (4, 3.4, 32768, 128, 0.31, True, True, 21548.83, 21.04),
    (4, 3.4, 32768, 160, 0.21, True, True, 21372.68, 16.70),
    (2, 1.7, 131072, 13, 1.00, False, False, 3669.19, 23.19),
    (2, 1.7, 131072, 40, 0.20, True, False, 6925.06, 21.64),
    (2, 1.7, 131072, 54, 0.10, True, False, 8169.60, 18.91),
]


def _sc(mtp, acc, ctx, bs, ratio, offload, tbo) -> ServeConfig:
    return ServeConfig(batch_per_gpu=bs, context=ctx, mtp=mtp,
                       accept_ratio=acc, sparse_memory_ratio=ratio,
                       offload=offload, two_batch_overlap=tbo,
                       overlap="layerwise")


def table2(hw: HardwareProfile = H800_EP32) -> list[dict[str, Any]]:
    """Throughput/OTPS for every paper row + our simulation + deviation."""
    out = []
    for (mtp, acc, ctx, bs, ratio, off, tbo, pthr, potps) in PAPER_TABLE2:
        sc = _sc(mtp, acc, ctx, bs, ratio, off, tbo)
        thr = throughput_node(hw, sc)
        ot = otps(hw, sc)
        out.append(dict(mtp=mtp, accept=acc, context=ctx, batch=bs,
                        ratio=ratio, offload=off,
                        sim_throughput=round(thr, 2), paper_throughput=pthr,
                        sim_otps=round(ot, 2), paper_otps=potps,
                        dev_pct=round(100 * (thr / pthr - 1), 1)))
    return out


def headline_improvements(hw: HardwareProfile = H800_EP32) -> dict[str, float]:
    """The abstract's two numbers: +69.4 % @32K and +123 % @128K."""
    b32 = throughput_node(hw, _sc(2, 1.7, 32768, 52, 1.0, False, True))
    e32 = throughput_node(hw, _sc(2, 1.7, 32768, 160, 0.21, True, True))
    b128 = throughput_node(hw, _sc(2, 1.7, 131072, 13, 1.0, False, False))
    e128 = throughput_node(hw, _sc(2, 1.7, 131072, 54, 0.10, True, False))
    return {"improvement_32k_pct": 100 * (e32 / b32 - 1),
            "paper_32k_pct": 69.4,
            "improvement_128k_pct": 100 * (e128 / b128 - 1),
            "paper_128k_pct": 123.0}


def fig1_throughput_vs_batch(hw: HardwareProfile = H800_EP32,
                             ctx: int = 32768) -> list[dict[str, Any]]:
    """Figure 1: throughput vs batch; GPU memory caps the baseline at ~52."""
    rows = []
    sc0 = _sc(2, 1.7, ctx, 52, 1.0, False, True)
    cap = max_feasible_batch(hw, sc0)
    for bs in [8, 16, 24, 32, 40, 52, 64, 80, 96, 112, 128, 144, 160]:
        sc = _sc(2, 1.7, ctx, bs, 1.0, False, True)
        feasible = bs <= cap
        rows.append(dict(batch=bs, feasible_on_gpu=feasible,
                         throughput=round(throughput_node(hw, sc), 2)))
    return rows


def fig2_similarity(ctx_list=(8192, 32768, 131072), layers=(0, 8, 24, 48),
                    steps: int = 64) -> list[dict[str, Any]]:
    """Figure 2: intra-layer similarity across context lengths."""
    out = []
    for ctx in ctx_list:
        for l in layers:
            tr = locality.make_trace(steps, ctx, layer=l, seed=7)
            sim = locality.similarity_of_trace(tr)
            out.append(dict(context=ctx, layer=l,
                            similarity_mean=round(float(sim.mean()), 4),
                            similarity_p10=round(float(np.percentile(sim, 10)), 4)))
    return out


def fig4_warmup(ctx: int = 32768, ratio: float = 0.2,
                steps: int = 48) -> dict[str, list[float]]:
    """Figure 4: early-decode miss count, before/after LRU-Warmup (MTP=1)."""
    cold = lru_sim.early_miss_curve(ctx, ratio, warmup=False, steps=steps)
    warm = lru_sim.early_miss_curve(ctx, ratio, warmup=True, steps=steps)
    return {"before_warmup": cold.tolist(), "after_warmup": warm.tolist()}


def fig5_miss_by_layer(ctx: int = 32768,
                       ratios=(0.1, 0.2, 0.4, 0.6)) -> list[dict[str, Any]]:
    """Figure 5: per-layer miss count across Sparse Memory Ratios."""
    out = []
    for r in ratios:
        prof = lru_sim.miss_profile(ctx, r, layers=61, steps=48)
        out.append(dict(ratio=r, miss_min=round(float(prof.min()), 2),
                        miss_max=round(float(prof.max()), 2),
                        miss_mean=round(float(prof.mean()), 2)))
    return out


def fig7_overlap_comparison(hw: HardwareProfile = H800_EP32
                            ) -> list[dict[str, Any]]:
    """Figure 7: per-layer time of the three overlap strategies vs miss
    count (paper setting: 128K, BS=160, MTP=2, TBO on, PCIe 37 GB/s)."""
    sc = ServeConfig(batch_per_gpu=160, context=131072, mtp=2,
                     offload=True, two_batch_overlap=True)
    out = []
    for miss in [0, 32, 64, 128, 256, 512, 1024, 2048]:
        c = layer_costs(hw, sc, moe_layer=True, miss_per_seq=float(miss))
        out.append(dict(miss=miss,
                        none_ms=round(1e3 * layer_time(c, "none"), 4),
                        da_ms=round(1e3 * layer_time(c, "da"), 4),
                        dba_ms=round(1e3 * layer_time(c, "dba"), 4)))
    return out


def fig8_9_miss_vs_context(ratios=(0.1, 0.2, 0.3, 0.4),
                           ctxs=(8192, 32768, 65536, 131072)
                           ) -> list[dict[str, Any]]:
    """Figures 8/9: miss behaviour across context lengths (MTP=2 r=0.2 for
    the layer consistency; ratio sweep for scalability)."""
    out = []
    for ctx in ctxs:
        for r in ratios:
            prof = lru_sim.miss_profile(ctx, r, layers=16, steps=32)
            out.append(dict(context=ctx, ratio=r,
                            miss_mean=round(float(prof.mean()), 2)))
    return out


def flashtrans_comparison(hw: HardwareProfile = H800_EP32,
                          miss: float = 256.0) -> dict[str, float]:
    """§3.1: effective-bandwidth impact — naive per-block copies vs
    FlashTrans-grade coalesced transfers, as per-layer fetch time."""
    sc_fast = ServeConfig(batch_per_gpu=160, offload=True,
                          use_flashtrans=True, avg_miss_per_seq=miss)
    sc_slow = dataclasses.replace(sc_fast, use_flashtrans=False)
    cf = layer_costs(hw, sc_fast, moe_layer=True, miss_per_seq=miss)
    cs = layer_costs(hw, sc_slow, moe_layer=True, miss_per_seq=miss)
    return {"flashtrans_fetch_ms": 1e3 * cf.t_fetch,
            "naive_fetch_ms": 1e3 * cs.t_fetch,
            "speedup": cs.t_fetch / max(cf.t_fetch, 1e-12)}


def v5e_projection() -> list[dict[str, Any]]:
    """ESS on the deployment target (TPU v5e pod, 256 chips, EP=256).

    v5e's 16 GB HBM makes the paper's §2.1 memory wall *harsher* than on
    80 GB H800s, so ESS buys more: the same machinery projects +87 % @32K
    and +128 % @128K decode throughput per pod."""
    from repro.simulator.costmodel import cache_bytes_per_seq
    from repro.simulator.hardware import TPU_V5E
    out = []
    for ctx, tbo in [(32768, True), (131072, False)]:
        free = TPU_V5E.hbm_bytes - 671e9 / 256 - 2e9
        cap_b = max(1, int(free / (cache_bytes_per_seq(ctx, 1.0, False)
                                   * 0.43)))
        cap_e = max(1, int(free / (cache_bytes_per_seq(ctx, 0.25, True)
                                   * 0.43)))

        def thr(bs, ratio, off):
            sc = ServeConfig(batch_per_gpu=bs, sparse_memory_ratio=ratio,
                             offload=off, two_batch_overlap=tbo, context=ctx,
                             overlap="layerwise", ep_size=256,
                             gpus_per_node=256)
            return throughput_node(TPU_V5E, sc)

        b = thr(cap_b, 1.0, False)
        e = thr(cap_e, 0.25, True)
        out.append(dict(context=ctx, batch_base=cap_b, batch_ess=cap_e,
                        thr_base=round(b, 1), thr_ess=round(e, 1),
                        improvement_pct=round(100 * (e / b - 1), 1)))
    return out


def memory_analysis(hw: HardwareProfile = H800_EP32) -> dict[str, Any]:
    """§2.1: weights/cache memory accounting + feasible-batch ceilings."""
    out = {}
    for ctx in (32768, 131072):
        for ratio, off in [(1.0, False), (0.3, True), (0.2, True), (0.1, True)]:
            sc = ServeConfig(batch_per_gpu=1, context=ctx,
                             sparse_memory_ratio=ratio, offload=off)
            out[f"ctx{ctx}_ratio{ratio}"] = max_feasible_batch(hw, sc)
    out["weights_gb_per_gpu"] = round(weights_bytes_per_gpu(
        ServeConfig()) / 1e9, 2)
    return out
