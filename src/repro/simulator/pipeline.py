"""Execution-pipeline reconstruction (paper §4.1): assembles per-layer op
timings into a decode-round time under the chosen overlap strategy, MTP and
Two-Batch Overlap — an event-level model of Figure 6's timelines.

Resources: one compute stream, one PCIe stream, one fabric (EP) stream per
GPU.  TBO interleaves two half-batches so one half's transfers/a2a overlap
the other half's compute (SGLang dual-stream semantics)."""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.simulator.costmodel import (LayerCosts, N_DENSE, N_LAYERS,
                                       ServeConfig, layer_costs, lm_head_time)
from repro.simulator.hardware import HardwareProfile


def layer_time(c: LayerCosts, overlap: str) -> float:
    """One layer's critical path (single batch stream), Figure 6 semantics."""
    serial_tail = c.t_ffn + c.t_a2a + c.t_writeback
    if overlap == "none":
        # Indexer -> fetch -> attention, fully serialized
        return (c.t_indexer + c.t_fetch + c.t_preattn + c.t_attn
                + serial_tail)
    t_attn0 = c.t_attn * c.t_attn0_frac
    t_attn1 = c.t_attn * (1.0 - c.t_attn0_frac)
    if overlap == "da":
        # fetch ∥ (PreAttn + Attn0); Attn1 waits for the fetch
        hidden = c.t_preattn + t_attn0
        exposed = max(0.0, c.t_fetch - hidden)
        return c.t_indexer + max(hidden, c.t_fetch) * 0 + hidden + exposed \
            + t_attn1 + serial_tail
    if overlap == "dba":
        # half the indexer also overlaps the fetch (batch-split indexer)
        hidden = c.t_preattn + t_attn0 + 0.5 * c.t_indexer
        exposed = max(0.0, c.t_fetch - hidden)
        return (0.5 * c.t_indexer + hidden + exposed + t_attn1
                + c.t_dba_overhead + serial_tail)
    raise ValueError(overlap)


def pick_overlap(hw: HardwareProfile, c: LayerCosts, sc: ServeConfig) -> str:
    """Layer-wise policy (paper §3.3): pick the strategy with the smaller
    modeled layer time — the offline-profiling decision."""
    return min(("da", "dba"), key=lambda o: layer_time(c, o))


def simulate_step(hw: HardwareProfile, sc: ServeConfig,
                  miss_by_layer: list[float] | None = None) -> float:
    """Seconds per decode round per GPU.

    With ``sc.async_offload`` the plan/compute/commit pipeline stages
    ``prefetch_hit_rate`` of each layer's misses one round ahead: the
    staged fraction leaves the layer critical path (only the residual
    misses pay a synchronous fetch), but its bytes still cross PCIe —
    the accumulated staged link time is exposed only when it exceeds
    the round's compute."""
    from repro.simulator.locality import expected_miss_per_seq
    hr = sc.prefetch_hit_rate if (sc.offload and sc.async_offload) else 0.0
    pf_hidden = 0.0
    times = []
    for layer in range(N_LAYERS):
        if sc.avg_miss_per_seq is not None:
            miss = sc.avg_miss_per_seq
        elif miss_by_layer is not None:
            miss = miss_by_layer[layer]
        else:
            miss = expected_miss_per_seq(sc.context, sc.sparse_memory_ratio,
                                         layer=layer, warmed=sc.warmup) \
                if sc.offload else 0.0
        c = layer_costs(hw, sc, moe_layer=(layer >= N_DENSE),
                        miss_per_seq=miss)
        if hr > 0.0:
            pf_hidden += c.t_fetch * hr
            c = dataclasses.replace(c, t_fetch=c.t_fetch * (1.0 - hr))
        ov = sc.overlap
        if ov == "layerwise":
            ov = pick_overlap(hw, c, sc)
        if not sc.offload:
            ov = "none"  # no fetch to hide; layer_time none path w/ fetch=0
        times.append(layer_time(c, ov))
    t = sum(times) + lm_head_time(hw, sc)

    if sc.two_batch_overlap and sc.batch_per_gpu >= 16:
        # two half-batches: each half's comm hides under the other half's
        # compute; effectiveness bounded by the comm/compute ratio.
        half = dataclasses.replace(sc, batch_per_gpu=sc.batch_per_gpu // 2)
        comm = 0.0
        comp = 0.0
        for layer in range(N_LAYERS):
            miss = (sc.avg_miss_per_seq if sc.avg_miss_per_seq is not None
                    else (expected_miss_per_seq(sc.context,
                                                sc.sparse_memory_ratio,
                                                layer=layer,
                                                warmed=sc.warmup)
                          if sc.offload else 0.0))
            ch = layer_costs(hw, half, moe_layer=(layer >= N_DENSE),
                             miss_per_seq=miss)
            # the staged fraction leaves the TBO comm stream too: only
            # residual (synchronous) fetches compete with the a2a there
            comm += ch.t_a2a + ch.t_fetch * (1.0 - hr) + ch.t_writeback
            comp += ch.t_preattn + ch.t_indexer + ch.t_attn + ch.t_ffn
        comp += lm_head_time(hw, half)
        # steady state: each half's comm hides under the other half's
        # compute; exposed only when comm > comp.  Plus pipeline edges
        # (first comm burst / last compute drain).
        t_tbo = 2 * comp + 2 * max(0.0, comm - comp) + 0.02 * comm
        t = min(t, t_tbo)

    if hr > 0.0:
        # staged traffic of one round (≈ next round's hits, full batch)
        # rides the PCIe stream under the whole round's compute; exposed
        # only past the link's round-level headroom.
        t += max(0.0, pf_hidden - t)
    return t


def throughput_node(hw: HardwareProfile, sc: ServeConfig,
                    miss_by_layer: list[float] | None = None) -> float:
    """Output tokens/s per node (Table 2 metric)."""
    t = simulate_step(hw, sc, miss_by_layer)
    return sc.gpus_per_node * sc.batch_per_gpu * sc.accept_ratio / t


def otps(hw: HardwareProfile, sc: ServeConfig,
         miss_by_layer: list[float] | None = None) -> float:
    """Output tokens/s per sequence (Table 2 'OTPS')."""
    return sc.accept_ratio / simulate_step(hw, sc, miss_by_layer)
