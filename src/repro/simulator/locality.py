"""Top-K access-pattern model with temporal locality (paper §2.2).

Two layers of modelling:

* ``make_trace`` — synthetic per-step Top-K index sets with controlled
  intra-layer similarity (Figure 2's 0.85–0.99 band): a Markov churn model
  where each step keeps a fraction of the previous set and redraws the rest
  from a recency-biased Zipf distribution (LongBench-V2-like reuse).
* ``expected_miss_per_seq`` — closed-form steady-state miss estimate used
  by the pipeline model, with per-layer churn heterogeneity matching
  Figure 5/8 (16.66–605 misses/step at ratio 0.2, consistent layer pattern
  across context lengths) and the small-pool thrashing blow-up of Figure 9.
"""

from __future__ import annotations

import numpy as np

TOPK = 2048


def layer_churn(layer: int, n_layers: int = 61, lo: float = 0.008,
                hi: float = 0.40, seed: int = 1234) -> float:
    """Per-layer churn (1 - intra-layer similarity), fixed pseudo-random
    profile: heavy-churn layers cluster early-mid stack (Fig. 5/8 shape)."""
    rng = np.random.default_rng(seed)
    prof = np.exp(rng.uniform(np.log(lo), np.log(hi), size=n_layers))
    prof.sort()
    perm = np.random.default_rng(seed + 1).permutation(n_layers)
    return float(prof[perm[layer % n_layers]])


def expected_miss_per_seq(context: int, ratio: float, layer: int = 0,
                          warmed: bool = True, topk: int = TOPK) -> float:
    """Steady-state misses per sequence per decode step."""
    K = min(topk, context)
    P = max(int(ratio * context), K)
    S = max(context, K + 1)
    churn = layer_churn(layer)
    deficit = max(0.0, 1.0 - (P - K) / max(1, S - K))   # 0 when pool == S
    # Fig 9: misses stable for pools >= ~6.4K entries (P/K >= 3.2), sharp
    # thrashing blow-up below that (frequent swap-in/swap-out)
    thrash = 2.5 * max(0.0, 3.2 * K / P - 1.0) ** 2
    miss = K * churn * deficit * (1.0 + thrash)
    if not warmed:
        miss += K * 0.25                                # early-phase penalty
    return float(min(miss, K))


def make_trace(steps: int, context: int, layer: int = 0, topk: int = TOPK,
               seed: int = 0, zipf_a: float = 1.1,
               recency_frac: float = 0.25) -> np.ndarray:
    """[steps, K] Top-K id sets with Figure-2-like temporal locality."""
    K = min(topk, context)
    rng = np.random.default_rng(seed + 17 * layer)
    churn = layer_churn(layer)

    # popularity: Zipf over positions + recency boost
    ranks = rng.permutation(context)
    pop = 1.0 / (1 + ranks.astype(np.float64)) ** zipf_a
    recent = np.zeros(context)
    n_rec = max(1, int(recency_frac * context))
    recent[-n_rec:] = np.linspace(0, 2.0, n_rec)
    p = pop * np.exp(recent)
    p /= p.sum()

    cur = rng.choice(context, size=K, replace=False, p=p)
    out = np.empty((steps, K), np.int64)
    for t in range(steps):
        n_new = rng.binomial(K, churn)
        if n_new:
            keep = rng.choice(K, size=K - n_new, replace=False)
            kept = cur[keep]
            mask = np.ones(context, bool)
            mask[kept] = False
            cand = np.nonzero(mask)[0]
            pw = p[cand] / p[cand].sum()
            new = rng.choice(cand, size=n_new, replace=False, p=pw)
            cur = np.concatenate([kept, new])
        out[t] = np.sort(cur)
    return out


def similarity_of_trace(trace: np.ndarray) -> np.ndarray:
    """Empirical Eq.-1 similarity of a [T, K] trace."""
    sims = []
    for t in range(1, len(trace)):
        inter = np.intersect1d(trace[t - 1], trace[t]).size
        sims.append(inter / trace.shape[1])
    return np.asarray(sims)
