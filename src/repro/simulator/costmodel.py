"""Analytic op-level cost model for DeepSeek-V3.2-Exp decode (paper §4.1).

Every term is physical (FLOPs / bytes over datasheet rates with an
MFU-saturation curve); the only fitted quantities are the two MFU-curve
parameters, calibrated against the paper's own Table 2 baseline row
(BS=52 → 9,647 tok/s/node) and one scaling row.  Timings are *per decode
round per GPU* with the paper's Table 1 system (TP=1, EP=32): attention and
caches are data-parallel (B sequences resident per GPU), experts are
expert-parallel.

All byte counts use the paper's fp8 serving layout: latent entry 656 B
(576 dims + scales), indexer entry 132 B (≈16.8 % of cache bytes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.simulator.hardware import HardwareProfile, MFUCurve

# DeepSeek-V3.2-Exp constants (arXiv:2412.19437 + paper)
D_MODEL = 7168
N_LAYERS = 61
N_DENSE = 3                 # first 3 layers dense
N_HEADS = 128
Q_LORA = 1536
KV_LORA = 512
QK_NOPE = 128
QK_ROPE = 64
V_HEAD = 128
D_FF_DENSE = 18432
D_EXPERT = 2048
N_EXPERTS = 256
TOPK_EXP = 8
N_SHARED = 1
VOCAB = 129280
IDX_HEADS = 64
IDX_DIM = 128
TOPK_DSA = 2048

LATENT_BYTES = 656          # paper §2.2
LATENT_Q8_BYTES = 578       # quantized host tier: 576 int8 + 2 B f16 scale
IDX_BYTES = 132             # 16.8 % of (656+132)
WEIGHT_BYTES = 1            # fp8 serving weights
ACT_BYTES = 2               # bf16 activations


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_per_gpu: int = 52
    context: int = 32768
    mtp: int = 2                     # draft depth; q_len = mtp + 1
    accept_ratio: float = 1.7        # emitted tokens / round / sequence
    ep_size: int = 32
    gpus_per_node: int = 8
    sparse_memory_ratio: float = 1.0 # 1.0 = all cache on GPU (baseline)
    offload: bool = False            # ESS on/off
    use_flashtrans: bool = True
    overlap: str = "da"              # none | da | dba | layerwise
    two_batch_overlap: bool = True
    avg_miss_per_seq: float | None = None   # override (else from locality model)
    warmup: bool = True
    # paged host tier (repro.cache.latent_cache): page-granular transfers +
    # page-granular host reservations.  False keeps the calibrated
    # FlashTrans row-fragment baseline (Table-2 anchors unchanged).
    paged_host: bool = False
    host_page_rows: int = 64
    # async-offload pipeline (repro.core.transfer): the previous round's
    # indexer scores drive a speculative H2D stage, so ``prefetch_hit_rate``
    # of each round's misses arrive pre-staged and only the residual
    # misses pay a synchronous fetch.  The staged traffic still crosses
    # PCIe — it is exposed only when its link time exceeds the round's
    # compute (modeled in simulate_step).  False keeps the calibrated
    # synchronous-fetch model (Table-2 anchors unchanged).
    async_offload: bool = False
    prefetch_hit_rate: float = 0.9
    # host-tier storage bytes per latent row.  The calibrated default is
    # the paper's 656 B fp8 serving layout (Table-2 anchors unchanged);
    # the repro's quantized tier (repro.distributed.compression) stores
    # 576 int8 dims + a 2 B f16 scale = 578 B, shrinking the host
    # reservation *and* every PCIe transfer by the same factor.  Device
    # HBM terms (attention reads, device cache ceiling) keep
    # LATENT_BYTES — the LRU pool stays bf16.
    cache_bytes_per_row: int = LATENT_BYTES

    @property
    def q_len(self) -> int:
        return self.mtp + 1


def active_params() -> float:
    """~37 B active params/token (dense + shared + top-8 experts + MLA)."""
    mla = (D_MODEL * Q_LORA + Q_LORA * N_HEADS * (QK_NOPE + QK_ROPE)
           + D_MODEL * KV_LORA + D_MODEL * QK_ROPE
           + KV_LORA * N_HEADS * (QK_NOPE + V_HEAD)
           + N_HEADS * V_HEAD * D_MODEL)
    dense_ffn = 3 * D_MODEL * D_FF_DENSE
    moe_ffn = 3 * D_MODEL * D_EXPERT * (TOPK_EXP + N_SHARED)
    idx = D_MODEL * (IDX_HEADS * IDX_DIM + IDX_DIM + IDX_HEADS)
    per_moe_layer = mla + moe_ffn + idx
    per_dense_layer = mla + dense_ffn + idx
    return (N_DENSE * per_dense_layer
            + (N_LAYERS - N_DENSE) * per_moe_layer
            + 2 * VOCAB * D_MODEL)


# ---------------------------------------------------------------------------
# Paged host-tier transfer + reservation model
# ---------------------------------------------------------------------------

# PCIe payload headroom over FlashTrans's measured 656-byte-fragment rate:
# the paper's 37 GB/s folds a per-fragment descriptor cost; whole-page
# fragments amortize it toward the link payload limit (~64/37 for PCIe5;
# 1.6 is the conservative figure used here for every profile).
PAGE_LINK_HEADROOM = 1.6


@dataclasses.dataclass(frozen=True)
class PagedTransferModel:
    """Scatter-gather transfer cost at page granularity.

    ``t = bytes / link_bw + fragments * frag_overhead``: the per-fragment
    descriptor overhead is *derived* from the profile's measured
    row-fragment bandwidth (``1/bw_meas = 1/link + ovh/656``), so the model
    reduces exactly to FlashTrans when every fragment is one 656 B row and
    approaches the link payload limit when fragments are whole pages.
    """
    page_rows: int
    link_h2d_bw: float
    link_d2h_bw: float
    h2d_frag_overhead_s: float
    d2h_frag_overhead_s: float
    row_bytes: int = LATENT_BYTES

    def h2d_time(self, rows: float, fragments: float) -> float:
        return (rows * self.row_bytes / self.link_h2d_bw
                + fragments * self.h2d_frag_overhead_s)

    def d2h_time(self, rows: float, fragments: float) -> float:
        return (rows * self.row_bytes / self.link_d2h_bw
                + fragments * self.d2h_frag_overhead_s)


def paged_transfer_model(hw: HardwareProfile, page_rows: int = 64,
                         row_bytes: int = LATENT_BYTES
                         ) -> PagedTransferModel:
    link_h2d = hw.h2d_bw * PAGE_LINK_HEADROOM
    link_d2h = hw.d2h_bw * PAGE_LINK_HEADROOM
    # the per-fragment descriptor overhead is a property of the link, not
    # the payload encoding: derive it from the measured 656 B-row rate
    # regardless of what this tier stores per row
    ovh_h2d = LATENT_BYTES * (1.0 / hw.h2d_bw - 1.0 / link_h2d)
    ovh_d2h = LATENT_BYTES * (1.0 / hw.d2h_bw - 1.0 / link_d2h)
    return PagedTransferModel(page_rows, link_h2d, link_d2h,
                              ovh_h2d, ovh_d2h, row_bytes)


def host_bytes_per_seq(sc: ServeConfig, avg_fill: float = 0.43) -> float:
    """Host-tier bytes one admitted sequence pins across the layer stack.

    Dense layout reserves ``context`` rows per slot up front; the paged
    layout maps pages as the sequence grows, so the pin tracks the actual
    mean fill (rounded up to whole pages — the only fragmentation)."""
    rows = float(sc.context)
    if sc.paged_host:
        R = sc.host_page_rows
        rows = math.ceil(avg_fill * sc.context / R) * R
    return N_LAYERS * rows * sc.cache_bytes_per_row


def max_host_admission_batch(hw: HardwareProfile, sc: ServeConfig,
                             avg_fill: float = 0.43,
                             reserve_frac: float = 0.05) -> int:
    """Host-memory admission ceiling: sequences admittable by free-page
    count (paged) vs dense per-slot reservations — the serve loop's gate."""
    usable = hw.host_mem_bytes * (1.0 - reserve_frac)
    return max(1, int(usable // host_bytes_per_seq(sc, avg_fill)))


# ---------------------------------------------------------------------------
# Inter-node migration model (PD-disaggregated handoff)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InterNodeModel:
    """Prefill→decode migration link (the PD handoff's wire).

    One migration moves a finished prompt's whole latent state at page
    granularity — per layer, the prompt's latent pages in the host tier's
    *storage* dtype (the quantized int8/fp8 page format doubles as the
    wire format, no dequant/requant round-trip) plus the indexer-key
    rows.  ``t = latency + bytes / bandwidth``: a single fabric message
    per handoff (the packet is one contiguous pack), so latency is paid
    once, not per page."""
    bandwidth: float         # bytes/s, usable point-to-point fabric
    latency_s: float         # per-packet (RDMA rendezvous + descriptor)
    row_bytes: int = LATENT_BYTES

    def packet_bytes(self, rows: float, num_layers: int = N_LAYERS
                     ) -> float:
        """Wire bytes of one migration: latent payload (+ per-row scales,
        folded into ``row_bytes``) and indexer keys across the stack."""
        return num_layers * rows * (self.row_bytes + IDX_BYTES)

    def transfer_time(self, rows: float, num_layers: int = N_LAYERS
                      ) -> float:
        return self.latency_s + self.packet_bytes(rows, num_layers) \
            / self.bandwidth


def internode_model(hw: HardwareProfile,
                    row_bytes: int = LATENT_BYTES) -> InterNodeModel:
    """The profile's scale-out fabric as a migration link.  The same
    per-GPU usable EP-fabric bandwidth carries handoffs (migrations and
    all-to-alls share the NICs); a2a latency stands in for the RDMA
    per-message cost."""
    return InterNodeModel(bandwidth=hw.fabric_bw, latency_s=hw.a2a_latency,
                          row_bytes=row_bytes)


def pd_migration_time_per_seq(hw: HardwareProfile, sc: ServeConfig,
                              avg_fill: float = 0.43) -> float:
    """Per-sequence handoff cost in a PD-disaggregated cluster: the
    prompt's rows (mean fill of the context, rounded up to whole pages
    when paged) cross the inter-node link once, in the host tier's
    storage dtype."""
    rows = avg_fill * sc.context
    if sc.paged_host:
        R = sc.host_page_rows
        rows = math.ceil(rows / R) * R
    return internode_model(hw, sc.cache_bytes_per_row).transfer_time(rows)


@dataclasses.dataclass
class LayerCosts:
    """Per-layer, per-GPU, per-decode-round timings (seconds)."""
    t_preattn: float        # q down/up-proj, rope, o-proj
    t_indexer: float        # full indexer scoring + top-k
    t_attn: float           # sparse MLA over top-2048
    t_attn0_frac: float     # fraction of t_attn independent of the fetch
    t_ffn: float            # routed+shared experts (incl. weight streaming)
    t_a2a: float            # EP dispatch+combine
    t_fetch: float          # H2D miss fetch
    t_writeback: float      # D2H new-latent writeback
    t_dba_overhead: float   # indexer batch-split loss


def _gemm_time(hw: HardwareProfile, rows: float, flops: float,
               weight_bytes: float) -> float:
    """max(compute @ MFU(rows), weight streaming) + launch."""
    eff = hw.mfu(rows)
    t_c = flops / (hw.peak_flops * max(eff, 1e-3))
    t_m = weight_bytes / hw.hbm_bw
    return max(t_c, t_m) + hw.kernel_launch


def layer_costs(hw: HardwareProfile, sc: ServeConfig, *, moe_layer: bool,
                miss_per_seq: float) -> LayerCosts:
    B = sc.batch_per_gpu
    q = sc.q_len
    rows = B * q                                  # GEMM rows per round
    S = sc.context

    # --- PreAttn: q projections + output proj (paper §3.3 PreAttn set) ----
    pre_flops = 2 * rows * (D_MODEL * Q_LORA
                            + Q_LORA * N_HEADS * (QK_NOPE + QK_ROPE)
                            + KV_LORA * N_HEADS * QK_NOPE    # absorb W_uk
                            + KV_LORA * N_HEADS * V_HEAD     # absorb W_uv
                            + N_HEADS * V_HEAD * D_MODEL
                            + D_MODEL * (KV_LORA + QK_ROPE))
    pre_w = WEIGHT_BYTES * (D_MODEL * Q_LORA
                            + Q_LORA * N_HEADS * (QK_NOPE + QK_ROPE)
                            + KV_LORA * N_HEADS * (QK_NOPE + V_HEAD)
                            + N_HEADS * V_HEAD * D_MODEL
                            + D_MODEL * (KV_LORA + QK_ROPE))
    t_preattn = _gemm_time(hw, rows, pre_flops, pre_w)

    # --- Indexer: reads the whole Indexer-Cache, scores, top-k ------------
    idx_flops = 2.0 * rows * S * IDX_HEADS * IDX_DIM
    idx_bytes = B * S * IDX_BYTES                 # cache resident per GPU
    # long-context scoring GEMMs run near peak (S-wide contraction)
    t_indexer = max(idx_flops / (hw.peak_flops * 0.75),
                    idx_bytes / hw.hbm_bw) + hw.kernel_launch

    # --- Sparse MLA over top-K latents ------------------------------------
    K = min(TOPK_DSA, S)
    attn_flops = 2.0 * rows * N_HEADS * K * ((KV_LORA + QK_ROPE) + KV_LORA)
    attn_bytes = B * q * K * LATENT_BYTES
    t_attn = max(attn_flops / (hw.peak_flops * 0.60),
                 attn_bytes / hw.hbm_bw) + hw.kernel_launch
    hit_frac = 1.0 - miss_per_seq / K if sc.offload else 1.0
    t_attn0_frac = max(0.0, min(1.0, hit_frac))

    # --- FFN ---------------------------------------------------------------
    if moe_layer:
        experts_per_gpu = N_EXPERTS / sc.ep_size
        tokens_total = rows * sc.ep_size          # DP over EP group
        routed_rows = tokens_total * TOPK_EXP / N_EXPERTS  # rows per expert
        ffn_flops = (2 * 3 * rows * D_MODEL * D_EXPERT * TOPK_EXP   # routed
                     + 2 * 3 * rows * D_MODEL * D_EXPERT * N_SHARED)
        ffn_w = WEIGHT_BYTES * 3 * D_MODEL * D_EXPERT * (experts_per_gpu
                                                         + N_SHARED)
        t_ffn = _gemm_time(hw, routed_rows, ffn_flops, ffn_w)
        # EP all-to-all: fp8 dispatch + bf16 combine per (token, expert)
        a2a_bytes = rows * TOPK_EXP * D_MODEL * (1 + ACT_BYTES)
        t_a2a = a2a_bytes / hw.fabric_bw + hw.a2a_latency
    else:
        ffn_flops = 2 * 3 * rows * D_MODEL * D_FF_DENSE
        ffn_w = WEIGHT_BYTES * 3 * D_MODEL * D_FF_DENSE
        t_ffn = _gemm_time(hw, rows, ffn_flops, ffn_w)
        t_a2a = 0.0

    # --- Offload traffic ----------------------------------------------------
    if sc.offload:
        if sc.paged_host and sc.use_flashtrans:
            pm = paged_transfer_model(hw, sc.host_page_rows,
                                      sc.cache_bytes_per_row)
            # fetched misses are top-k scattered: one fragment per miss,
            # bounded by the pages a context spans
            frags = B * min(miss_per_seq,
                            math.ceil(sc.context / pm.page_rows))
            t_fetch = pm.h2d_time(B * miss_per_seq, frags)
            # writeback rows are consecutive: whole-page fragments
            wb_frags = B * math.ceil(q / pm.page_rows)
            t_writeback = pm.d2h_time(B * q, wb_frags)
        else:
            bw_h2d = hw.h2d_bw if sc.use_flashtrans else hw.h2d_naive_bw
            bw_d2h = hw.d2h_bw if sc.use_flashtrans else hw.d2h_naive_bw
            t_fetch = B * miss_per_seq * sc.cache_bytes_per_row / bw_h2d
            t_writeback = B * q * sc.cache_bytes_per_row / bw_d2h
    else:
        t_fetch = 0.0
        t_writeback = 0.0

    t_dba_overhead = 0.15 * t_indexer / 2 + 2 * hw.kernel_launch

    return LayerCosts(t_preattn, t_indexer, t_attn, t_attn0_frac, t_ffn,
                      t_a2a, t_fetch, t_writeback, t_dba_overhead)


def lm_head_time(hw: HardwareProfile, sc: ServeConfig) -> float:
    rows = sc.batch_per_gpu * sc.q_len
    flops = 2 * rows * D_MODEL * VOCAB
    return _gemm_time(hw, rows, flops, WEIGHT_BYTES * D_MODEL * VOCAB)


def weights_bytes_per_gpu(sc: ServeConfig) -> float:
    mla_idx = (D_MODEL * Q_LORA + Q_LORA * N_HEADS * (QK_NOPE + QK_ROPE)
               + D_MODEL * (KV_LORA + QK_ROPE)
               + KV_LORA * N_HEADS * (QK_NOPE + V_HEAD)
               + N_HEADS * V_HEAD * D_MODEL
               + D_MODEL * (IDX_HEADS * IDX_DIM + IDX_DIM + IDX_HEADS))
    dense = 3 * D_MODEL * D_FF_DENSE
    moe = 3 * D_MODEL * D_EXPERT * (N_EXPERTS / sc.ep_size + N_SHARED)
    total = (N_LAYERS * mla_idx + N_DENSE * dense
             + (N_LAYERS - N_DENSE) * moe + 2 * VOCAB * D_MODEL)
    return total * WEIGHT_BYTES


def cache_bytes_per_seq(context: int, sparse_ratio: float,
                        offload: bool) -> float:
    """Device-resident cache bytes per sequence per layer-stack."""
    latent_dev = context * (sparse_ratio if offload else 1.0) * LATENT_BYTES
    idx_dev = context * IDX_BYTES            # indexer cache never offloaded
    return N_LAYERS * (latent_dev + idx_dev)


def max_feasible_batch(hw: HardwareProfile, sc: ServeConfig,
                       activation_reserve: float = 4e9,
                       avg_fill: float = 0.43) -> int:
    """GPU-memory batch ceiling (paper §2.1).  ``avg_fill`` is the mean
    context occupancy across the continuous batch — inferred from the
    paper's own ceiling (52 sequences @32K on 80 GB with ~41 GB of weights
    implies ~43 % average fill; full-fill would cap at ~20)."""
    free = hw.hbm_bytes - weights_bytes_per_gpu(sc) - activation_reserve
    per_seq = cache_bytes_per_seq(sc.context, sc.sparse_memory_ratio,
                                  sc.offload) * avg_fill
    return max(1, int(free // per_seq))


def calibrate(hw: HardwareProfile, target_base: float = 9647.71,
              target_ess: float = 16347.88) -> HardwareProfile:
    """Fit the two MFU-curve params to the paper's Table 2 anchor rows
    (MTP=2, 32K: BS=52 baseline and BS=160 ratio-0.21 ESS row).

    This implements the paper's methodology: the simulator is anchored on
    measured metadata — here the published measurements themselves."""
    from repro.simulator.pipeline import simulate_step  # cycle-free at call

    def thr(hwx, bs, ratio, offload, miss):
        scx = ServeConfig(batch_per_gpu=bs, sparse_memory_ratio=ratio,
                          offload=offload, avg_miss_per_seq=miss)
        t = simulate_step(hwx, scx)
        return scx.gpus_per_node * bs * scx.accept_ratio / t

    best = None
    import numpy as np
    for eff_max in np.linspace(0.3, 0.9, 25):
        for rows_half in np.linspace(100, 3000, 60):
            hwx = dataclasses.replace(hw, mfu=MFUCurve(eff_max, rows_half))
            e1 = thr(hwx, 52, 1.0, False, 0.0) / target_base - 1.0
            e2 = thr(hwx, 160, 0.21, True, 128.0) / target_ess - 1.0
            err = e1 * e1 + e2 * e2
            if best is None or err < best[0]:
                best = (err, eff_max, rows_half)
    return dataclasses.replace(hw, mfu=MFUCurve(best[1], best[2]))
