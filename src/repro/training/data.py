"""Deterministic synthetic LM data pipeline.

Restart-safe by construction: batch ``i`` is a pure function of
``(seed, i)`` — a preempted job that restores step N resumes with exactly
the batch it would have seen, no iterator state to checkpoint.  Per-host
sharding takes ``host_id/num_hosts`` slices so every host touches only its
addressable part of the global batch (multi-pod data loading).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    # synthetic structure: zipf unigrams + copy spans (so loss can fall)
    zipf_a: float = 1.2
    copy_prob: float = 0.3


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


def make_batch(cfg: DataConfig, step: int, host_id: int = 0,
               num_hosts: int = 1) -> dict[str, jnp.ndarray]:
    """Batch for ``step`` (host slice): {"inputs","labels","positions"}."""
    b_local = cfg.global_batch // num_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_id]))
    probs = _zipf_probs(cfg.vocab_size, cfg.zipf_a)
    toks = rng.choice(cfg.vocab_size, size=(b_local, cfg.seq_len + 1),
                      p=probs).astype(np.int32)
    # inject copy spans: second half repeats a window from the first half
    # (gives the model learnable structure -> decreasing loss in examples)
    for i in range(b_local):
        if rng.random() < cfg.copy_prob:
            w = cfg.seq_len // 4
            src = rng.integers(0, cfg.seq_len // 2 - w)
            dst = rng.integers(cfg.seq_len // 2, cfg.seq_len + 1 - w)
            toks[i, dst:dst + w] = toks[i, src:src + w]
    pos = np.broadcast_to(np.arange(cfg.seq_len, dtype=np.int32),
                          (b_local, cfg.seq_len))
    return {"inputs": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "positions": jnp.asarray(pos)}
