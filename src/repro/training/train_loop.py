"""Fault-tolerant training loop.

Production behaviors, all exercised by tests/examples at laptop scale:

* resume-from-latest on start (crash/preemption restart),
* SIGTERM/SIGINT → finish the current step, save an emergency checkpoint,
  exit cleanly (preemption notice handling),
* periodic async checkpoints that never block the step,
* deterministic data (step-indexed) so a resumed run replays identically,
* a watchdog that flags straggling steps (>k× the trailing median) — at
  fleet scale this is where slow-host mitigation hooks in,
* NaN-loss circuit breaker (skip + count, abort past a budget).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, make_batch


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    nan_budget: int = 5


@dataclasses.dataclass
class LoopState:
    step: int = 0
    nan_count: int = 0
    straggler_events: int = 0
    stop_requested: bool = False


def train_loop(train_step: Callable, params: Any, opt_state: Any,
               data_cfg: DataConfig, loop: LoopConfig,
               *, log: Callable[[str], None] = print) -> tuple[Any, Any, LoopState]:
    """Run (or resume) training.  Returns final (params, opt_state, state)."""
    st = LoopState()
    saver = ckpt.AsyncSaver()

    # ---- resume ------------------------------------------------------------
    last = ckpt.latest_step(loop.ckpt_dir)
    if last is not None:
        restored = ckpt.restore(loop.ckpt_dir, last,
                                {"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        st.step = last
        log(f"[resume] restored step {last} from {loop.ckpt_dir}")

    # ---- preemption handling -----------------------------------------------
    def _on_term(signum, frame):
        st.stop_requested = True
        log(f"[signal] {signum}: will checkpoint and exit after this step")

    old_term = signal.signal(signal.SIGTERM, _on_term)

    durations: list[float] = []
    try:
        while st.step < loop.total_steps and not st.stop_requested:
            t0 = time.time()
            batch = make_batch(data_cfg, st.step)
            params, opt_state, metrics = train_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0

            if not np.isfinite(loss):
                st.nan_count += 1
                log(f"[warn] non-finite loss at step {st.step} "
                    f"({st.nan_count}/{loop.nan_budget})")
                if st.nan_count > loop.nan_budget:
                    raise FloatingPointError("nan budget exhausted")
            if durations and dt > loop.straggler_factor * np.median(durations):
                st.straggler_events += 1
                log(f"[straggler] step {st.step} took {dt:.2f}s "
                    f"(median {np.median(durations):.2f}s)")
            durations = (durations + [dt])[-32:]

            st.step += 1
            if st.step % loop.log_every == 0:
                log(f"step {st.step}: loss={loss:.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if st.step % loop.ckpt_every == 0:
                saver.save(loop.ckpt_dir, st.step,
                           {"params": params, "opt": opt_state}, loop.keep)
    finally:
        signal.signal(signal.SIGTERM, old_term)
        if st.stop_requested or st.step >= loop.total_steps:
            saver.wait()
            ckpt.save(loop.ckpt_dir, st.step,
                      {"params": params, "opt": opt_state}, keep=loop.keep)
            log(f"[ckpt] final checkpoint at step {st.step}")
        saver.wait()
    return params, opt_state, st
