"""AdamW + schedules, pure JAX (no optax).

Moments are fp32 regardless of param dtype; state shards exactly like the
parameters (the dry-run passes the same PartitionSpecs), giving ZeRO-style
fully-sharded optimizer state under the ``2d`` profile.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init_opt_state(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, (s + 1) / cfg.warmup_steps)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(math.pi * prog))
    return warm * jnp.where(s < cfg.warmup_steps, 1.0, cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: OptState
                 ) -> tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(new_m, new_v, step), \
        {"grad_norm": gnorm, "lr": lr}
