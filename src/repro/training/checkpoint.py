"""Sharded, atomic, elastic checkpointing (no orbax dependency).

Design for 1000+ node fleets:

* **per-leaf .npy shards + JSON manifest** — each host writes only its
  addressable shards; the manifest records the global shape/dtype and the
  logical PartitionSpec, so restore can *reshard* onto any mesh (elastic
  up/down-scaling after node loss).
* **atomic**: writes land in ``step_XXXX.tmp`` and are renamed only after
  the manifest fsyncs — a crash mid-save never corrupts the latest
  checkpoint.
* **async**: ``save_async`` snapshots to host RAM (device_get) and writes
  on a worker thread so the train loop keeps stepping.
* **integrity**: every shard records a crc32; restore verifies.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Callable

import jax
import numpy as np

SEP = "///"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(path: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest: dict[str, Any] = {"step": step, "leaves": {}}
    for key, leaf in _flatten(tree).items():
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{abs(zlib.crc32(key.encode())):08x}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(path, keep)
    return final


class AsyncSaver:
    """Snapshot-on-device-get + background write; at most one in flight."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, path: str, step: int, tree: Any, keep: int = 3) -> None:
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(path, step, snapshot), kwargs={"keep": keep},
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, step: int | None, like: Any,
            sharding_fn: Callable[[str, tuple], Any] | None = None) -> Any:
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs).

    ``sharding_fn(key, shape)`` may return a Sharding to place each leaf
    (elastic restore onto a different mesh); default: replicate/local.
    Verifies crc32 of every shard.
    """
    if step is None:
        step = latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathk, leaf in flat_like:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pathk)
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(d, meta["file"]))
        if (zlib.crc32(arr.tobytes()) & 0xFFFFFFFF) != meta["crc"]:
            raise IOError(f"checkpoint corruption in {key}")
        if sharding_fn is not None:
            sh = sharding_fn(key, arr.shape)
            arr = jax.device_put(arr, sh) if sh is not None else arr
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def _gc(path: str, keep: int) -> None:
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"), ignore_errors=True)
