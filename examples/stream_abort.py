"""Request-lifecycle front-end: interleaved streaming clients, a
mid-generation abort, a stop-sequence early exit, and priority-aware
admission — the `EssEngine` API surface that makes the paper's decoupled
batch-size scaling usable by real workloads.

Four clients share two decode slots of one ESS serve loop:

* **rid0 (streamer)** — consumed incrementally through the
  ``stream(rid)`` generator, token event by token event, until its
  ``finish_reason="length"`` terminal record;
* **rid1 (abort)** — a long request a client disconnects from after
  three tokens: ``abort(rid)`` returns its host pages to the allocator
  *immediately* (between two serve rounds), fully resets the slot, and
  closes the stream with ``finish_reason="abort"``;
* **rid2 (stop)** — carries ``stop_token_ids`` chosen from a probe run
  of the same prompt, so its stream ends early, exactly at the stop
  position, with ``finish_reason="stop"``;
* **rid3 (priority)** — a latecomer submitted mid-run (at the moment of
  the abort, with rid2 already waiting) but with ``priority=1``: when
  the abort frees a slot, it is admitted ahead of rid2 (queued long
  before it at priority 0) — stable FIFO holds within a class, higher
  classes go first.

    PYTHONPATH=src python examples/stream_abort.py
"""

import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.api import EssEngine, SamplingParams


def main() -> None:
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    NUM_SLOTS, SMAX = 2, 64

    # explicit token prompts (not rid-derived), so the probe run and the
    # interleaved run below produce identical streams per prompt
    prompt_stream = [int(t) for t in jax.random.randint(
        jax.random.key(11), (12,), 0, cfg.vocab_size)]
    prompt_abort = [int(t) for t in jax.random.randint(
        jax.random.key(12), (12,), 0, cfg.vocab_size)]
    prompt_stop = [int(t) for t in jax.random.randint(
        jax.random.key(13), (16,), 0, cfg.vocab_size)]
    prompt_prio = [int(t) for t in jax.random.randint(
        jax.random.key(14), (10,), 0, cfg.vocab_size)]

    # probe: what would the stop client emit unconstrained?  Pick a
    # mid-stream token that does not occur earlier as its stop sequence.
    probe = EssEngine(params, cfg, num_slots=NUM_SLOTS, max_seq=SMAX)
    [ref] = probe.generate([prompt_stop], SamplingParams(max_tokens=10))
    stop_idx, stop_tok = next(
        (i, t) for i, t in enumerate(ref.tokens)
        if i >= 2 and t not in ref.tokens[:i])
    print(f"probe stream {ref.tokens} -> stop token {stop_tok} "
          f"(position {stop_idx})")

    engine = EssEngine(params, cfg, num_slots=NUM_SLOTS, max_seq=SMAX)
    free0 = engine.session.allocator.free_pages
    r_stream = engine.submit(prompt_stream, SamplingParams(max_tokens=8))
    r_abort = engine.submit(prompt_abort, SamplingParams(max_tokens=32))
    r_stop = engine.submit(prompt_stop, SamplingParams(
        max_tokens=10, stop_token_ids=(stop_tok,)))

    # client 1: consume rid0 incrementally; disconnect rid1 after 3
    # tokens and, at that same moment, submit a priority-1 latecomer —
    # it will take the freed slot ahead of rid2, which queued first
    aborted = False
    r_prio = None
    print(f"\nstreaming rid{r_stream}:")
    for ev in engine.stream(r_stream):
        if ev.token is not None:
            print(f"  rid{ev.rid} token[{ev.index}] = {ev.token}")
        else:
            print(f"  rid{ev.rid} terminal: {ev.finish_reason}")
        if not aborted and \
                len(engine.session.outputs.get(r_abort, [])) >= 3:
            print(f"  -> client disconnect: abort(rid{r_abort})")
            assert engine.abort(r_abort)
            r_prio = engine.submit(prompt_prio,
                                   SamplingParams(max_tokens=4, priority=1))
            print(f"  -> late submit rid{r_prio} at priority 1 "
                  f"(rid{r_stop} has been waiting at priority 0)")
            aborted = True

    # drain the remaining clients (stop + priority requests)
    while engine.has_work():
        engine.step()
    outs = {r: engine.output(r)
            for r in (r_stream, r_abort, r_stop, r_prio)}

    print("\nfinal outputs:")
    for r, o in sorted(outs.items()):
        print(f"  rid{r}: {o.finish_reason:8s} {o.tokens}")
    m = engine.metrics()
    print(f"metrics: aborted={m['aborted']} "
          f"finish_reasons={m['finish_reasons']} "
          f"ttft_p50={m['ttft_p50_s']:.3f}s itl_p50={m['itl_p50_s']:.4f}s")

    assert outs[r_stream].finish_reason == "length" \
        and outs[r_stream].n_generated == 8
    assert outs[r_abort].finish_reason == "abort" and aborted
    assert 3 <= outs[r_abort].n_generated < 32     # cut mid-generation
    # stop stream == unconstrained probe cut exactly at the stop position
    assert outs[r_stop].finish_reason == "stop"
    assert outs[r_stop].tokens == ref.tokens[:stop_idx + 1]
    # the priority-1 latecomer was admitted before the priority-0 rid2
    assert engine.session.report.ttft_rounds[r_prio] \
        < engine.session.report.ttft_rounds[r_stop]
    # the abort reclaimed its host pages immediately; all pages free now
    assert engine.session.allocator.free_pages == free0
    print("\nlifecycle OK: stream / abort / stop / priority all verified")


if __name__ == "__main__":
    main()
