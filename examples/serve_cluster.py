"""PD-disaggregated serving: one prefill worker, two decode workers.

The paper's Figure 3 separates prefill and decode into distinct node
pools joined by a "Load" arrow — a prompt prefills on a bandwidth-rich
worker, then its latent state migrates (page-granular, storage dtype on
the wire) to a decode worker that owns the rest of its lifetime.  This
example drives that topology through ``EssCluster``, the multi-node
drop-in for ``EssEngine``, and shows:

* **bitwise parity** — the clustered streams match a single engine's
  exactly, including a seeded sampling request (the packet carries
  pages, scale planes, indexer keys, first token and MTP hidden, so the
  decode worker reproduces the single-node math bit for bit);
* **the handoff itself** — migration packets crossing a simulated
  inter-node channel with a cost-model-derived delay, and the byte
  accounting of what travelled;
* **slot recycling** — the prefill worker's slots free at pack time,
  not at request completion: prefill capacity is never held hostage by
  decode lifetimes;
* **routing** — the router placing each migration on the decode worker
  with the most free host bytes, so load spreads without rejections.

    PYTHONPATH=src python examples/serve_cluster.py
"""

import sys

sys.path.insert(0, "src")

import jax

from repro.cluster import EssCluster, InterNodeChannel
from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.api import EssEngine, SamplingParams
from repro.simulator.costmodel import internode_model
from repro.simulator.hardware import H800_EP32


def main() -> None:
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    SMAX = 64
    prompts = [14, 10, 12, 9, 11]
    sp = [SamplingParams(max_tokens=6),
          SamplingParams(max_tokens=5),
          SamplingParams(max_tokens=4, temperature=0.9, seed=7),
          SamplingParams(max_tokens=5),
          SamplingParams(max_tokens=6)]

    print("-- single-engine reference --")
    eng = EssEngine(params, cfg, num_slots=2, max_seq=SMAX)
    ref = eng.generate(prompts, sp, max_rounds=300)
    for o in ref:
        print(f"  rid{o.rid}: {o.tokens} ({o.finish_reason})")

    print("\n-- 1 prefill + 2 decode workers, cost-model channel --")
    # the channel's delay comes from the calibrated H800 fabric model:
    # latency + wire_bytes / bandwidth, quantized to serve steps
    channel = InterNodeChannel(model=internode_model(H800_EP32),
                               step_time_s=5e-3)
    clu = EssCluster(params, cfg, num_prefill=1, num_decode=2,
                     num_slots=2, max_seq=SMAX, channel=channel)
    outs = clu.generate(prompts, sp, max_rounds=300)
    for o in outs:
        print(f"  rid{o.rid}: {o.tokens} ({o.finish_reason})")

    assert [(o.tokens, o.finish_reason) for o in outs] \
        == [(o.tokens, o.finish_reason) for o in ref], \
        "clustered streams must match the single engine bitwise"
    print("\nstreams bitwise identical across the PD handoff "
          "(incl. the seeded sampling request)")

    m = clu.metrics()
    print(f"\nmigrations: {m['migrations']} packed, {m['installed']} "
          f"installed; wire: {m['wire_bytes']} B, "
          f"{m['sim_transfer_s']*1e3:.2f} ms simulated transfer")
    print(f"decode tokens per worker: "
          f"{[w.session.report.decode_tokens for w in clu.decode]} "
          f"(router spread by free host bytes)")
    pre = clu.prefill[0].session
    print(f"prefill worker: {pre.report.prefill_chunks} chunks, "
          f"{pre.report.prefill_tokens} prompt tokens, all "
          f"{pre.allocator.free_pages}/{pre.allocator.num_pages} host "
          f"pages free again — slots recycled at pack time")
    assert m["migrations"] == len(prompts) == m["installed"]
    assert m["rejected"] == 0
    print("\nok")


if __name__ == "__main__":
    main()
