"""ESS serving with continuous batching + MTP speculative decode.

Drives the offload-centric engine through the scheduler: requests arrive,
prefill with LRU-Warmup, decode rounds emit tokens (optionally MTP
speculative), finished sequences leave and new ones take their slots —
with a mid-run preemption to demonstrate the recovery path.

    PYTHONPATH=src python examples/serve_ess.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving.sampling import greedy
from repro.serving.scheduler import Request, Scheduler


def main() -> None:
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, SMAX, PROMPT = 2, 96, 24

    sched = Scheduler(num_slots=B, max_seq=SMAX)
    for rid in range(4):
        sched.submit(Request(rid=rid, prompt_len=PROMPT, max_new_tokens=6))

    # one shared decode batch: slot i <-> batch row i
    toks = jax.random.randint(jax.random.key(1), (B, PROMPT), 0,
                              cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(PROMPT)[None], (B, PROMPT))
    admitted = sched.admit()
    print(f"admitted: {[r.rid for _, r in admitted]}")
    logits, caches = E.ess_prefill(params, cfg, toks, pos, SMAX)
    tok = greedy(logits[:, -1])

    rounds = 0
    while sched.running or sched.queue:
        out = E.ess_decode(params, cfg, tok[:, None], caches.lens[:, None],
                           caches)
        caches = out.caches
        tok = greedy(out.logits[:, -1])
        done = sched.record_tokens({i: 1 for i in sched.active_slots()})
        for req in done:
            print(f"  round {rounds}: request {req.rid} finished "
                  f"({req.generated} tokens)")
        if rounds == 2 and sched.slots[1].active:
            print("  round 2: PREEMPTING slot 1 (simulated node loss)")
            sched.preempt(1)
            # its cache rows are reset on re-admission (re-prefill)
            caches = caches._replace(
                lens=caches.lens.at[1].set(0))
        newly = sched.admit()
        for slot, req in newly:
            print(f"  round {rounds}: request {req.rid} -> slot {slot} "
                  f"(preempted {req.preempted_count}x), re-prefilling")
            ntoks = jax.random.randint(jax.random.key(10 + req.rid),
                                       (1, PROMPT), 0, cfg.vocab_size)
            lg1, c1 = E.ess_prefill(params, cfg, ntoks, pos[:1], SMAX,
                                    do_warmup=False)
            # graft the fresh sequence into the shared batch state
            caches = caches._replace(
                lens=caches.lens.at[slot].set(int(c1.lens[0])),
                host_latent=caches.host_latent.at[:, slot].set(
                    c1.host_latent[:, 0]),
                ikeys=tuple(full.at[slot].set(one[0]) for full, one in
                            zip(caches.ikeys, c1.ikeys)),
                pools=tuple(jax.tree.map(
                    lambda f, o: f.at[slot].set(o[0]) if f.ndim > 0 else f,
                    fp, op) for fp, op in zip(caches.pools, c1.pools)))
            tok = tok.at[slot].set(greedy(lg1[:, -1])[0])
        rounds += 1
        if rounds > 40:
            break
    print(f"\nall requests served in {rounds} decode rounds; "
          f"finished: {[r.rid for r in sched.finished]}")


if __name__ == "__main__":
    main()
