"""ESS serving through the public `EssEngine` API with continuous
batching over the paged host latent-cache.

Drives ``repro.serving.api.EssEngine`` — the request-lifecycle front-end
over the re-entrant serve-round core: more requests than decode slots
stream through one long-lived decode batch; admission is gated on free
host pages (the pool is provisioned *below* the dense layout's
``slots x blocks`` pin, so the gate actually engages); a mid-run
preemption demonstrates the recovery path — pages return to the
allocator and the slot gets a full cache reset before its next
occupant, while the preempted request replays its identical stream.

Prefill is **chunked and decode-interleaved**: each serve round runs one
``prefill_chunk``-token chunk for at most one admitting slot, scattered
straight into its mapped host pages, while every running slot keeps
decoding — watch rid=4's long prompt stream in between other requests'
token events.

Decode runs **MTP speculative rounds** (depth 2) composed with
**Two-Batch Overlap**: every round drafts 2 tokens per slot, verifies all
drafts with one Q=3 step split into two overlapped half-batches, and
emits 1–3 accepted tokens per slot; rid=3 samples (temperature 0.8) via
``SamplingParams`` and transparently degrades to exact Q=1 emission
inside the same rounds.

Every round runs as a **donated compiled StepProgram** over the
device-resident engine state — pass ``compiled=False`` to ``EssEngine``
for the op-by-op debugging path; the emitted streams are identical
either way.  (See ``examples/stream_abort.py`` for the incremental
``stream()`` / ``abort()`` / stop-token side of the API.)

With ``overlap=True`` the rounds run as the **plan/compute/commit
pipeline**: each round's indexer scores drive a speculative H2D stage
into a double-buffered slab, so most of the next round's misses arrive
pre-staged (watch the prefetch hit/miss/wasted counters in the report
line) while the residual misses fall back to the synchronous gather —
the emitted streams are bitwise identical to the synchronous path.

    PYTHONPATH=src python examples/serve_ess.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.cache import latent_cache as LC
from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.api import EssEngine, SamplingParams


def main() -> None:
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    cfg = dataclasses.replace(cfg, mtp_depth=2)    # 2 stacked draft modules
    params = init_params(jax.random.key(0), T.model_def(cfg))
    NUM_SLOTS, SMAX = 2, 96

    # >= 2x num_slots requests stream through the two decode slots; the
    # later, longer requests pin 3 pages each so a freed slot has to *wait*
    # for pages — the admission gate in action.  rid=4's long prompt
    # streams through several prefill chunks while the others decode.
    workload = [(24, SamplingParams(max_tokens=6)),
                (24, SamplingParams(max_tokens=6)),
                (40, SamplingParams(max_tokens=8)),
                (40, SamplingParams(max_tokens=8, temperature=0.8,
                                    top_k=64, seed=7)),
                (72, SamplingParams(max_tokens=8))]

    # page budget far below the dense pin (2 slots x 6 blocks = 12 pages
    # would be capacity parity at page_rows=16)
    num_pages = 7
    per_req = [LC.pages_for_len(cfg, plen + sp.max_tokens)
               for plen, sp in workload]
    print(f"slots={NUM_SLOTS} pages={num_pages} (per request: {per_req}, "
          f"page_rows={cfg.ess.host_page_rows})")

    engine = EssEngine(params, cfg, num_slots=NUM_SLOTS, max_seq=SMAX,
                       num_host_pages=num_pages, prefill_chunk=16,
                       mtp_depth=2, tbo=True, overlap=True)
    rids = [engine.submit(plen, sp) for plen, sp in workload]

    # drive serve rounds by hand (generate() would do the same loop);
    # at round 2 preempt slot 1 — a simulated node loss on the session
    # underneath the API.  The victim requeues ahead of its priority
    # class and replays its stream on re-admission.
    rnd = 0
    while engine.has_work():
        engine.step()
        if rnd == 2 and engine.session.sched.slots[1].active:
            print("  round 2: PREEMPTING slot 1 (simulated node loss)")
            engine.session.preempt(1)
        rnd += 1
        assert rnd < 300, "serve loop failed to converge"
    outs = [engine.output(r) for r in rids]

    report = engine.session.report
    for ev in report.events:
        print(f"  {ev}")
    print(f"\nall requests served in {report.rounds} decode rounds "
          f"({report.spec_rounds} speculative); finish reasons: "
          f"{[o.finish_reason for o in outs]}")
    print(f"decode tokens: {report.decode_tokens} "
          f"({report.tokens_per_s:.1f} accepted-tok/s, "
          f"{report.rounds_per_s:.1f} rounds/s); "
          f"accept rate {report.accept_rate:.2f} "
          f"({report.accepted_tokens}/{report.drafted_tokens} drafts); "
          f"prefill: {report.prefill_tokens} toks in "
          f"{report.prefill_chunks} chunks; "
          f"admissions blocked on pages: "
          f"{engine.session.sched.blocked_admissions}; "
          f"peak pages in use: {report.peak_pages_in_use}/{report.num_pages}")
    print(f"async-offload pipeline: prefetch hits/misses/wasted rows "
          f"{report.prefetch_hits}/{report.prefetch_misses}/"
          f"{report.prefetch_wasted_rows} "
          f"(hit rate {report.prefetch_hit_rate:.2f})")
    print("ttft (serve rounds from submit to first token): "
          + ", ".join(f"rid{r}={t}" for r, t in
                      sorted(report.ttft_rounds.items())))
    for o in outs:
        print(f"  rid{o.rid} tokens: {o.tokens}")
    assert all(o.finish_reason == "length" for o in outs)
    assert engine.session.sched.blocked_admissions > 0, \
        "page gate never engaged"
    assert report.prefill_chunks > len(workload), "chunking never engaged"
    assert report.spec_rounds > 0, "speculative rounds never engaged"
    assert all(o.n_generated == sp.max_tokens
               for o, (_, sp) in zip(outs, workload))


if __name__ == "__main__":
    main()
