"""ESS serving with continuous batching over the paged host latent-cache.

Drives ``repro.serving.engine.ServeSession``: more requests than decode
slots stream through one long-lived decode batch; admission is gated on
free host pages (the pool is provisioned *below* the dense layout's
``slots x blocks`` pin, so the gate actually engages); a mid-run preemption
demonstrates the recovery path — pages return to the allocator and the slot
gets a full cache reset before its next occupant.

Prefill is **chunked and decode-interleaved**: each serve round runs one
``prefill_chunk``-token chunk for at most one admitting slot, scattered
straight into its mapped host pages, while every running slot keeps
decoding — watch rid=4's long prompt stream in between other requests'
token events.

Decode runs **MTP speculative rounds** (depth 2) composed with
**Two-Batch Overlap**: every round drafts 2 tokens per slot, verifies all
drafts with one Q=3 step split into two overlapped half-batches, and
emits 1–3 accepted tokens per slot; rid=3 samples (temperature 0.8) and
transparently degrades to exact Q=1 emission inside the same rounds.

Every round runs as a **donated compiled StepProgram** over the
device-resident engine state (draft + verify + accept/rollback + token
selection fused under one jit, one packed host fetch per round) — pass
``compiled=False`` to ``ServeSession`` for the op-by-op debugging path;
the emitted streams are identical either way.

    PYTHONPATH=src python examples/serve_ess.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.cache import latent_cache as LC
from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving.scheduler import Request


def main() -> None:
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    cfg = dataclasses.replace(cfg, mtp_depth=2)    # 2 stacked draft modules
    params = init_params(jax.random.key(0), T.model_def(cfg))
    NUM_SLOTS, SMAX = 2, 96

    # >= 2x num_slots requests stream through the two decode slots; the
    # later, longer requests pin 3 pages each so a freed slot has to *wait*
    # for pages — the admission gate in action.  rid=4's long prompt
    # streams through several prefill chunks while the others decode.
    requests = [Request(rid=0, prompt_len=24, max_new_tokens=6),
                Request(rid=1, prompt_len=24, max_new_tokens=6),
                Request(rid=2, prompt_len=40, max_new_tokens=8),
                Request(rid=3, prompt_len=40, max_new_tokens=8,
                        temperature=0.8, top_k=64, seed=7),
                Request(rid=4, prompt_len=72, max_new_tokens=8)]

    # page budget far below the dense pin (2 slots x 6 blocks = 12 pages
    # would be capacity parity at page_rows=16)
    num_pages = 7
    per_req = [LC.pages_for_len(cfg, r.prompt_len + r.max_new_tokens)
               for r in requests]
    print(f"slots={NUM_SLOTS} pages={num_pages} (per request: {per_req}, "
          f"page_rows={cfg.ess.host_page_rows})")

    session = E.ServeSession(params, cfg, num_slots=NUM_SLOTS, max_seq=SMAX,
                             num_host_pages=num_pages, prefill_chunk=16,
                             mtp_depth=2, tbo=True)

    def on_round(s: E.ServeSession, rnd: int) -> None:
        if rnd == 2 and s.sched.slots[1].active:
            print("  round 2: PREEMPTING slot 1 (simulated node loss)")
            s.preempt(1)

    report = session.run(requests, on_round=on_round)
    for ev in report.events:
        print(f"  {ev}")
    print(f"\nall requests served in {report.rounds} decode rounds "
          f"({report.spec_rounds} speculative); "
          f"finished: {sorted(report.finished_rids)}")
    print(f"decode tokens: {report.decode_tokens} "
          f"({report.tokens_per_s:.1f} accepted-tok/s, "
          f"{report.rounds_per_s:.1f} rounds/s); "
          f"accept rate {report.accept_rate:.2f} "
          f"({report.accepted_tokens}/{report.drafted_tokens} drafts); "
          f"prefill: {report.prefill_tokens} toks in "
          f"{report.prefill_chunks} chunks; "
          f"admissions blocked on pages: {report.admissions_blocked}; "
          f"peak pages in use: {report.peak_pages_in_use}/{report.num_pages}")
    print("ttft (serve rounds from submit to first token): "
          + ", ".join(f"rid{r}={t}" for r, t in
                      sorted(report.ttft_rounds.items())))
    for rid in sorted(session.outputs):
        print(f"  rid{rid} tokens: {session.outputs[rid]}")
    assert sorted(report.finished_rids) == [r.rid for r in requests]
    assert report.admissions_blocked > 0, "page gate never engaged"
    assert report.prefill_chunks > len(requests), "chunking never engaged"
    assert report.spec_rounds > 0, "speculative rounds never engaged"
    assert all(len(session.outputs[r.rid]) == r.max_new_tokens
               for r in requests)


if __name__ == "__main__":
    main()
