"""End-to-end training driver: a ~100M-param qwen3-family model trained for
a few hundred steps on the synthetic copy-structure corpus, with the full
fault-tolerance stack (async checkpoints, resume, straggler watchdog).

    PYTHONPATH=src python examples/train_small.py          # ~100M, 200 steps
    PYTHONPATH=src python examples/train_small.py --tiny   # smoke scale
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models.params import count_params, init_params
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import LoopConfig, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    if args.tiny:
        cfg = get_config("qwen3-0.6b-smoke", param_dtype=jnp.float32)
        steps, batch, seq = args.steps or 60, 8, 64
    else:
        # ~100M-param member of the qwen3 family (same code path as 0.6B)
        cfg = get_config("qwen3-0.6b", param_dtype=jnp.float32,
                         num_layers=8, d_model=512, num_heads=8,
                         num_kv_heads=4, head_dim=64, d_ff=1536,
                         vocab_size=32000)
        steps, batch, seq = args.steps or 200, 16, 256

    n = count_params(T.model_def(cfg))
    print(f"training {cfg.name}: {n/1e6:.1f}M params, {steps} steps, "
          f"batch {batch} x seq {seq}")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    opt_cfg = AdamWConfig(lr=6e-4, total_steps=steps,
                          warmup_steps=max(5, steps // 20))
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=batch,
                      seq_len=seq, copy_prob=0.5)
    loop = LoopConfig(total_steps=steps, ckpt_every=max(50, steps // 4),
                      ckpt_dir=args.ckpt_dir, log_every=10)
    _, _, st = train_loop(step, params, init_opt_state(params), data, loop)
    print(f"done at step {st.step} (stragglers flagged: "
          f"{st.straggler_events}, nan events: {st.nan_count})")


if __name__ == "__main__":
    main()
