"""Reproduce the paper's headline numbers with the high-fidelity simulator.

Prints Table 2 (all 18 rows, simulated vs published), the two abstract
claims (+69.4 % @32K, +123 % @128K), and the figure-level behaviours
(warmup, overlap crossover, miss scaling).

    PYTHONPATH=src python examples/simulate_paper.py
"""

import sys

sys.path.insert(0, "src")

from repro.simulator import experiments as E


def main() -> None:
    print("=== Table 2: throughput & OTPS (sim vs paper) ===")
    print(f"{'mtp':>3} {'acc':>4} {'ctx':>6} {'BS':>4} {'ratio':>5} "
          f"{'sim thr':>9} {'paper':>9} {'dev':>6}")
    for r in E.table2():
        print(f"{r['mtp']:>3} {r['accept']:>4} {r['context']:>6} "
              f"{r['batch']:>4} {r['ratio']:>5} "
              f"{r['sim_throughput']:>9.0f} {r['paper_throughput']:>9.0f} "
              f"{r['dev_pct']:>5.1f}%")

    h = E.headline_improvements()
    print(f"\n32K improvement: +{h['improvement_32k_pct']:.1f}% "
          f"(paper +{h['paper_32k_pct']})")
    print(f"128K improvement: +{h['improvement_128k_pct']:.1f}% "
          f"(paper +{h['paper_128k_pct']})")

    print("\n=== Fig 4: LRU-Warmup ===")
    w = E.fig4_warmup(steps=16)
    print(" cold:", w["before_warmup"][:8])
    print(" warm:", w["after_warmup"][:8])

    print("\n=== Fig 7: overlap strategies (per-layer ms vs miss count) ===")
    for r in E.fig7_overlap_comparison():
        print(f" miss={r['miss']:>5}: none={r['none_ms']:.3f} "
              f"da={r['da_ms']:.3f} dba={r['dba_ms']:.3f}")

    print("\n=== §2.1 memory wall ===")
    print(" ", E.memory_analysis())
    print("\n=== §3.1 FlashTrans ===")
    print(" ", E.flashtrans_comparison())


if __name__ == "__main__":
    main()
