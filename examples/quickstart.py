"""Quickstart: the ESS pipeline end-to-end on CPU in ~2 minutes.

Builds the smoke-scale DeepSeek-V3.2-Exp (DSA + MLA + MoE + ESS), prefills
a prompt with LRU-Warmup, decodes greedily through the offload-centric
engine, and shows that (a) outputs match the monolithic model exactly and
(b) the Sparse Memory Pool's miss counts collapse after the first steps —
the temporal locality the whole paper rests on.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import count_params, init_params
from repro.serving import engine as E
from repro.serving.sampling import greedy


def main() -> None:
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    cfg = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    print(f"model: {cfg.name} — {count_params(T.model_def(cfg))/1e6:.2f}M "
          f"params, {cfg.num_layers} layers, DSA top-{cfg.dsa.index_topk}, "
          f"pool ratio {cfg.ess.sparse_memory_ratio}")
    params = init_params(jax.random.key(0), T.model_def(cfg))

    B, S, SMAX, NEW = 2, 24, 64, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    print("\n-- prefill (exactness demo uses the cold pool; warmup shown "
          "below) --")
    logits, caches = E.ess_prefill(params, cfg, toks, pos, SMAX,
                                   do_warmup=False)
    tok = greedy(logits[:, -1])

    # monolithic reference for the same continuation
    pf = T.forward(params, cfg, toks, pos, mode="prefill")
    cm = pf.caches
    cm["mla"] = jax.tree.map(
        lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, SMAX - S), (0, 0))),
        cm["mla"])
    tok_m = greedy(pf.logits[:, -1])

    print("\n-- ESS decode (fetch ∥ Attn0 → Attn1 → exact merge) --")
    same = True
    for step in range(NEW):
        out = E.ess_decode(params, cfg, tok[:, None], caches.lens[:, None],
                           caches)
        caches = out.caches
        tok = greedy(out.logits[:, -1])
        om = T.forward(params, cfg, tok_m[:, None], cm["lens"][:, None],
                       mode="decode", caches=cm)
        cm = om.caches
        tok_m = greedy(om.logits[:, -1])
        same &= bool((np.array(tok) == np.array(tok_m)).all())
        miss = np.array(out.stats["misses"])
        hits = np.array(out.stats["hits"])
        print(f"  step {step}: tokens={np.array(tok)} pool misses/seq={miss}"
              f" hits/seq={hits}")
    print(f"\nESS continuation == monolithic continuation: {same}")
    assert same

    print("\n-- LRU-Warmup effect (paper Fig. 4) --")
    _, cold = E.ess_prefill(params, cfg, toks, pos, SMAX, do_warmup=False)
    _, warm = E.ess_prefill(params, cfg, toks, pos, SMAX, do_warmup=True)
    nxt = greedy(logits[:, -1])
    oc = E.ess_decode(params, cfg, nxt[:, None], cold.lens[:, None], cold)
    ow = E.ess_decode(params, cfg, nxt[:, None], warm.lens[:, None], warm)
    print(f"  first-step misses/seq  cold pool: {np.array(oc.stats['misses'])}"
          f"  warmed pool: {np.array(ow.stats['misses'])}")


if __name__ == "__main__":
    main()
