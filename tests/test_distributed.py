"""Multi-device execution tests for the distributed substrate.

These run in a subprocess with 8 forced host devices (the main test
process must keep the default single device — see conftest.py).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_parallel_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4, 2), ("pod", "model"))
        L, B, D = 8, 8, 16
        w = jax.random.normal(jax.random.key(0), (L, D, D)) * 0.3
        x = jax.random.normal(jax.random.key(1), (B, D))

        def layer(lw, h):
            return jnp.tanh(h @ lw)

        ref = x
        for i in range(L):
            ref = layer(w[i], ref)
        got = pipeline_apply(layer, w, x, mesh, axis="pod", microbatches=4)
        np.testing.assert_allclose(np.array(got), np.array(ref),
                                   rtol=1e-4, atol=1e-5)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_sharded_flash_decode_matches_oracle():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import sharded_flash_decode
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        B, H, S, D = 2, 4, 64, 16
        q = jax.random.normal(jax.random.key(0), (B, H, D))
        k = jax.random.normal(jax.random.key(1), (B, S, D))
        v = jax.random.normal(jax.random.key(2), (B, S, D))
        valid = jnp.arange(S)[None] < jnp.array([64, 40])[:, None]
        got = sharded_flash_decode(mesh, "data", q, k, v, valid, 0.25)
        s = jnp.einsum("bhd,bsd->bhs", q, k) * 0.25
        s = jnp.where(valid[:, None], s, -2e38)
        w = jax.nn.softmax(s, -1)
        ref = jnp.einsum("bhs,bsd->bhd", w, v)
        np.testing.assert_allclose(np.array(got), np.array(ref),
                                   rtol=1e-5, atol=1e-5)
        print("FLASH_OK")
    """)
    assert "FLASH_OK" in out


def test_dryrun_entrypoint_small_cell():
    """The dry-run CLI itself (with its own 512-device env) stays green."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["REPRO_XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-0.6b",
         "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, timeout=580)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "1 ok, 0 skipped, 0 errors" in out.stdout


def test_compression_under_psum():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import (compress_grads,
                                                   decompress_grads, init_ef)
        from repro.distributed.sharding import shard_map_compat
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        g = {"w": jax.random.normal(jax.random.key(0), (8, 64))}

        def allreduce_compressed(gs):
            # per-shard quantize -> dequantized mean across shards
            q, s, _ = compress_grads(gs, init_ef(gs))
            deq = decompress_grads(q, s)
            return jax.tree.map(lambda x: jax.lax.pmean(x, "data"), deq)

        fn = shard_map_compat(allreduce_compressed, mesh=mesh,
                              in_specs=({"w": P("data")},),
                              out_specs={"w": P("data")})
        got = fn(g)
        # reference: the true mean across shards (rows), tiled back
        ref = jnp.broadcast_to(jnp.mean(g["w"], axis=0, keepdims=True),
                               g["w"].shape)
        np.testing.assert_allclose(np.array(got["w"]), np.array(ref),
                                   atol=0.02)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


# ---------------------------------------------------------------------------
# Reference-quantizer edge cases (single process — quantize_rows is the
# cache tier's reference quantizer, so its corners are contract surface)
# ---------------------------------------------------------------------------

def _cmp():
    import jax  # noqa: F401  (keeps the lazy import pattern of this file)
    from repro.distributed import compression as cmp
    return cmp


def test_quantize_rows_all_zero_page_roundtrips_exactly():
    import jax.numpy as jnp
    import numpy as np
    cmp = _cmp()
    x = jnp.zeros((2, 4, 8), jnp.bfloat16)       # an all-zero host page
    for dt in cmp.CACHE_QUANT_DTYPES.values():
        q, s = cmp.quantize_rows(x, dt)
        assert s.shape == (2, 4, 1) and s.dtype == cmp.SCALE_DTYPE
        np.testing.assert_array_equal(np.array(q, np.int32), 0)
        np.testing.assert_array_equal(np.array(s, np.float32), 0.0)
        deq = cmp.dequantize_rows(q, s, jnp.bfloat16)
        np.testing.assert_array_equal(np.array(deq, np.float32), 0.0)


def test_quantize_rows_sentinel_rows_keep_zero_scale():
    # zero rows *inside* a page of live rows stay exactly zero — the
    # paged tier's unwritten/sentinel rows must survive the round trip
    import jax.numpy as jnp
    import numpy as np
    cmp = _cmp()
    x = jnp.stack([jnp.zeros((8,)), jnp.full((8,), 3.0),
                   jnp.zeros((8,))]).astype(jnp.bfloat16)
    q, s = cmp.quantize_rows(x, jnp.int8)
    sf = np.array(s, np.float32).ravel()
    assert sf[0] == 0.0 and sf[2] == 0.0 and sf[1] > 0.0
    deq = np.array(cmp.dequantize_rows(q, s, jnp.bfloat16), np.float32)
    np.testing.assert_array_equal(deq[0], 0.0)
    np.testing.assert_array_equal(deq[2], 0.0)
    np.testing.assert_allclose(deq[1], 3.0, rtol=2e-2)


def test_quantize_rows_max_magnitude_clips_not_wraps():
    # the f16-rounded stored scale can land *below* amax/qmax; the
    # payload must clip to the dtype's max magnitude, never overflow
    import jax.numpy as jnp
    import numpy as np
    cmp = _cmp()
    x = jnp.array([[1000.0, -1000.0, 999.9, 0.25]], jnp.float32)
    for name, dt in cmp.CACHE_QUANT_DTYPES.items():
        q, s = cmp.quantize_rows(x, dt)
        qf = np.array(q, np.float32)
        m = cmp.quant_max(dt)
        assert np.abs(qf).max() <= m
        assert qf[0, 0] == m and qf[0, 1] == -m          # amax hits the rail
        deq = np.array(cmp.dequantize_rows(q, s, jnp.float32))
        np.testing.assert_allclose(deq[0, :2], [1000.0, -1000.0],
                                   rtol=1e-2)
        # small elements keep their sign and scale-bounded error
        assert abs(deq[0, 3] - 0.25) <= np.array(s, np.float32)[0, 0]


def test_quantize_rows_negative_only_rows():
    # amax from a negative extremum: symmetric quantization must not
    # bias the sign or saturate one-sided
    import jax
    import jax.numpy as jnp
    import numpy as np
    cmp = _cmp()
    x = -jnp.abs(jax.random.normal(jax.random.key(3), (5, 16),
                                   jnp.float32)) - 0.1
    q, s = cmp.quantize_rows(x.astype(jnp.bfloat16), jnp.int8)
    deq = np.array(cmp.dequantize_rows(q, s, jnp.float32))
    assert (deq <= 0).all()
    err = np.abs(deq - np.array(x, np.float32))
    bound = np.array(s, np.float32) * 0.5 + np.abs(np.array(x)) * 0.01
    assert (err <= bound).all()
