"""Multi-device execution tests for the distributed substrate.

These run in a subprocess with 8 forced host devices (the main test
process must keep the default single device — see conftest.py).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_parallel_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4, 2), ("pod", "model"))
        L, B, D = 8, 8, 16
        w = jax.random.normal(jax.random.key(0), (L, D, D)) * 0.3
        x = jax.random.normal(jax.random.key(1), (B, D))

        def layer(lw, h):
            return jnp.tanh(h @ lw)

        ref = x
        for i in range(L):
            ref = layer(w[i], ref)
        got = pipeline_apply(layer, w, x, mesh, axis="pod", microbatches=4)
        np.testing.assert_allclose(np.array(got), np.array(ref),
                                   rtol=1e-4, atol=1e-5)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_sharded_flash_decode_matches_oracle():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import sharded_flash_decode
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        B, H, S, D = 2, 4, 64, 16
        q = jax.random.normal(jax.random.key(0), (B, H, D))
        k = jax.random.normal(jax.random.key(1), (B, S, D))
        v = jax.random.normal(jax.random.key(2), (B, S, D))
        valid = jnp.arange(S)[None] < jnp.array([64, 40])[:, None]
        got = sharded_flash_decode(mesh, "data", q, k, v, valid, 0.25)
        s = jnp.einsum("bhd,bsd->bhs", q, k) * 0.25
        s = jnp.where(valid[:, None], s, -2e38)
        w = jax.nn.softmax(s, -1)
        ref = jnp.einsum("bhs,bsd->bhd", w, v)
        np.testing.assert_allclose(np.array(got), np.array(ref),
                                   rtol=1e-5, atol=1e-5)
        print("FLASH_OK")
    """)
    assert "FLASH_OK" in out


def test_dryrun_entrypoint_small_cell():
    """The dry-run CLI itself (with its own 512-device env) stays green."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["REPRO_XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-0.6b",
         "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, timeout=580)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "1 ok, 0 skipped, 0 errors" in out.stdout


def test_compression_under_psum():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import (compress_grads,
                                                   decompress_grads, init_ef)
        from repro.distributed.sharding import shard_map_compat
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        g = {"w": jax.random.normal(jax.random.key(0), (8, 64))}

        def allreduce_compressed(gs):
            # per-shard quantize -> dequantized mean across shards
            q, s, _ = compress_grads(gs, init_ef(gs))
            deq = decompress_grads(q, s)
            return jax.tree.map(lambda x: jax.lax.pmean(x, "data"), deq)

        fn = shard_map_compat(allreduce_compressed, mesh=mesh,
                              in_specs=({"w": P("data")},),
                              out_specs={"w": P("data")})
        got = fn(g)
        # reference: the true mean across shards (rows), tiled back
        ref = jnp.broadcast_to(jnp.mean(g["w"], axis=0, keepdims=True),
                               g["w"].shape)
        np.testing.assert_allclose(np.array(got["w"]), np.array(ref),
                                   atol=0.02)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out
