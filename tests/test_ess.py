"""ESS core behaviour: overlap exactness, warmup, locality metric, engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import lru_pool as LP
from repro.core import overlap as OV
from repro.core import warmup as WU
from repro.core.similarity import intra_layer_similarity, similarity_trace
from repro.models import mla as M
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    mla_p = init_params(jax.random.key(0), M.mla_def(cfg))
    idx_p = init_params(jax.random.key(1), M.indexer_def(cfg))
    B, S, ctx = 3, 64, 40
    lat = jax.random.normal(jax.random.key(2), (B, S, cfg.mla.latent_dim),
                            jnp.float32) * 0.5
    ikeys = jax.random.normal(jax.random.key(3), (B, S, cfg.dsa.index_dim),
                              jnp.float32)
    lens = jnp.full((B,), ctx, jnp.int32)
    x = jax.random.normal(jax.random.key(4), (B, 1, cfg.d_model),
                          jnp.float32) * 0.3
    pos = jnp.full((B, 1), ctx - 1, jnp.int32)
    return cfg, mla_p, idx_p, B, S, lat, ikeys, lens, x, pos


@pytest.mark.parametrize("mode", ["none", "da", "dba"])
def test_overlap_modes_exact_vs_monolithic(setup, mode):
    cfg, mla_p, idx_p, B, S, lat, ikeys, lens, x, pos = setup
    ref, _ = M.sparse_mla_decode(mla_p, idx_p, cfg, x, pos, lat, ikeys, lens)
    cfg_x = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    P = max(int(0.5 * S), cfg.dsa.index_topk)
    pool = LP.init_pool(B, P, S, cfg.mla.latent_dim, jnp.float32)
    st = OV.ESSLayerState(pool, lat)
    out, st2, stats = OV.ess_sparse_attention(
        mla_p, idx_p, cfg_x, x, pos, st, ikeys, lens, overlap=mode)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=1e-5)
    assert int(np.array(stats.misses).sum()) > 0        # cold pool missed


def test_pool_reuse_reduces_misses(setup):
    cfg, mla_p, idx_p, B, S, lat, ikeys, lens, x, pos = setup
    cfg_x = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    P = max(int(0.5 * S), cfg.dsa.index_topk)
    pool = LP.init_pool(B, P, S, cfg.mla.latent_dim, jnp.float32)
    st = OV.ESSLayerState(pool, lat)
    _, st1, s1 = OV.ess_sparse_attention(mla_p, idx_p, cfg_x, x, pos, st,
                                         ikeys, lens, overlap="da")
    _, _, s2 = OV.ess_sparse_attention(mla_p, idx_p, cfg_x, x, pos, st1,
                                       ikeys, lens, overlap="da")
    assert int(np.array(s2.misses).sum()) < int(np.array(s1.misses).sum())
    assert int(np.array(s2.misses).sum()) == 0          # same query -> hits


def test_lru_warmup_preheats_pool(setup):
    cfg, mla_p, idx_p, B, S, lat, ikeys, lens, x, pos = setup
    P = max(int(0.5 * S), cfg.dsa.index_topk)
    pool0 = LP.init_pool(B, P, S, cfg.mla.latent_dim, jnp.float32)
    x_tail = jnp.repeat(x, 4, axis=1)
    pool_w = WU.lru_warmup(pool0, lat, x_tail, idx_p, ikeys, lens, cfg,
                           slot_mask=None)
    cfg_x = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    _, _, s_cold = OV.ess_sparse_attention(
        mla_p, idx_p, cfg_x, x, pos, OV.ESSLayerState(pool0, lat), ikeys,
        lens, overlap="da")
    _, _, s_warm = OV.ess_sparse_attention(
        mla_p, idx_p, cfg_x, x, pos, OV.ESSLayerState(pool_w, lat), ikeys,
        lens, overlap="da")
    assert int(np.array(s_warm.misses).sum()) < \
        int(np.array(s_cold.misses).sum())


def test_engine_prefill_decode_matches_monolithic():
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 24, 40
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                              cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
    # monolithic reference
    pf = T.forward(params, cfg, toks[:, :S], pos[:, :S], mode="prefill")
    cm = pf.caches
    cm["mla"] = jax.tree.map(
        lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, Smax - S), (0, 0))),
        cm["mla"])
    dm = T.forward(params, cfg, toks[:, S:S + 1], pos[:, S:S + 1],
                   mode="decode", caches=cm)
    # ESS path (exact envelope, cold pool)
    cfg_x = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    _, ce = E.ess_prefill(params, cfg_x, toks[:, :S], pos[:, :S], Smax,
                          do_warmup=False)
    oe = E.ess_decode(params, cfg_x, toks[:, S:S + 1], pos[:, S:S + 1], ce)
    # fp reassociation (gather-K vs masked-dense softmax) can flip Top-K
    # selection at near-tie scores in a handful of positions; the bulk of
    # the logits must agree tightly
    diff = np.abs(np.array(oe.logits[:, -1]) - np.array(dm.logits[:, -1]))
    assert diff.max() < 5e-2
    assert diff.mean() < 5e-3          # bulk within bf16 rounding scale


def test_engine_prefill_chunked_matches_train():
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = T.forward(params, cfg, toks, pos, mode="train").logits
    lg, _ = E.ess_prefill(params, cfg, toks, pos, 40, do_warmup=False)
    # same Top-K selection semantics; a few near-tie positions may flip
    # under fp reassociation (chunk-gather vs dense-masked attention)
    diff = np.abs(np.array(lg) - np.array(ref))
    assert diff.max() < 5e-2
    assert diff.mean() < 2e-3
    # chunked prefill streams through the same engine: bit-identical
    lg7, _ = E.ess_prefill(params, cfg, toks, pos, 40, do_warmup=False,
                           prefill_chunk=7)
    np.testing.assert_array_equal(np.array(lg7), np.array(lg))


def test_intra_layer_similarity_eq1():
    a = jnp.array([[1, 2, 3, 4]])
    b = jnp.array([[3, 4, 5, 6]])
    r = intra_layer_similarity(a, b)
    np.testing.assert_allclose(np.array(r), [0.5])
    # identical sets -> 1, disjoint -> 0
    np.testing.assert_allclose(np.array(intra_layer_similarity(a, a)), [1.0])
    c = jnp.array([[7, 8, 9, 10]])
    np.testing.assert_allclose(np.array(intra_layer_similarity(a, c)), [0.0])
    tr = similarity_trace(jnp.stack([a, b, c]))
    assert tr.shape == (2, 1)


def test_dba_equals_da_results(setup):
    """DBA is a scheduling change only — numerics must match DA."""
    cfg, mla_p, idx_p, B, S, lat, ikeys, lens, x, pos = setup
    cfg_x = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    P = max(int(0.5 * S), cfg.dsa.index_topk)
    pool = LP.init_pool(B, P, S, cfg.mla.latent_dim, jnp.float32)
    st = OV.ESSLayerState(pool, lat)
    out_da, _, _ = OV.ess_sparse_attention(mla_p, idx_p, cfg_x, x, pos, st,
                                           ikeys, lens, overlap="da")
    out_dba, _, _ = OV.ess_sparse_attention(mla_p, idx_p, cfg_x, x, pos, st,
                                            ikeys, lens, overlap="dba")
    np.testing.assert_allclose(np.array(out_da), np.array(out_dba),
                               atol=1e-5)
