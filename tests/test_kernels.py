"""Per-kernel allclose vs the pure-jnp oracles, over shape/dtype sweeps
(interpret mode — kernel bodies execute on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gather_cache import ops as gops
from repro.kernels.gather_cache import ref as gref
from repro.kernels.indexer import ops as iops
from repro.kernels.indexer import ref as iref
from repro.kernels.sparse_mla import ops as sops
from repro.kernels.sparse_mla import ref as sref
from repro.kernels.sparse_mla.sparse_mla import sparse_mla_partial_kernel

DTYPES = [jnp.float32, jnp.bfloat16]


def tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("S,D,M", [(64, 576, 16), (100, 64, 7), (33, 128, 33)])
def test_gather_rows(dt, S, D, M):
    cache = jax.random.normal(jax.random.key(0), (S, D), jnp.float32).astype(dt)
    ids = jax.random.randint(jax.random.key(1), (M,), -3, S)
    out = gops.gather_rows(cache, ids)
    ref = jnp.where((ids >= 0)[:, None], gref.gather_rows_ref(cache, ids), 0)
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(ref, np.float32), **tol(dt))


@pytest.mark.parametrize("page", [4, 8])
def test_gather_pages(page):
    cache = jax.random.normal(jax.random.key(0), (64, 32), jnp.float32)
    pids = jax.random.randint(jax.random.key(1), (5,), 0, 64 // page)
    out = gops.gather_pages(cache, pids, page)
    ref = gref.gather_row_blocks_ref(cache, pids, page)
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=1e-6)


@pytest.mark.parametrize("qdt", [jnp.int8, jnp.float8_e4m3fn])
@pytest.mark.parametrize("S,D,M", [(64, 80, 16), (33, 40, 7)])
def test_gather_rows_dequant(qdt, S, D, M):
    from repro.distributed import compression as cmp
    rows = jax.random.normal(jax.random.key(0), (S, D), jnp.float32)
    q, s = cmp.quantize_rows(rows.astype(jnp.bfloat16), qdt)
    ids = jax.random.randint(jax.random.key(1), (M,), -3, S)
    out = gops.gather_rows_dequant(q, s, ids)
    ref = jnp.where((ids >= 0)[:, None],
                    gref.gather_rows_dequant_ref(q, s, ids), 0)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(ref, np.float32), rtol=1e-5,
                               atol=1e-5)
    # fused output matches the two-step quantized read exactly
    two_step = cmp.dequantize_rows(gref.gather_rows_ref(q, ids),
                                   gref.gather_rows_ref(s, ids),
                                   jnp.bfloat16)
    two_step = jnp.where((ids >= 0)[:, None], two_step, 0)
    np.testing.assert_array_equal(np.array(out, np.float32),
                                  np.array(two_step, np.float32))


@pytest.mark.parametrize("page", [4, 8])
def test_gather_pages_dequant(page):
    from repro.distributed import compression as cmp
    rows = jax.random.normal(jax.random.key(0), (64, 32), jnp.float32)
    q, s = cmp.quantize_rows(rows.astype(jnp.bfloat16), jnp.int8)
    pids = jax.random.randint(jax.random.key(1), (5,), 0, 64 // page)
    out = gops.gather_pages_dequant(q, s, pids, page)
    ref = gref.gather_row_blocks_dequant_ref(q, s, pids, page)
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(ref, np.float32), rtol=1e-5)


@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("H,D,K,R,kb", [
    (16, 576, 128, 512, 128), (12, 96, 100, 64, 32),
    (4, 64, 17, 32, 8), (128, 576, 256, 512, 128)])
def test_sparse_mla_partial(dt, H, D, K, R, kb):
    q = jax.random.normal(jax.random.key(0), (H, D), jnp.float32).astype(dt)
    rows = jax.random.normal(jax.random.key(1), (K, D), jnp.float32).astype(dt)
    valid = jax.random.bernoulli(jax.random.key(2), 0.8, (K,))
    valid = valid.at[0].set(True)  # at least one valid
    o, m, l = sparse_mla_partial_kernel(q, rows, valid, 0.1, R, kb=kb)
    ro, rm, rl = sref.sparse_mla_partial_ref(q, rows, valid, 0.1, R)
    np.testing.assert_allclose(np.array(m), np.array(rm), **tol(dt))
    np.testing.assert_allclose(np.array(l), np.array(rl), **tol(dt))
    np.testing.assert_allclose(np.array(o), np.array(ro), **tol(dt))


def test_sparse_mla_batched_and_finalize():
    B, Q, H, D, K, R = 2, 2, 8, 96, 64, 64
    q = jax.random.normal(jax.random.key(0), (B, Q, H, D), jnp.bfloat16)
    rows = jax.random.normal(jax.random.key(1), (B, K, D), jnp.bfloat16)
    valid = jax.random.bernoulli(jax.random.key(2), 0.7, (B, K))
    valid = valid.at[:, 0].set(True)
    p = sops.partial_attend(q, rows, valid, 0.125, R)
    # against dense softmax
    s = jnp.einsum("bqhd,bkd->bqhk", q.astype(jnp.float32),
                   rows.astype(jnp.float32)) * 0.125
    s = jnp.where(valid[:, None, None, :], s, -2e38)
    w = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bqhk,bkv->bqhv", w, rows[..., :R].astype(jnp.float32))
    got = p.o / np.maximum(np.array(p.l)[..., None], 1e-30)
    np.testing.assert_allclose(np.array(got), np.array(ref), rtol=2e-2,
                               atol=2e-2)


def test_fused_gather_attend_matches_dense():
    B, Q, H, D, K, S, R = 2, 1, 8, 96, 16, 64, 64
    q = jax.random.normal(jax.random.key(0), (B, Q, H, D), jnp.float32)
    lat = jax.random.normal(jax.random.key(1), (B, S, D), jnp.float32)
    ids = jax.random.randint(jax.random.key(2), (B, Q, K), 0, 48)
    valid_s = jnp.arange(S)[None] < jnp.array([48, 40])[:, None]
    out = sops.sparse_mla_gather_attend(q, lat, ids, valid_s, 0.1, R)
    gl = jnp.take_along_axis(lat[:, None], ids[..., None], axis=2)
    gv = jnp.take_along_axis(jnp.broadcast_to(valid_s[:, None], (B, Q, S)),
                             ids, axis=2)
    s = jnp.einsum("bqhd,bqkd->bqhk", q, gl) * 0.1
    s = jnp.where(gv[:, :, None], s, -2e38)
    w = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bqhk,bqkv->bqhv", w, gl[..., :R])
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("Hi,Di,S", [(64, 128, 300), (10, 48, 64),
                                     (4, 32, 1000)])
def test_indexer_scores(dt, Hi, Di, S):
    B, Q = 2, 3
    q = jax.random.normal(jax.random.key(0), (B, Q, Hi, Di),
                          jnp.float32).astype(dt)
    w = jax.random.normal(jax.random.key(1), (B, Q, Hi),
                          jnp.float32).astype(dt)
    keys = jax.random.normal(jax.random.key(2), (B, S, Di),
                             jnp.float32).astype(dt)
    valid = jnp.arange(S)[None, :] < jnp.array([S, S // 2])[:, None]
    sc = iops.indexer_scores(q, w, keys, valid)
    ref = jax.vmap(lambda q1, w1, k1, v1: jax.vmap(
        lambda q2, w2: iref.indexer_scores_ref(q2, w2, k1, v1))(q1, w1))(
        q, w, keys, valid)
    mask = np.array(ref) > -1e37
    np.testing.assert_allclose(np.array(sc)[mask], np.array(ref)[mask],
                               **tol(dt))
    assert bool(((np.array(sc) <= -1e37) == ~mask).all())


def test_indexer_topk_selects_valid_only():
    B, Q, Hi, Di, S = 1, 1, 4, 16, 50
    q = jax.random.normal(jax.random.key(0), (B, Q, Hi, Di))
    w = jnp.abs(jax.random.normal(jax.random.key(1), (B, Q, Hi)))
    keys = jax.random.normal(jax.random.key(2), (B, S, Di))
    valid = jnp.arange(S)[None, :] < 30
    _, ids = iops.topk_select(q, w, keys, valid, k=8)
    assert int(np.array(ids).max()) < 30
