"""Property tests (hypothesis) for the LRU Sparse Memory Pool invariants.

``hypothesis`` is a dev-only dependency (requirements-dev.txt); environments
without it still collect the suite — these property tests just skip.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import lru_pool as LP


def mk_pool(B=2, P=8, S=32, D=4):
    return LP.init_pool(B, P, S, D, jnp.float32)


def ref_lru(requests, P):
    """Python-dict LRU oracle: returns miss count per step."""
    slot = {}
    last = {}
    step = 0
    misses = []
    for req in requests:
        miss = [r for r in req if r not in slot]
        for r in req:
            if r in slot:
                last[r] = step
        # evict coldest for each miss
        for r in miss:
            if len(slot) >= P:
                coldest = min(slot, key=lambda k: last[k])
                del slot[coldest]
                del last[coldest]
            slot[r] = True
            last[r] = step
        misses.append(len(miss))
        step += 1
    return misses


@hp.given(st.lists(st.integers(0, 31), min_size=1, max_size=24),
          st.integers(4, 16))
@hp.settings(max_examples=30, deadline=None)
def test_lru_miss_counts_match_oracle_single_id(stream, P):
    """One id per step -> unique LRU stamps -> tie-free, exact oracle."""
    B, S, D = 1, 32, 4
    pool = LP.init_pool(B, P, S, D, jnp.float32)
    got = []
    for r in stream:
        ids = jnp.array([[r]], jnp.int32)
        pool, lk, stats = LP.lookup(pool, ids, ids >= 0, max_misses=1, slot_mask=None)
        pool = LP.admit(pool, lk.miss_ids, jnp.ones((1, 1, D)), slot_mask=None)
        pool = LP.tick(pool)
        got.append(int(stats.misses[0]))
    assert got == ref_lru([[r] for r in stream], P)


@hp.given(st.lists(st.lists(st.integers(0, 31), min_size=1, max_size=6,
                            unique=True), min_size=1, max_size=10),
          st.integers(8, 16))
@hp.settings(max_examples=30, deadline=None)
def test_lru_guarantee_batched(reqs, P):
    """Batched admissions share an LRU stamp, so tie-breaking is free —
    but the LRU *guarantee* must hold: an entry can only miss if, since
    its last access, at least P distinct (possibly tied) other ids were
    accessed."""
    B, S, D = 1, 32, 4
    pool = LP.init_pool(B, P, S, D, jnp.float32)
    last_access: dict[int, int] = {}
    history: list[set] = []
    for t, req in enumerate(reqs):
        ids = jnp.full((1, 6), -1, jnp.int32).at[0, :len(req)].set(
            jnp.array(req, jnp.int32))
        pool, lk, stats = LP.lookup(pool, ids, ids >= 0, max_misses=6, slot_mask=None)
        missed = set(int(i) for i in np.array(lk.miss_ids[0]) if i >= 0)
        for r in req:
            if r in missed and r in last_access:
                t0 = last_access[r]
                others = set()
                for tt in range(t0, t + 1):
                    others |= (history[tt] if tt < len(history) else
                               set(req)) - {r}
                assert len(others) >= P, (
                    f"id {r} evicted although only {len(others)} < {P} "
                    f"other ids were accessed since step {t0}")
        history.append(set(req))
        for r in req:
            last_access[r] = t
        pool = LP.admit(pool, lk.miss_ids, jnp.ones((1, 6, D)), slot_mask=None)
        pool = LP.tick(pool)


@hp.given(st.lists(st.lists(st.integers(0, 31), min_size=1, max_size=5,
                            unique=True), min_size=1, max_size=8))
@hp.settings(max_examples=30, deadline=None)
def test_pool_invariants(reqs):
    """forward map consistency: slot_of[id] == p  =>  ids[p] == id."""
    pool = mk_pool()
    for req in reqs:
        ids = jnp.full((2, 5), -1, jnp.int32)
        ids = ids.at[0, :len(req)].set(jnp.array(req, jnp.int32))
        ids = ids.at[1, :len(req)].set(jnp.array(req, jnp.int32))
        pool, lk, _ = LP.lookup(pool, ids, ids >= 0, max_misses=5, slot_mask=None)
        rows = jnp.ones((2, 5, 4))
        pool = LP.admit(pool, lk.miss_ids, rows, slot_mask=None)
        pool = LP.tick(pool)
        so = np.array(pool.slot_of)
        pids = np.array(pool.ids)
        for b in range(2):
            for pos in range(so.shape[1]):
                if so[b, pos] >= 0:
                    assert pids[b, so[b, pos]] == pos
            # every valid slot's id maps back (no dangling forward entries)
            for p_ in range(pids.shape[1]):
                if pids[b, p_] >= 0:
                    assert so[b, pids[b, p_]] == p_


def test_lookup_marks_hits_and_packs_misses():
    pool = mk_pool(B=1)
    ids = jnp.array([[3, 5, 7, -1]], jnp.int32)
    pool, lk, stats = LP.lookup(pool, ids, ids >= 0, max_misses=4, slot_mask=None)
    assert int(stats.misses[0]) == 3
    np.testing.assert_array_equal(np.array(lk.miss_ids[0, :3]), [3, 5, 7])
    rows = jnp.arange(4 * 4, dtype=jnp.float32).reshape(1, 4, 4)
    pool = LP.admit(pool, lk.miss_ids, rows, slot_mask=None)
    pool = LP.tick(pool)
    # second lookup: all hits, data returned matches admitted rows
    pool, lk2, st2 = LP.lookup(pool, ids, ids >= 0, max_misses=4, slot_mask=None)
    assert int(st2.misses[0]) == 0
    got, _ = LP.gather_resident(pool, lk2.slot, lk2.hit)
    np.testing.assert_allclose(np.array(got[0, 0]), np.array(rows[0, 0]))


def test_miss_envelope_overflow_drops_lowest_priority():
    pool = mk_pool(B=1, P=8)
    ids = jnp.array([[1, 2, 3, 4, 5]], jnp.int32)   # 5 misses, envelope 3
    pool, lk, stats = LP.lookup(pool, ids, ids >= 0, max_misses=3, slot_mask=None)
    assert int(stats.overflow[0]) == 2
    # packed misses are the FIRST (highest-score) requests
    np.testing.assert_array_equal(np.array(lk.miss_ids[0]), [1, 2, 3])


def test_invalidate_beyond_removes_stale_entries():
    pool = mk_pool(B=1, P=8)
    ids = jnp.array([[2, 9, 14]], jnp.int32)
    pool, lk, _ = LP.lookup(pool, ids, ids >= 0, max_misses=3, slot_mask=None)
    pool = LP.admit(pool, lk.miss_ids, jnp.ones((1, 3, 4)), slot_mask=None)
    pool = LP.invalidate_beyond(pool, jnp.array([10]))
    so = np.array(pool.slot_of[0])
    assert so[2] >= 0 and so[9] >= 0
    assert so[14] == -1
    assert 14 not in np.array(pool.ids[0])


def test_protected_slots_not_evicted():
    pool = mk_pool(B=1, P=4)
    ids = jnp.array([[0, 1, 2, 3]], jnp.int32)
    pool, lk, _ = LP.lookup(pool, ids, ids >= 0, max_misses=4, slot_mask=None)
    pool = LP.admit(pool, lk.miss_ids, jnp.ones((1, 4, 4)), slot_mask=None)
    pool = LP.tick(pool)
    # request 2 new ids while protecting slots of ids 0,1
    prot = jnp.array([[0, 1]], jnp.int32)
    slot_prot = jnp.take_along_axis(pool.slot_of, prot, axis=1)
    ids2 = jnp.array([[10, 11]], jnp.int32)
    pool, lk2, _ = LP.lookup(pool, ids2, ids2 >= 0, max_misses=2, slot_mask=None)
    pool = LP.admit(pool, lk2.miss_ids, jnp.ones((1, 2, 4)),
                    slot_mask=None, protect_slots=slot_prot)
    so = np.array(pool.slot_of[0])
    assert so[0] >= 0 and so[1] >= 0          # protected survived
    assert so[10] >= 0 and so[11] >= 0        # admitted
