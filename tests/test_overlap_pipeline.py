"""Pipelined round architecture (plan → compute → commit): TransferEngine
slab primitives, indexer-driven prefetch planning, overlap-vs-sync stream
parity, lifecycle edges (admission / preemption / abort / stop-token
truncation) landing against in-flight staged transfers, fill-round
accounting, and the ESS105 no-blocking-stage audit.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_audit as JA
from repro.configs import get_config
from repro.core import transfer as TR
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving.api import EssEngine, SamplingParams
from repro.serving.scheduler import Request


def smoke_cfg(mtp_depth=None, **ess_overrides):
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    if ess_overrides:
        cfg = dataclasses.replace(
            cfg, ess=dataclasses.replace(cfg.ess, **ess_overrides))
    if mtp_depth is not None:
        cfg = dataclasses.replace(cfg, mtp_depth=mtp_depth)
    return cfg


@pytest.fixture(scope="module")
def cfg():
    return smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.key(0), T.model_def(cfg))


# ---------------------------------------------------------------------------
# TransferEngine primitives
# ---------------------------------------------------------------------------

def test_empty_slab_is_disarmed():
    ids, rows, scales = TR.empty_slab(3, 2, 4, 8, jnp.bfloat16)
    assert ids.shape == (3, 2, 4) and (np.array(ids) == -1).all()
    assert rows.shape == (3, 2, 4, 8) and (np.array(rows) == 0).all()
    assert scales is None                        # raw bf16 tier: no plane


def test_empty_slab_quantized_carries_scale_plane():
    ids, rows, scales = TR.empty_slab(3, 2, 4, 8, jnp.int8,
                                      scale_dtype=jnp.float16)
    assert rows.dtype == jnp.int8
    assert scales.shape == (3, 2, 4, 1) and scales.dtype == jnp.float16
    assert (np.array(scales) == 0).all()


def test_plan_prefetch_ranks_nonresident_in_horizon_by_score():
    # slot 0: horizon 6 of 8; positions 1 and 4 are pool-resident and
    # must never be staged; the rest rank by score.  slot 1 is dead.
    sc = jnp.asarray([[.1, .9, .3, .8, .7, .2, .99, .5],
                      [.9, .9, .9, .9, .9, .9, .9, .9]], jnp.float32)
    qlens = jnp.asarray([6, 8], jnp.int32)
    slot_of = jnp.full((2, 8), -1, jnp.int32)
    slot_of = slot_of.at[0, 1].set(3).at[0, 4].set(0)
    live = jnp.asarray([True, False])
    pred = TR.plan_prefetch(sc, qlens, slot_of, live, topk=4,
                            prefetch_rows=3)
    assert pred.shape == (2, 3)
    # candidates for slot 0: {0:.1, 2:.3, 3:.8, 5:.2} (1,4 resident;
    # 6,7 out of horizon) -> score order 3, 2, 5
    assert pred[0].tolist() == [3, 2, 5]
    assert pred[1].tolist() == [-1, -1, -1]          # dead slot: no plan


def test_plan_prefetch_pads_when_candidates_run_out():
    sc = jnp.asarray([[.5, .6, .7, .8]], jnp.float32)
    pred = TR.plan_prefetch(sc, jnp.asarray([2], jnp.int32),
                            jnp.full((1, 4), -1, jnp.int32),
                            jnp.asarray([True]), topk=4, prefetch_rows=6)
    # only positions 0,1 are in horizon; P=6 pads with -1
    assert pred.shape == (1, 6)
    assert pred[0, :2].tolist() == [1, 0]
    assert pred[0, 2:].tolist() == [-1] * 4


def test_match_staged_serves_only_staged_needed_rows():
    ids = jnp.asarray([[3, 7, -1]], jnp.int32)                  # [B=1,P=3]
    rows = jnp.arange(6, dtype=jnp.float32).reshape(1, 3, 2) + 1.
    miss = jnp.asarray([[7, 4, 3]], jnp.int32)
    need = jnp.asarray([[True, True, False]])     # 3 needed elsewhere
    matched, out = TR.match_staged(ids, rows, miss, need)
    assert matched[0].tolist() == [True, False, False]
    np.testing.assert_array_equal(np.array(out[0, 0]), np.array(rows[0, 1]))
    assert (np.array(out[0, 1:]) == 0).all()


def test_transfer_engine_lifecycle_edges_cancel_staged_ids():
    te = TR.TransferEngine(num_layers=2, num_slots=2, prefetch_rows=3,
                           dim=4, dtype=jnp.float32)
    ids = jnp.asarray([[[2, 5, 9], [1, 4, 8]],
                       [[3, 6, 7], [0, 2, 5]]], jnp.int32)

    class _S:
        def __init__(self, ids, rows, scales=None):
            self.staged_ids, self.staged_rows = ids, rows
            self.staged_scales = scales

        def _replace(self, **kw):
            return _S(kw.get("staged_ids", self.staged_ids),
                      kw.get("staged_rows", self.staged_rows),
                      kw.get("staged_scales", self.staged_scales))

    s = _S(ids, jnp.zeros((2, 2, 3, 4)))
    # truncate: slot 1 rolls back to len 5 -> staged ids >= 5 cancel,
    # slot 0 untouched; new_len may be traced
    t = te.truncate_slot(s, 1, jnp.asarray(5, jnp.int32))
    assert np.array(t.staged_ids[:, 1]).tolist() == [[1, 4, -1], [0, 2, -1]]
    assert np.array(t.staged_ids[:, 0]).tolist() == np.array(ids[:, 0]).tolist()
    # invalidate: release/abort cancels the whole slot column
    v = te.invalidate_slot(t, 0)
    assert (np.array(v.staged_ids[:, 0]) == -1).all()
    # issue_stage disarms everything; await_staged hands the triple back
    a = te.issue_stage(v)
    aid, arow, ascale = te.await_staged(a)
    assert (np.array(aid) == -1).all() and (np.array(arow) == 0).all()
    assert ascale is None                        # raw tier: no scale plane


# ---------------------------------------------------------------------------
# Stream parity: overlap on == overlap off, bit for bit
# ---------------------------------------------------------------------------

_PARITY_WORKLOAD = [(10, dict(max_tokens=5)),
                    (8, dict(max_tokens=3)),
                    (13, dict(max_tokens=6)),
                    (9, dict(max_tokens=4, temperature=0.8, top_k=64,
                             top_p=0.95, seed=123))]


def _run_workload(params, cfg, **engine_kw):
    prompts = [p for p, _ in _PARITY_WORKLOAD]
    sps = [SamplingParams(**kw) for _, kw in _PARITY_WORKLOAD]
    eng = EssEngine(params, cfg, num_slots=2, max_seq=32, **engine_kw)
    outs = eng.generate(prompts, sps, max_rounds=120)
    assert sorted(eng.session._terminal) == [0, 1, 2, 3]
    return eng, [o.tokens for o in outs]


@pytest.mark.parametrize("mtp_depth,compiled", [(0, True), (2, True),
                                                (2, False)])
def test_overlap_stream_parity(cfg, params, mtp_depth, compiled):
    """Acceptance bar: the pipelined path's streams are bitwise
    identical to the synchronous path on the same greedy + sampled
    workload (misses fall back, never corrupt)."""
    kw = dict(mtp_depth=mtp_depth, compiled=compiled)
    _, base = _run_workload(params, cfg, overlap=False, **kw)
    eng, over = _run_workload(params, cfg, overlap=True, **kw)
    assert over == base
    rep = eng.session.report
    assert rep.prefetch_hits + rep.prefetch_misses > 0   # pipeline engaged


def test_overlap_stream_parity_dense_host_tier(params):
    cfg_d = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0, paged_host=False)
    _, base = _run_workload(params, cfg_d, overlap=False, mtp_depth=2)
    eng, over = _run_workload(params, cfg_d, overlap=True, mtp_depth=2)
    assert over == base and not eng.session.caches.paged


# ---------------------------------------------------------------------------
# Lifecycle edges vs in-flight staged transfers
# ---------------------------------------------------------------------------

def _drive_with_preempt(params, cfg, *, overlap, preempt_round=3,
                        check_slab=False):
    eng = EssEngine(params, cfg, num_slots=2, max_seq=32, overlap=overlap)
    rids = [eng.submit(p, SamplingParams(max_tokens=6))
            for p in (8, 9, 10, 11)]
    rnd = 0
    while eng.has_work():
        eng.step()
        if rnd == preempt_round and eng.session.sched.slots[1].active:
            eng.session.preempt(1)
            if check_slab:
                # the victim's in-flight staged transfers are cancelled
                # at the preemption edge, before any next-round program
                # could consume a stale row
                ids = np.array(eng.session.state.staged_ids)
                assert (ids[:, 1] == -1).all()
        rnd += 1
        assert rnd < 200
    return [eng.output(r).tokens for r in rids]


def test_preemption_cancels_staged_and_replays_identically(cfg, params):
    """A preemption landing between one round's plan (slab armed for
    round N+1) and the next round's commit must cancel the victim
    slot's staged transfers; the re-admitted request replays a stream
    bitwise equal to the synchronous path under the same preemption."""
    base = _drive_with_preempt(params, cfg, overlap=False)
    over = _drive_with_preempt(params, cfg, overlap=True, check_slab=True)
    assert over == base


def _abort_run(params, cfg, *, overlap):
    eng = EssEngine(params, cfg, num_slots=1, max_seq=32, overlap=overlap)
    r0 = eng.submit(10, SamplingParams(max_tokens=8))
    r1 = eng.submit(9, SamplingParams(max_tokens=5))
    for _ in range(4):                     # r0 decoding, slab armed
        eng.step()
    slot = next(i for i, s in enumerate(eng.session.sched.slots)
                if s.active and s.rid == r0)
    assert eng.abort(r0)
    if overlap:
        ids = np.array(eng.session.state.staged_ids)
        assert (ids[:, slot] == -1).all()  # abort cancelled the staging
    while eng.has_work():
        eng.step()
    assert eng.finish_reason(r0) == "abort"
    return eng.output(r1).tokens


def test_abort_and_admission_reuse_slab_slot(cfg, params):
    """Aborting a request cancels its slot's staged ids; the slot's next
    occupant (admission edge) starts from a disarmed slab column and
    streams identically to the synchronous path under the same abort
    schedule."""
    assert _abort_run(params, cfg, overlap=True) \
        == _abort_run(params, cfg, overlap=False)


def _permutation_params(cfg):
    """Zeroed params with a permutation head (see test_api): the stream
    is a non-constant permutation walk and MTP acceptance is full, so a
    verify round provably drafts past a chosen stop position."""
    base = jax.tree.map(jnp.zeros_like,
                        init_params(jax.random.key(0), T.model_def(cfg)))
    V, d = cfg.vocab_size, cfg.d_model
    emb = jax.random.normal(jax.random.key(1), (V, d), cfg.param_dtype)
    perm = jax.random.permutation(jax.random.key(2), V)
    base["embed"] = emb
    base["unembed"] = emb[jnp.argsort(perm)]
    proj = jnp.zeros((cfg.mtp_depth, 2 * d, d), cfg.param_dtype)
    proj = proj.at[:, d:, :].set(jnp.eye(d, dtype=cfg.param_dtype))
    base["mtp"]["proj"] = proj
    return base


def _stop_run(params, cfg, stop, *, overlap, snap):
    s = E.ServeSession(params, cfg, num_slots=1, max_seq=48, mtp_depth=2,
                       overlap=overlap)
    inner = s.sched.release_hook

    def capture(slot):
        snap["lens"] = int(np.array(s.caches.lens)[slot])
        snap["ids"] = [np.sort(ids[ids >= 0])
                       for ids in (np.array(p.ids[slot])
                                   for p in s.caches.pools)]
        if s.state.staged_ids is not None:
            # stop-token rollback: staged transfers beyond the truncated
            # length were cancelled before the release
            ids = np.array(s.state.staged_ids)[:, slot]
            assert (ids < snap["lens"]).all()
        inner(slot)

    s.sched.release_hook = capture
    s.run([Request(rid=0, prompt_len=10, max_new_tokens=9,
                   stop_token_ids=(stop,))], max_rounds=60)
    return s.outputs[0]


def test_stop_truncation_rolls_back_staged_state(cfg):
    """A stop token landing mid-verify truncates the slot's tail; under
    overlap the rollback must also cancel the staged ids beyond the cut,
    and the released lens/pool state must equal the synchronous run's."""
    params = _permutation_params(cfg)
    s = E.ServeSession(params, cfg, num_slots=1, max_seq=48, mtp_depth=2)
    s.run([Request(rid=0, prompt_len=10, max_new_tokens=9)], max_rounds=60)
    stream = s.outputs[0]
    stop = stream[2]                       # cuts the first verify round

    snap_sync, snap_over = {}, {}
    out_sync = _stop_run(params, cfg, stop, overlap=False, snap=snap_sync)
    out_over = _stop_run(params, cfg, stop, overlap=True, snap=snap_over)
    assert out_sync == out_over == stream[:3]
    assert snap_sync["lens"] == snap_over["lens"] == 10 + 2
    for a, b in zip(snap_sync["ids"], snap_over["ids"]):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Fill-round accounting (ServeReport)
# ---------------------------------------------------------------------------

def test_fill_rounds_excluded_from_cadence_identically(cfg, params):
    """`rounds_per_s` excludes each slot's pipeline-fill window from
    numerator and denominator, and classifies the same rounds as fill in
    sync and overlapped runs (the window depends only on the admission
    schedule)."""
    reps = {}
    for overlap in (False, True):
        eng, _ = _run_workload(params, cfg, overlap=overlap)
        reps[overlap] = eng.session.report
    sync, over = reps[False], reps[True]
    assert sync.fill_rounds == over.fill_rounds > 0
    assert sync.rounds == over.rounds > sync.fill_rounds
    for rep in (sync, over):
        got = rep.rounds_per_s * rep.decode_wall_s
        assert abs(got - (rep.rounds - rep.fill_rounds)) < 1e-6


def test_fill_round_window_resets_per_promotion():
    rep = E.ServeReport(rounds=10, fill_rounds=4, decode_wall_s=2.0)
    assert rep.rounds_per_s == pytest.approx(3.0)
    # all-fill degenerate run: cadence reads zero, never negative
    rep2 = E.ServeReport(rounds=3, fill_rounds=3, wall_s=1.0)
    assert rep2.rounds_per_s == 0.0


# ---------------------------------------------------------------------------
# ESS105 no-blocking-stage: checker + slicer sabotage
# ---------------------------------------------------------------------------

def test_ess105_checker_flags_blocking_and_dead_prefetch():
    clean = JA.check_pipeline_overlap("decode", consumes_staged=True,
                                      n_exclusive_gathers=2)
    assert clean == []
    dead = JA.check_pipeline_overlap("decode", consumes_staged=False,
                                     n_exclusive_gathers=1)
    assert [f.rule for f in dead] == ["ESS105"]
    assert "does not consume" in dead[0].message \
        or "dead prefetch" in dead[0].message
    blocking = JA.check_pipeline_overlap("spec", consumes_staged=True,
                                         n_exclusive_gathers=0)
    assert [f.rule for f in blocking] == ["ESS105"]
    assert "critical path" in blocking[0].message


def test_ess105_slicer_separates_exclusive_gathers():
    """Toy program with one gather per output: the backward slice must
    attribute each gather to its output alone — the property that lets
    the audit prove the slab refill sits off the token path."""
    def toy(a, b, tbl):
        return tbl[a].sum(), tbl[b]

    jaxpr = jax.make_jaxpr(toy)(jnp.zeros((3,), jnp.int32),
                                jnp.zeros((2,), jnp.int32),
                                jnp.zeros((8, 4), jnp.float32)).jaxpr
    in0, g0 = JA._slice_jaxpr(jaxpr, {0})
    in1, g1 = JA._slice_jaxpr(jaxpr, {1})
    assert 0 in in0 and 1 not in in0       # out0 needs a, not b
    assert 1 in in1 and 0 not in in1
    assert g1 - g0 and g0 - g1             # one exclusive gather each

    def fused(a, tbl):
        x = tbl[a]                          # single gather feeds BOTH
        return x.sum(), x

    j2 = jax.make_jaxpr(fused)(jnp.zeros((3,), jnp.int32),
                               jnp.zeros((8, 4), jnp.float32)).jaxpr
    _, h0 = JA._slice_jaxpr(j2, {0})
    _, h1 = JA._slice_jaxpr(j2, {1})
    assert not (h1 - h0)                    # no exclusive gather: blocking


def test_staged_slab_leaves_ride_donation():
    """The staging slab joins EngineState as the last two leaves and the
    pipelined decode program still donates every leaf (ESS101 over the
    grown state)."""
    targets = [t for t in JA.build_targets(prefetch=4)
               if t.kind == "decode"]
    plain = [t for t in JA.build_targets() if t.kind == "decode"]
    n_pf = len(jax.tree.leaves(targets[0].state))
    n_plain = len(jax.tree.leaves(plain[0].state))
    assert n_pf == n_plain + 2             # staged_ids + staged_rows
    assert JA.audit_donation(targets=targets) == []
