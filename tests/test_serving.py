"""Serving layer: scheduler, MTP speculative rollback, paged KV, TBO."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import kv_cache as KV
from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving import mtp as MTP
from repro.serving.sampling import greedy, sample
from repro.serving.scheduler import Request, Scheduler, feasible_batch_size


def test_scheduler_admission_completion_preemption():
    s = Scheduler(num_slots=2, max_seq=64)
    for i in range(3):
        s.submit(Request(rid=i, prompt_len=8,
                         max_new_tokens=4 if i == 0 else 16))
    admitted = s.admit()
    assert [r.rid for _, r in admitted] == [0, 1]
    assert s.occupancy() == 1.0
    # finish slot 0 (4 tokens)
    for _ in range(4):
        done = s.record_tokens({0: 1, 1: 1})
    assert any(r.rid == 0 for r in done)
    # slot freed; request 2 admitted next round
    admitted2 = s.admit()
    assert [r.rid for _, r in admitted2] == [2]
    # preempt slot 1 -> its request requeues at the FRONT
    s.preempt(1)
    assert s.queue[0].rid == 1
    assert s.queue[0].preempted_count == 1


def test_scheduler_rejects_oversize():
    s = Scheduler(num_slots=1, max_seq=16)
    s.submit(Request(rid=0, prompt_len=20, max_new_tokens=4))
    assert s.admit() == []
    assert s.finished[0].rid == 0


def test_feasible_batch_size_formula():
    b = feasible_batch_size(hbm_bytes=80_000_000_000,
                            weight_bytes_per_dev=41_000_000_000,
                            cache_bytes_per_seq=600_000_000)
    assert 40 <= b <= 60   # the paper's ~52 regime


def test_paged_kv_append_and_gather():
    kv = KV.init_paged(npages=16, page=4, kv_heads=2, head_dim=8, batch=2,
                       max_blocks=4, dtype=jnp.float32)
    ks, vs = [], []
    for t in range(6):
        k = jax.random.normal(jax.random.key(t), (2, 2, 8))
        v = k + 1
        kv = KV.append_token(kv, k, v)
        ks.append(k)
    kk, vv, valid = KV.gather_kv(kv, max_seq=8)
    assert kk.shape == (2, 8, 2, 8)
    np.testing.assert_array_equal(np.array(valid[:, :6]), True)
    np.testing.assert_array_equal(np.array(valid[:, 6:]), False)
    for t in range(6):
        np.testing.assert_allclose(np.array(kk[:, t]), np.array(ks[t]),
                                   rtol=1e-6)
    kv2 = KV.release_sequence(kv, 0)
    assert int(kv2.lens[0]) == 0 and int(kv2.lens[1]) == 6


def test_mtp_speculative_rollback_semantics():
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    cfg = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 16, 48
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    logits, caches = E.ess_prefill(params, cfg, toks, pos, Smax,
                                   do_warmup=False)
    tok = greedy(logits[:, -1])
    out = E.ess_decode(params, cfg, tok[:, None], caches.lens[:, None],
                       caches)
    hidden = out.stats["hidden"][:, -1]
    caches = out.caches
    tok = greedy(out.logits[:, -1])
    lens_before = np.array(caches.lens)

    def dec_fn(p_, c_, t_, po_, ca_):
        return E.ess_decode(p_, c_, t_, po_, ca_)

    spec = MTP.speculative_step(dec_fn, params, cfg, caches, tok, hidden)
    n = np.array(spec.n_accepted)
    assert ((1 <= n) & (n <= cfg.mtp_depth + 1)).all()
    np.testing.assert_array_equal(np.array(spec.caches.lens),
                                  lens_before + n)
    # pool must hold no entries at positions >= lens (rollback invalidation)
    for pool in spec.caches.pools:
        ids = np.array(pool.ids)
        lens = np.array(spec.caches.lens)
        for b in range(B):
            assert (ids[b][ids[b] >= 0] < lens[b]).all()


def test_sampling_greedy_and_temperature():
    logits = jnp.array([[0.1, 3.0, -1.0]])
    assert int(greedy(logits)[0]) == 1
    assert int(sample(jax.random.key(0), logits, temperature=0.0)[0]) == 1
    t = sample(jax.random.key(0), logits, temperature=1.0, top_k=2)
    assert int(t[0]) in (0, 1)


def test_mtp_spec_rollback_gated_on_slot_mask():
    """Regression (frozen-slot rollback): a slot frozen by ``slot_mask``
    (freed or mid-prefill) appends nothing during the verify step, so
    ``lens_after == lens``.  The old unconditional correction
    ``lens_after - (depth+1) + (n_acc+1)`` *shrank* the frozen slot's lens
    by ``depth - n_acc`` and ``invalidate_beyond`` then dropped its live
    pool entries."""
    from repro.core import lru_pool as LP
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    cfg = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 16, 48
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    logits, caches = E.ess_prefill(params, cfg, toks, pos, Smax,
                                   do_warmup=False)
    tok = greedy(logits[:, -1])
    # one live decode step populates both slots' pools + hidden
    out = E.ess_decode(params, cfg, tok[:, None], caches.lens[:, None],
                       caches)
    caches, hidden, tok = out.caches, out.stats["hidden"][:, -1], \
        greedy(out.logits[:, -1])
    lens_before = np.array(caches.lens)
    ids_before = [np.array(p.ids[1]) for p in caches.pools]
    assert any((i >= 0).any() for i in ids_before)   # slot 1 has live entries

    mask = jnp.asarray([True, False])

    def dec_fn(p_, c_, t_, po_, ca_):
        return E.ess_decode(p_, c_, t_, po_, ca_, slot_mask=mask)

    spec = MTP.speculative_step(dec_fn, params, cfg, caches, tok, hidden,
                                slot_mask=mask)
    lens_after = np.array(spec.caches.lens)
    # live slot advanced by its accepted+bonus count; frozen slot untouched
    assert lens_after[0] == lens_before[0] + int(spec.n_accepted[0])
    assert lens_after[1] == lens_before[1]
    for p, before in zip(spec.caches.pools, ids_before):
        np.testing.assert_array_equal(np.array(p.ids[1]), before)
        assert LP.check_consistent(p)
    # the ungated formula would have shrunk the frozen slot:
    depth = cfg.mtp_depth
    assert lens_before[1] - (depth + 1) + int(spec.n_accepted[1]) \
        < lens_before[1]


def test_two_batch_overlap_split_merge():
    from repro.cache import latent_cache as LC
    from repro.serving.tbo import merge_caches, split_caches, two_batch_step
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    cfg = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 12, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, caches = E.ess_prefill(params, cfg, toks, pos, Smax, do_warmup=False)
    nxt = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)

    ref = E.ess_decode(params, cfg, nxt, caches.lens[:, None], caches)

    ca, cb = split_caches(caches, 1)

    def step_fn(p_, c_, t_, po_, ch_, slot_mask=None):
        return E.ess_decode(p_, c_, t_, po_, ch_, slot_mask=slot_mask)

    logits, ca2, cb2, stats = two_batch_step(step_fn, params, cfg, nxt,
                                             caches.lens[:, None], ca, cb)
    np.testing.assert_allclose(np.array(logits), np.array(ref.logits),
                               atol=2e-2)
    assert stats["hidden"].shape[0] == B     # per-half stats concatenated

    # ---- page-merge regression: keeping either half's host_latent loses
    # the other half's D2H appends (both halves share the global pool) ----
    merged = merge_caches(ca2, cb2)
    np.testing.assert_array_equal(np.array(merged.lens),
                                  np.array(ref.caches.lens))
    np.testing.assert_array_equal(np.array(merged.block_tables),
                                  np.array(caches.block_tables))
    # slot 0's append survives from half A, slot 1's from half B (each
    # half holds its slot at batch row 0 of its own view)
    row0 = LC.slot_latents(merged, 0)[:, S]
    row1 = LC.slot_latents(merged, 1)[:, S]
    np.testing.assert_array_equal(np.array(row0),
                                  np.array(LC.slot_latents(ca2, 0)[:, S]))
    np.testing.assert_array_equal(np.array(row1),
                                  np.array(LC.slot_latents(cb2, 0)[:, S]))
    assert np.abs(np.array(row0)).sum() > 0
    assert np.abs(np.array(row1)).sum() > 0
    # the bug this fixes: half-A's host alone has a ZERO row where half-B
    # appended slot 1's latent
    from repro.core import offload
    lost = offload.host_gather_rows(
        ca2.host_latent, jnp.full((1, 1), S, jnp.int32), layer=0,
        batch_offset=0, block_table=merged.block_tables[1:])
    assert np.abs(np.array(lost)).sum() == 0

    # masked halves stay untouched through the TBO path
    mask = jnp.zeros((B,), bool)
    _, ca3, cb3, _ = two_batch_step(step_fn, params, cfg, nxt,
                                    caches.lens[:, None], ca, cb,
                                    slot_mask=mask)
    np.testing.assert_array_equal(np.array(merge_caches(ca3, cb3).lens),
                                  np.array(caches.lens))
