"""Paged host latent-cache: block-table parity, slot recycling, serve loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import latent_cache as LC
from repro.configs import get_config
from repro.core import lru_pool as LP
from repro.core import offload
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving.scheduler import Request


def smoke_cfg(**ess_overrides):
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    if ess_overrides:
        cfg = dataclasses.replace(
            cfg, ess=dataclasses.replace(cfg.ess, **ess_overrides))
    return cfg


def test_paged_is_default_for_offload_configs():
    cfg = smoke_cfg()
    assert LC.uses_paged_host(cfg)
    caches = LC.init_ess_caches(cfg, 2, 40, jnp.float32)
    assert caches.paged and caches.block_tables.shape[0] == 2
    dense = LC.init_ess_caches(smoke_cfg(paged_host=False), 2, 40,
                               jnp.float32)
    assert not dense.paged and dense.host_latent.shape == \
        (cfg.num_layers, 2, 40, cfg.mla.latent_dim)


def test_paged_vs_dense_roundtrip_bitwise():
    """host_gather_rows/host_scatter_rows must round-trip bitwise-equal
    through a *scrambled* (non-identity) block table."""
    cfg = smoke_cfg()
    B, S, D = 3, 40, cfg.mla.latent_dim
    caches = LC.init_ess_caches(cfg, B, S, jnp.float32)
    NP = caches.host_latent.shape[1]
    perm = np.random.RandomState(0).permutation(NP)
    bt = jnp.asarray(perm.reshape(B, -1), jnp.int32)

    dense = jnp.zeros((cfg.num_layers, B, S, D), jnp.float32)
    ids = jnp.array([[0, 5, 17, 39], [1, 2, 3, -1], [38, 0, 7, 12]],
                    jnp.int32)
    rows = jax.random.normal(jax.random.key(0), (B, 4, D), jnp.float32)

    for layer in (0, cfg.num_layers - 1):
        hp = offload.host_scatter_rows(caches.host_latent, ids, rows,
                                       slot_mask=None, layer=layer,
                                       block_table=bt)
        hd = offload.host_scatter_rows(dense, ids, rows, slot_mask=None,
                                       layer=layer)
        got_p = offload.host_gather_rows(hp, ids, layer=layer,
                                         block_table=bt)
        got_d = offload.host_gather_rows(hd, ids, layer=layer)
        np.testing.assert_array_equal(np.array(got_p), np.array(got_d))
        ref = jnp.where((ids >= 0)[..., None], rows, 0)
        np.testing.assert_array_equal(np.array(got_p), np.array(ref))


def test_paged_scatter_drops_unmapped_and_out_of_range():
    cfg = smoke_cfg()
    B, S, D = 2, 40, cfg.mla.latent_dim
    caches = LC.init_ess_caches(cfg, B, S, jnp.float32)
    bt = caches.block_tables.at[1].set(-1)               # slot 1 unmapped
    ids = jnp.array([[0, 999], [3, 5]], jnp.int32)       # 999 out of range
    rows = jnp.ones((B, 2, D), jnp.float32)
    h = offload.host_scatter_rows(caches.host_latent, ids, rows,
                                  slot_mask=None, block_table=bt)
    got = offload.host_gather_rows(h, ids, block_table=bt)
    np.testing.assert_array_equal(np.array(got[0, 0]), np.ones(D))
    assert np.array(got[0, 1]).sum() == 0                # OOR dropped
    assert np.array(got[1]).sum() == 0                   # unmapped dropped
    # nothing leaked into other pages: only one row non-zero globally
    assert int((np.array(h) != 0).any(axis=-1).sum()) == 1


def test_slot_latents_gather_pages_kernel_parity():
    """The Pallas gather_pages page-fetch matches the jnp reference view."""
    cfg = smoke_cfg()
    B, S, D = 2, 40, cfg.mla.latent_dim
    caches = LC.init_ess_caches(cfg, B, S, jnp.float32)
    NP = caches.host_latent.shape[1]
    perm = np.random.RandomState(1).permutation(NP)
    bt = jnp.asarray(perm.reshape(B, -1), jnp.int32)
    host = jax.random.normal(jax.random.key(2), caches.host_latent.shape,
                             jnp.float32)
    caches = caches._replace(host_latent=host, block_tables=bt)
    for slot in range(B):
        a = LC.slot_latents(caches, slot, use_kernel=False)
        b = LC.slot_latents(caches, slot, use_kernel=True)
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_engine_paged_matches_dense_path():
    """Full prefill+decode parity: paged host tier vs the dense layout."""
    cfg_p = smoke_cfg(max_miss_ratio=1.0)
    cfg_d = smoke_cfg(max_miss_ratio=1.0, paged_host=False)
    params = init_params(jax.random.key(0), T.model_def(cfg_p))
    B, S, Smax = 2, 20, 40
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                              cfg_p.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))

    lg_p, c_p = E.ess_prefill(params, cfg_p, toks[:, :S], pos[:, :S], Smax,
                              do_warmup=False)
    lg_d, c_d = E.ess_prefill(params, cfg_d, toks[:, :S], pos[:, :S], Smax,
                              do_warmup=False)
    assert c_p.paged and not c_d.paged
    np.testing.assert_allclose(np.array(lg_p), np.array(lg_d), atol=1e-6)
    o_p = E.ess_decode(params, cfg_p, toks[:, S:], pos[:, S:], c_p)
    o_d = E.ess_decode(params, cfg_d, toks[:, S:], pos[:, S:], c_d)
    np.testing.assert_allclose(np.array(o_p.logits), np.array(o_d.logits),
                               atol=1e-6)
    for k in ("hits", "misses"):
        np.testing.assert_array_equal(np.array(o_p.stats[k]),
                                      np.array(o_d.stats[k]))


# ---------------------------------------------------------------------------
# Slot recycling
# ---------------------------------------------------------------------------

def test_reset_slot_clears_pool_maps():
    """Regression: a recycled slot's pool must not hit stale entries.
    Resetting only ``lens`` (the old preemption path) leaves the maps
    populated — lookups would *hit* and serve the previous request's
    latents."""
    cfg = smoke_cfg()
    B, S = 2, 40
    caches = LC.init_ess_caches(cfg, B, S, jnp.float32)
    ids = jnp.array([[3, 7, 11], [5, 9, 13]], jnp.int32)
    pools = []
    for p in caches.pools:
        p, lk, _ = LP.lookup(p, ids, ids >= 0, max_misses=3, slot_mask=None)
        p = LP.admit(p, lk.miss_ids, jnp.ones((B, 3, cfg.mla.latent_dim)),
                      slot_mask=None)
        pools.append(LP.tick(p))
    caches = caches._replace(pools=tuple(pools),
                             lens=jnp.array([20, 20], jnp.int32))

    # the old buggy path: only lens reset -> stale HIT
    stale = caches._replace(lens=caches.lens.at[1].set(0))
    _, lk_stale, st_stale = LP.lookup(stale.pools[0], ids, ids >= 0, 3, slot_mask=None)
    assert int(st_stale.hits[1]) == 3        # the bug this PR fixes

    # reset_slot: full per-slot reset -> no hits, slot 0 untouched
    clean = LC.reset_slot(caches, 1)
    assert int(clean.lens[1]) == 0 and int(clean.lens[0]) == 20
    for p in clean.pools:
        assert (np.array(p.ids[1]) == -1).all()
        assert (np.array(p.last_use[1]) == -1).all()
        assert (np.array(p.slot_of[1]) == -1).all()
        assert (np.array(p.ids[0]) >= 0).sum() == 3
    _, lk_clean, st_clean = LP.lookup(clean.pools[0], ids, ids >= 0, 3, slot_mask=None)
    assert int(st_clean.hits[1]) == 0
    assert int(st_clean.hits[0]) == 3


def _pool_host_consistent(caches, slot):
    """Every resident pool entry of ``slot`` must equal the host-tier row
    at its position — stale entries from a previous occupant cannot."""
    host = LC.slot_latents(caches, slot)                 # [L, S_pad, D]
    for layer, p in enumerate(caches.pools):
        ids = np.array(p.ids[slot])
        data = np.array(p.data[slot])
        n_checked = 0
        for j, pid in enumerate(ids):
            if pid >= 0:
                np.testing.assert_array_equal(
                    data[j], np.array(host[layer, pid]),
                    err_msg=f"layer {layer} pool slot {j} pos {pid}")
                n_checked += 1
        assert n_checked > 0
    return True


def test_preempt_readmit_no_stale_pool_entries():
    """preempt -> re-admit -> the recycled slot's pool serves only the new
    occupant's latents (consistency with the host tier, which the graft
    rewrote)."""
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=48)
    reqs = [Request(rid=i, prompt_len=12, max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        session.submit(r)
    session.admit()
    session.prefill_round()          # chunked prefill: one slot per round
    session.prefill_round()
    session.decode_round()
    # preempt slot 1 mid-flight: the release hook must fully reset it
    session.preempt(1)
    for p in session.caches.pools:
        assert (np.array(p.ids[1]) == -1).all()
        assert (np.array(p.slot_of[1]) == -1).all()
    assert int(session.caches.lens[1]) == 0
    # rid=1 re-queued at the front; next admit recycles slot 1
    admitted = session.admit()
    assert [(s, r.rid) for s, r in admitted] == [(1, 1)]
    session.prefill_round()
    session.decode_round()
    _pool_host_consistent(session.caches, 1)
    # drive to completion: everything finishes, pools stay consistent
    report = session.run(max_rounds=60)
    assert sorted(report.finished_rids) == [0, 1, 2]


def test_serve_loop_streams_requests_page_gated():
    """>= 2x num_slots requests through one long-lived batch; admission
    gated on free host pages (pool provisioned below the dense pin)."""
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    # 16 rows/request -> 1 page each; 32-row requests -> 2 pages.
    reqs = [Request(rid=0, prompt_len=12, max_new_tokens=4),
            Request(rid=1, prompt_len=12, max_new_tokens=4),
            Request(rid=2, prompt_len=24, max_new_tokens=8),
            Request(rid=3, prompt_len=24, max_new_tokens=8)]
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=48,
                             num_host_pages=3)
    samples = []                                   # pages in use, per round

    def on_round(s, rnd):
        samples.append(s.num_pages - s.allocator.free_pages)

    report = session.run(reqs, max_rounds=80, on_round=on_round)
    assert sorted(report.finished_rids) == [0, 1, 2, 3]
    assert report.admissions_blocked > 0           # the gate engaged
    assert report.peak_pages_in_use <= report.num_pages == 3
    # peak is sampled every round (not just at admit): it must dominate
    # every end-of-round sample (intra-round admit/release transients can
    # push it higher than any end-of-round observation)
    assert report.peak_pages_in_use >= max(samples)
    assert session.allocator.free_pages == 3       # all pages returned
    assert (np.array(session.caches.block_tables) == -1).all()
