"""Chunked decode-interleaved prefill + inactive-slot decode gating.

Covers this PR's tentpole and headline bugfix:

* chunked prefill (any ``prefill_chunk``) is **bit-identical** to the
  one-shot ``ess_prefill`` path — host latents, indexer keys, first
  sampled token;
* a long prompt admits without stalling the decode batch (decode rounds
  continue between prefill chunks);
* masked (freed / mid-prefill) slots are gated *inside* ``ess_decode``:
  no phantom host-page writes, no pool pollution, no lens drift;
* preemption resets per-attempt progress so a re-admitted request
  generates its full ``max_new_tokens``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import latent_cache as LC
from repro.configs import get_config
from repro.configs.base import DSAConfig
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving.sampling import greedy
from repro.serving.scheduler import Request


def smoke_cfg(**ess_overrides):
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    if ess_overrides:
        cfg = dataclasses.replace(
            cfg, ess=dataclasses.replace(cfg.ess, **ess_overrides))
    return cfg


# ---------------------------------------------------------------------------
# Parity: chunked == one-shot, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [7, 64])
def test_chunked_prefill_bitwise_parity(chunk):
    """Host latents, indexer keys and the first sampled token must be
    bit-identical between chunked and one-shot prefill: every chunk stage
    (score, top-k, gather, attend, ffn) is fixed-shape and per-token."""
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 24, 64
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    lg1, c1 = E.ess_prefill(params, cfg, toks, pos, Smax, do_warmup=False)
    lgc, cc = E.ess_prefill(params, cfg, toks, pos, Smax, do_warmup=False,
                            prefill_chunk=chunk)
    np.testing.assert_array_equal(np.array(c1.host_latent),
                                  np.array(cc.host_latent))
    for l in range(cfg.num_layers):
        np.testing.assert_array_equal(np.array(c1.ikeys[l]),
                                      np.array(cc.ikeys[l]))
    np.testing.assert_array_equal(np.array(c1.lens), np.array(cc.lens))
    np.testing.assert_array_equal(np.array(greedy(lg1[:, -1])),
                                  np.array(greedy(lgc[:, -1])))
    # full prefill logits are bitwise equal too (same per-token math)
    np.testing.assert_array_equal(np.array(lg1), np.array(lgc))


def test_serve_session_chunked_prefill_matches_oneshot_first_token():
    """The serve loop's in-place chunked prefill (scatter into mapped
    pages, no donor/graft) reproduces the compat path's host rows and
    first token, bit for bit.

    ``do_warmup=True`` routes the session through the legacy op-by-op
    chunk path — the only execution substrate comparable bit-level
    against the eager one-shot reference (XLA's full-graph fusion
    perturbs low-order float bits, so jitted StepProgram chunks are
    held to *stream*-level parity instead — tests/test_compiled_serve).
    The warmup replay touches only the pools, never the host rows or
    the first token compared here."""
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    PROMPT, SMAX = 20, 48

    def prompt_fn(req):
        return jax.random.randint(jax.random.key(1000 + req.rid),
                                  (1, req.prompt_len), 0, cfg.vocab_size)

    session = E.ServeSession(params, cfg, num_slots=2, max_seq=SMAX,
                             prefill_chunk=7, prompt_fn=prompt_fn,
                             do_warmup=True)
    req = Request(rid=0, prompt_len=PROMPT, max_new_tokens=4)
    session.submit(req)
    session.admit()
    while session._prefill:
        session.prefill_round()
    # reference: one-shot donor prefill of the same prompt
    toks = prompt_fn(req)
    pos = jnp.arange(PROMPT, dtype=jnp.int32)[None]
    lg, donor = E.ess_prefill(params, cfg, toks, pos, SMAX, do_warmup=False)
    assert int(session.tok[0]) == int(greedy(lg[:, -1])[0])
    got = LC.slot_latents(session.caches, 0)[:, :PROMPT]
    ref = LC.slot_latents(donor, 0)[:, :PROMPT]
    np.testing.assert_array_equal(np.array(got), np.array(ref))


# ---------------------------------------------------------------------------
# Long-prompt admission: decode keeps running between chunks
# ---------------------------------------------------------------------------

def test_32k_prompt_admits_without_decode_stall():
    """A 32K-token prompt streams through chunked prefill while the other
    slot keeps decoding — the one-shot donor path would freeze the batch
    for the whole prefill."""
    base = smoke_cfg()
    cfg = dataclasses.replace(                    # nano variant: 2 layers,
        base, num_layers=2,                       # 1-head indexer, CPU-sized
        dsa=DSAConfig(index_heads=1, index_dim=8, index_topk=8))
    params = init_params(jax.random.key(0), T.model_def(cfg))
    LONG, SHORT = 32768, 8
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=LONG + 8,
                             prefill_chunk=4096)
    reqs = [Request(rid=0, prompt_len=SHORT, max_new_tokens=24),
            Request(rid=1, prompt_len=LONG, max_new_tokens=2)]
    decode_during_prefill = []

    def on_round(s, rnd):
        if s._prefill:                            # rid=1 still prefilling
            decode_during_prefill.append(s.report.decode_tokens)

    report = session.run(reqs, max_rounds=64, on_round=on_round)
    assert sorted(report.finished_rids) == [0, 1]
    assert report.prefill_chunks >= LONG // 4096 + 1
    assert report.prefill_tokens == LONG + SHORT
    # decode rounds continued between rid=1's chunks
    assert decode_during_prefill and \
        decode_during_prefill[-1] > decode_during_prefill[0]
    chunk_evs = [e for e in report.events if "prefill chunk" in e]
    assert len(chunk_evs) == report.prefill_chunks
    assert report.ttft_rounds[1] >= LONG // 4096  # one chunk per round


# ---------------------------------------------------------------------------
# Headline bugfix: inactive slots are masked inside the decode step
# ---------------------------------------------------------------------------

def test_masked_decode_writes_nothing():
    """With every slot masked, a decode step must leave host pages, pools
    and lens bit-identical — freed slots can no longer run phantom steps
    that scatter garbage latents or admit zeros into their pool."""
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 12, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, caches = E.ess_prefill(params, cfg, toks, pos, Smax, do_warmup=False)
    nxt = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)

    mask = jnp.zeros((B,), bool)
    out = E.ess_decode(params, cfg, nxt, caches.lens[:, None], caches,
                       slot_mask=mask)
    np.testing.assert_array_equal(np.array(out.caches.host_latent),
                                  np.array(caches.host_latent))
    np.testing.assert_array_equal(np.array(out.caches.lens),
                                  np.array(caches.lens))
    for p0, p1 in zip(caches.pools, out.caches.pools):
        np.testing.assert_array_equal(np.array(p0.ids), np.array(p1.ids))
        np.testing.assert_array_equal(np.array(p0.data), np.array(p1.data))
    for l in range(cfg.num_layers):
        np.testing.assert_array_equal(np.array(out.caches.ikeys[l]),
                                      np.array(caches.ikeys[l]))
    assert int(np.array(out.stats["hits"]).sum()) == 0
    assert int(np.array(out.stats["misses"]).sum()) == 0


def test_freed_slot_does_not_alias_live_slot_pages():
    """Regression for the serve-loop aliasing bug: a freed slot whose
    stale block table still points at (now someone else's) pages used to
    scatter a garbage latent row through it.  Decode with slot 1 freed:
    slot 0's host pages change only at its own append row, and slot 1's
    pool stays empty."""
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 12, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, caches = E.ess_prefill(params, cfg, toks, pos, Smax, do_warmup=False)
    # free slot 1 the buggy way: lens zeroed, block table STALE — and make
    # the staleness adversarial: slot 1's table aliases slot 0's pages
    caches = LC.reset_slot(caches, 1)
    caches = caches._replace(
        block_tables=caches.block_tables.at[1].set(caches.block_tables[0]))
    before = np.array(caches.host_latent)

    nxt = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)
    mask = jnp.asarray([True, False])
    out = E.ess_decode(params, cfg, nxt, caches.lens[:, None], caches,
                       slot_mask=mask)
    after = np.array(out.caches.host_latent)

    # slot 0 appended exactly one row per layer at position S -> page
    # S // R, row S % R; every other host row is bit-identical.  The old
    # phantom step wrote slot 1's garbage at position 0 == slot 0's page 0.
    R = cfg.ess.host_page_rows
    bt0 = np.array(caches.block_tables[0])
    pg, rw = bt0[S // R], S % R
    changed = (after != before).any(axis=-1)          # [L, NP, R]
    expect = np.zeros_like(changed)
    expect[:, pg, rw] = True
    np.testing.assert_array_equal(changed, changed & expect)
    assert changed[:, pg, rw].all()                   # the append happened
    # freed slot's pool stayed empty (no phantom admit of a zero row)
    for p in out.caches.pools:
        assert (np.array(p.ids[1]) == -1).all()
    assert int(np.array(out.caches.lens[1])) == 0

    # the same step WITHOUT the mask exhibits the bug this PR fixes: the
    # phantom write lands in slot 0's page 0 (kept as documentation that
    # this regression test bites)
    out_buggy = E.ess_decode(params, cfg, nxt, caches.lens[:, None], caches)
    after_buggy = np.array(out_buggy.caches.host_latent)
    assert (after_buggy[:, bt0[0], 0] != before[:, bt0[0], 0]).any()


def test_serve_loop_freed_slot_rounds_leave_it_untouched():
    """Drive the real serve loop to a state with one freed slot and keep
    decoding: the freed slot's lens/pool stay clean with no post-hoc
    fixups (the old loop re-zeroed lens after every phantom step)."""
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=48)
    reqs = [Request(rid=0, prompt_len=12, max_new_tokens=20),
            Request(rid=1, prompt_len=12, max_new_tokens=2)]
    report = None
    for r in reqs:
        session.submit(r)
    for _ in range(8):                # rid=1 finishes, slot 1 frees
        session.step()
    assert not session.sched.slots[1].active
    for _ in range(4):                # decode rounds with a freed slot
        session.step()
    assert int(session.caches.lens[1]) == 0
    for p in session.caches.pools:
        assert (np.array(p.ids[1]) == -1).all()
    assert (np.array(session.caches.block_tables[1]) == -1).all()


def test_serve_warmup_replays_after_last_chunk():
    """With ``do_warmup=True`` the slot's Sparse Memory Pool is preheated
    (LRU-Warmup replay from its mapped pages) after the final prefill
    chunk, before the first decode step — and the warmed entries match the
    host tier."""
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=48,
                             do_warmup=True, prefill_chunk=16)
    session.submit(Request(rid=0, prompt_len=20, max_new_tokens=4))
    session.admit()
    while session._prefill:
        session.prefill_round()
    host = LC.slot_latents(session.caches, 0)
    n_warm = 0
    for layer, p in enumerate(session.caches.pools):
        ids = np.array(p.ids[0])
        for j, pid in enumerate(ids):
            if pid >= 0:
                n_warm += 1
                np.testing.assert_array_equal(
                    np.array(p.data[0, j]), np.array(host[layer, pid]))
        # the un-admitted slot stays cold
        assert (np.array(p.ids[1]) == -1).all()
    assert n_warm > 0
    # warmed pool reduces first-decode misses vs a cold session
    cold = E.ServeSession(params, cfg, num_slots=2, max_seq=48,
                          do_warmup=False, prefill_chunk=16)
    cold.submit(Request(rid=0, prompt_len=20, max_new_tokens=4))
    cold.admit()
    while cold._prefill:
        cold.prefill_round()
    mask = jnp.asarray([True, False])
    o_warm = E.ess_decode(params, cfg, session.tok[:, None],
                          session.caches.lens[:, None], session.caches,
                          slot_mask=mask)
    o_cold = E.ess_decode(params, cfg, cold.tok[:, None],
                          cold.caches.lens[:, None], cold.caches,
                          slot_mask=mask)
    assert int(np.array(o_warm.stats["misses"]).sum()) < \
        int(np.array(o_cold.stats["misses"]).sum())


def test_serve_warmup_depth_independent_of_chunking():
    """Warmup windows span chunk boundaries: prompt_len=17 with
    prefill_chunk=16 leaves a 1-token final chunk, but the replay must
    still cover the full ``warmup_windows`` tail (accumulated across
    chunks) — bit-identical pool state vs a single-chunk prefill."""
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))

    def mk(chunk):
        s = E.ServeSession(params, cfg, num_slots=1, max_seq=32,
                           do_warmup=True, prefill_chunk=chunk)
        s.submit(Request(rid=0, prompt_len=17, max_new_tokens=2))
        s.admit()
        while s._prefill:
            s.prefill_round()
        return s

    a, b = mk(16), mk(64)
    for pa, pb in zip(a.caches.pools, b.caches.pools):
        np.testing.assert_array_equal(np.array(pa.ids), np.array(pb.ids))
        np.testing.assert_array_equal(np.array(pa.data), np.array(pb.data))
        np.testing.assert_array_equal(np.array(pa.last_use),
                                      np.array(pb.last_use))
    assert any((np.array(p.ids[0]) >= 0).sum() > 0 for p in a.caches.pools)
    assert int(a.tok[0]) == int(b.tok[0])


# ---------------------------------------------------------------------------
# Preemption resets per-attempt progress
# ---------------------------------------------------------------------------

def test_preempt_resets_generated_and_readmit_serves_full_budget():
    cfg = smoke_cfg()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    NEW = 6
    session = E.ServeSession(params, cfg, num_slots=1, max_seq=48)
    req = Request(rid=0, prompt_len=12, max_new_tokens=NEW)
    session.submit(req)
    session.step()                     # admit + prefill + 1 decode token
    session.step()
    assert req.generated == 2
    session.preempt(0)
    assert req.generated == 0          # per-attempt progress reset
    assert req.preempted_count == 1

    # re-admission: the attempt re-prefills and must produce the FULL
    # max_new_tokens again (the old code finished `generated` early).
    # The prefill first token consumes one budget unit, so the decode
    # phase delivers (and rounds through) NEW - 1 tokens and the stream
    # holds NEW total.
    decode_rounds_before = session.report.rounds
    report = session.run(max_rounds=40)
    assert report.finished_rids == [0]
    assert req.generated == NEW - 1
    assert len(session.outputs[0]) == NEW == req.generated + 1
    assert report.rounds - decode_rounds_before == NEW - 1
