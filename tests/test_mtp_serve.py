"""MTP speculative decode + TBO wired into the continuous-batching serve
loop.

Covers this PR's tentpole and satellites:

* Q>1 ``ess_decode`` parity: one Q=3 verify step == three sequential Q=1
  steps, bit-identical on lens / indexer caches / host pages (dense *and*
  paged) — requires per-query causal masking, per-query fetch validity
  and duplicate-miss dedup in the flattened pool lookup;
* pool-map invariants after flattened Q>1 lookup + admit + rollback;
* MTP-enabled ``ServeSession`` (depth 2, greedy) emits token streams
  bit-identical to the Q=1 baseline, solo and composed with TBO;
* full-acceptance arithmetic (zero params -> every draft accepted),
  including the budget clamp when a verify round out-emits the request;
* a slot finishing mid-spec-round leaves the freed slot's pages and pool
  untouched;
* per-request sampling: deterministic keyed streams, identical between
  Q=1 and speculative serve modes (sampling slots force-reject drafts).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import latent_cache as LC
from repro.configs import get_config
from repro.core import lru_pool as LP
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving.scheduler import Request


def smoke_cfg(mtp_depth=None, **ess_overrides):
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    if ess_overrides:
        cfg = dataclasses.replace(
            cfg, ess=dataclasses.replace(cfg.ess, **ess_overrides))
    if mtp_depth is not None:
        cfg = dataclasses.replace(cfg, mtp_depth=mtp_depth)
    return cfg


# ---------------------------------------------------------------------------
# Q>1 decode parity (the verify step the speculative round relies on)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [True, False])
def test_q3_decode_matches_three_q1_steps(paged):
    """A single Q=3 step must leave lens / indexer caches / host pages
    bit-identical to three sequential Q=1 steps.  ``overlap='none'``
    keeps the attention partition-invariant (one softmax over the union),
    so only the per-query causal mask and miss dedup are on trial."""
    cfg = smoke_cfg(max_miss_ratio=1.0, overlap="none", paged_host=paged)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax, Q = 2, 14, 40, 3
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, caches = E.ess_prefill(params, cfg, toks, pos, Smax, do_warmup=False)
    assert caches.paged == paged
    nxt = jax.random.randint(jax.random.key(2), (B, Q), 0, cfg.vocab_size)

    flat = E.ess_decode(params, cfg, nxt,
                        caches.lens[:, None] + jnp.arange(Q)[None], caches)

    c = caches
    seq_logits = []
    for q in range(Q):
        o = E.ess_decode(params, cfg, nxt[:, q:q + 1], c.lens[:, None], c)
        seq_logits.append(o.logits[:, 0])
        c = o.caches

    np.testing.assert_array_equal(np.array(flat.caches.lens),
                                  np.array(c.lens))
    for l in range(cfg.num_layers):
        np.testing.assert_array_equal(np.array(flat.caches.ikeys[l]),
                                      np.array(c.ikeys[l]))
    np.testing.assert_array_equal(np.array(flat.caches.host_latent),
                                  np.array(c.host_latent))
    # logits agree per position (same attended sets and values; fp-exact
    # here because the union attention is partition-invariant)
    for q in range(Q):
        np.testing.assert_allclose(np.array(flat.logits[:, q]),
                                   np.array(seq_logits[q]), atol=2e-2)
        np.testing.assert_array_equal(
            np.argmax(np.array(flat.logits[:, q]), -1),
            np.argmax(np.array(seq_logits[q]), -1))
    # the flattened lookup+admit left every pool map mirror-consistent
    for p in flat.caches.pools:
        assert LP.check_consistent(p)


def test_duplicate_miss_requests_admit_once():
    """Q>1 flattened lookups repeat positions across drafts.  Duplicate
    misses must share one miss-buffer rank (one fetch, one admit): a
    duplicate admit left a zombie forward entry whose eventual eviction
    clobbered the live duplicate's inverse link."""
    pool = LP.init_pool(1, 8, 32, 4, jnp.float32)
    ids = jnp.array([[5, 9, 5, 9, 2]], jnp.int32)    # 3 unique, 2 dups
    pool, lk, stats = LP.lookup(pool, ids, ids >= 0, max_misses=5, slot_mask=None)
    assert int(stats.misses[0]) == 3                 # unique fetch rows
    np.testing.assert_array_equal(np.array(lk.miss_ids[0]),
                                  [5, 9, 2, -1, -1])
    # duplicate requests point at the first occurrence's rank
    np.testing.assert_array_equal(np.array(lk.miss_rank[0, :5]),
                                  [0, 1, 0, 1, 2])
    pool = LP.admit(pool, lk.miss_ids,
                    jnp.arange(5 * 4, dtype=jnp.float32).reshape(1, 5, 4),
                    slot_mask=None)
    pool = LP.tick(pool)
    assert LP.check_consistent(pool)
    pids = np.array(pool.ids[0])
    assert (pids == 5).sum() == 1 and (pids == 9).sum() == 1


def test_invalidate_beyond_after_admit_consistent():
    """Rollback ordering contract: the verify step admits rows at draft
    positions, then ``invalidate_beyond`` drops everything >= the
    corrected lens — maps stay mirror-consistent and dropped positions
    MISS on re-lookup."""
    pool = LP.init_pool(1, 8, 32, 4, jnp.float32)
    ids = jnp.array([[3, 11, 12]], jnp.int32)        # 11, 12 = draft rows
    pool, lk, _ = LP.lookup(pool, ids, ids >= 0, max_misses=3, slot_mask=None)
    pool = LP.admit(pool, lk.miss_ids, jnp.ones((1, 3, 4)), slot_mask=None)
    pool = LP.tick(pool)
    pool = LP.invalidate_beyond(pool, jnp.array([11]))   # 1 draft accepted
    assert LP.check_consistent(pool)
    pool, lk2, st2 = LP.lookup(pool, ids, ids >= 0, max_misses=3, slot_mask=None)
    np.testing.assert_array_equal(np.array(lk2.hit[0]),
                                  [True, False, False])
    assert int(st2.misses[0]) == 2


# ---------------------------------------------------------------------------
# Serve-loop stream parity: MTP (and TBO) vs the Q=1 baseline
# ---------------------------------------------------------------------------

def _requests():
    return [Request(rid=0, prompt_len=10, max_new_tokens=5),
            Request(rid=1, prompt_len=8, max_new_tokens=3),
            Request(rid=2, prompt_len=12, max_new_tokens=6),
            Request(rid=3, prompt_len=9, max_new_tokens=4)]


def _run(params, cfg, reqs, **kw):
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=32, **kw)
    report = session.run(reqs, max_rounds=100)
    assert sorted(report.finished_rids) == sorted(r.rid for r in reqs)
    return session, report


def test_serve_mtp_stream_parity_greedy():
    """Acceptance criterion: an MTP-enabled ServeSession.run (depth 2,
    greedy) emits token streams bit-identical to the Q=1 baseline for
    every request."""
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    base, rb = _run(params, cfg, _requests())
    spec, rs = _run(params, cfg, _requests(), mtp_depth=2)
    assert base.outputs == spec.outputs
    assert all(len(v) == r.max_new_tokens
               for v, r in zip((base.outputs[i] for i in range(4)),
                               _requests()))
    assert rs.spec_rounds == rs.rounds > 0
    assert rs.drafted_tokens > 0
    assert rs.decode_tokens == rb.decode_tokens
    assert rs.rounds <= rb.rounds          # >= 1 token per verify round


def test_serve_mtp_tbo_stream_parity():
    """TBO composes with the speculative rounds: half-A's pool fetches
    overlap half-B's verify compute, page merge keeps both halves' D2H
    writes, and the emitted streams stay bit-identical."""
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    base, _ = _run(params, cfg, _requests())
    tbo_q1, _ = _run(params, cfg, _requests(), tbo=True)
    tbo_spec, rt = _run(params, cfg, _requests(), mtp_depth=2, tbo=True)
    assert base.outputs == tbo_q1.outputs
    assert base.outputs == tbo_spec.outputs
    assert rt.spec_rounds > 0


def test_serve_mtp_full_acceptance_and_budget_clamp():
    """Zero params make every draft match the model (all-argmax-0), so
    depth 2 emits exactly 3 tokens per live slot per round: rounds shrink
    ~3x, accept_rate is 1.0, and a request whose budget is not a multiple
    of 3 is clamped mid-round instead of over-running max_new_tokens."""
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)
    params = jax.tree.map(jnp.zeros_like,
                          init_params(jax.random.key(0), T.model_def(cfg)))
    reqs = [Request(rid=0, prompt_len=8, max_new_tokens=4),
            Request(rid=1, prompt_len=8, max_new_tokens=7)]
    base, rb = _run(params, cfg, [dataclasses.replace(r) for r in reqs])
    spec, rs = _run(params, cfg, [dataclasses.replace(r) for r in reqs],
                    mtp_depth=2)
    assert rs.accept_rate == 1.0
    assert rs.rounds < rb.rounds
    assert base.outputs == spec.outputs
    for r in reqs:
        assert len(spec.outputs[r.rid]) == r.max_new_tokens
    # the scheduler's generated counters never over-ran the budget, and
    # every recorded token was actually delivered (charge == delivery):
    # the stream = prefill first token + `generated` decode tokens
    assert all(req.generated + 1 == req.max_new_tokens
               == len(spec.outputs[req.rid])
               for req in spec.sched.finished)


def test_spec_round_mid_finish_leaves_freed_slot_untouched():
    """A slot finishing during a speculative round frees its pages and
    pool; subsequent spec rounds over the surviving slot must leave the
    freed slot's state and its released pages bit-untouched."""
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=32,
                             mtp_depth=2)
    reqs = [Request(rid=0, prompt_len=8, max_new_tokens=2),
            Request(rid=1, prompt_len=8, max_new_tokens=12)]
    for r in reqs:
        session.submit(r)
    for _ in range(30):
        session.step()
        if 0 in session.report.finished_rids or \
                any(rq.rid == 0 for rq in session.sched.finished):
            break
    assert any(rq.rid == 0 for rq in session.sched.finished)
    assert session.sched.running                  # rid=1 still decoding
    slot1 = session.sched.finished[0].slot        # may be None; find freed
    freed = [i for i, s in enumerate(session.sched.slots) if not s.active]
    assert len(freed) == 1
    f = freed[0]
    live = 1 - f
    host_before = np.array(session.caches.host_latent)
    live_pages = np.array(session.caches.block_tables[live])
    live_pages = set(live_pages[live_pages >= 0].tolist())
    for _ in range(3):                            # more spec rounds
        session.step()
    assert int(session.caches.lens[f]) == 0
    for p in session.caches.pools:
        assert (np.array(p.ids[f]) == -1).all()
    assert (np.array(session.caches.block_tables[f]) == -1).all()
    host_after = np.array(session.caches.host_latent)
    NP = host_after.shape[1]
    for pg in range(NP):
        if pg not in live_pages:                  # freed/released pages
            np.testing.assert_array_equal(host_after[:, pg],
                                          host_before[:, pg],
                                          err_msg=f"page {pg} touched")


# ---------------------------------------------------------------------------
# Per-request sampling through the serve loop
# ---------------------------------------------------------------------------

def test_serve_sampling_deterministic_and_mode_invariant():
    """temperature/top_k/top_p + a per-slot PRNG key thread through
    Request/ServeSession: streams are deterministic in the request seed,
    and identical between Q=1 and speculative modes (sampling slots
    force-reject drafts and draw from the exact Q=1 distribution with the
    same key)."""
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))

    def reqs():
        return [Request(rid=0, prompt_len=10, max_new_tokens=5),
                Request(rid=1, prompt_len=8, max_new_tokens=6,
                        temperature=0.8, top_k=64, seed=123)]

    a, ra = _run(params, cfg, reqs())
    b, _ = _run(params, cfg, reqs())
    assert a.outputs == b.outputs                 # keyed determinism
    spec, rs = _run(params, cfg, reqs(), mtp_depth=2)
    assert spec.outputs == a.outputs              # mode-invariant sampling
    assert rs.spec_rounds > 0
    greedy_all, _ = _run(params, cfg, [
        Request(rid=0, prompt_len=10, max_new_tokens=5),
        Request(rid=1, prompt_len=8, max_new_tokens=6)])
    assert greedy_all.outputs[0] == a.outputs[0]  # greedy slot unaffected
    assert greedy_all.outputs[1] != a.outputs[1]  # sampling engaged
