"""PD-disaggregated cluster: bitwise handoff parity and routing.

The migration contract is *bitwise*: a request prefilled on one worker
and decoded on another must produce the exact greedy (and seeded
sampling) stream a single engine produces, because the packet moves the
complete per-request state — host pages and scale planes verbatim in
the storage dtype, indexer keys, first token, MTP hidden — and the
decode round's per-slot math is independent of slot index and
co-residents.  These tests pin that across tiers (bf16/int8) and
speculation (Q=1 / mtp2), plus the lifecycle edges: abort mid-handoff
returns pages on both sides, preemption on a decode worker replays the
stream, and a full decode worker is routed around, never rejected.
"""

import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.cluster import EssCluster, InterNodeChannel
from repro.configs import get_config
from repro.distributed import compression as cmp
from repro.serving import scheduler as SCH
from repro.serving.api import EssEngine, SamplingParams
from repro.simulator import costmodel as CM

MAX_SEQ = 32

PROMPTS = [11, 8, 9, 10]
PARAMS = [SamplingParams(max_tokens=5),
          SamplingParams(max_tokens=4),
          SamplingParams(max_tokens=3, temperature=0.9, seed=5),
          SamplingParams(max_tokens=4)]


def _cfg(host_dtype="bf16"):
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    return dataclasses.replace(
        cfg, mtp_depth=2,
        ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0,
                                host_cache_dtype=host_dtype))


@functools.lru_cache(maxsize=None)
def _setup(host_dtype="bf16"):
    from repro.models import transformer as T
    from repro.models.params import init_params
    cfg = _cfg(host_dtype)
    return cfg, init_params(jax.random.key(0), T.model_def(cfg))


def _streams(outs):
    return [(o.tokens, o.finish_reason) for o in outs]


# ---------------------------------------------------------------------------
# bitwise stream parity: 1 prefill + 1 decode worker vs single engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("host_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("mtp_depth", [0, 2])
def test_pd_stream_parity_bitwise(host_dtype, mtp_depth):
    cfg, params = _setup(host_dtype)
    eng = EssEngine(params, cfg, num_slots=2, max_seq=MAX_SEQ,
                    mtp_depth=mtp_depth)
    single = _streams(eng.generate(PROMPTS, PARAMS, max_rounds=300))

    clu = EssCluster(params, cfg, num_prefill=1, num_decode=1,
                     num_slots=2, max_seq=MAX_SEQ, mtp_depth=mtp_depth)
    clustered = _streams(clu.generate(PROMPTS, PARAMS, max_rounds=300))

    assert clustered == single
    m = clu.metrics()
    assert m["migrations"] == len(PROMPTS) == m["installed"]
    assert m["wire_bytes"] > 0 and m["rejected"] == 0


# ---------------------------------------------------------------------------
# the quantized payload is the wire format — bits land verbatim
# ---------------------------------------------------------------------------

def test_migration_moves_quantized_pages_verbatim():
    """No dequant/requant round trip: the decode worker's host rows (and
    scale plane) for the prompt are bit-identical to the packet, which
    is itself one raw fetch of the prefill worker's rows."""
    cfg, params = _setup("int8")
    clu = EssCluster(params, cfg, num_prefill=1, num_decode=1,
                     num_slots=2, max_seq=MAX_SEQ,
                     channel=InterNodeChannel(delay_steps=1))
    captured = []
    real_send = clu.channel.send
    clu.channel.send = lambda pkt: (captured.append(pkt),
                                    real_send(pkt))[1]
    pre_alloc = clu.prefill[0].session.allocator
    total_prefill_pages = pre_alloc.free_pages
    rid = clu.submit(11, SamplingParams(max_tokens=4))
    guard = 50
    while not clu.decode[0].installed and guard:
        clu.step()
        guard -= 1
    assert guard and captured
    pkt = captured[0]
    assert pkt.pages.dtype == np.int8 and pkt.scales is not None
    # prefill released everything at pack — its slot recycled already
    assert pre_alloc.free_pages == total_prefill_pages

    s = clu.decode[0].session
    slot = next(i for i, sl in enumerate(s.sched.slots)
                if sl.active and sl.rid == rid)
    ids = np.asarray(s.allocator.owned(slot)[:pkt.n_pages])
    host = np.asarray(s.caches.host_latent[:, ids])
    scales = np.asarray(s.caches.host_scales[:, ids])
    rows_per_page = pkt.pages.shape[2]
    for p in range(pkt.n_pages):
        # only prompt rows: the decode round already appended past plen
        rows = min(max(pkt.prompt_len - p * rows_per_page, 0),
                   rows_per_page)
        np.testing.assert_array_equal(
            host[:, p, :rows], np.asarray(pkt.pages)[:, p, :rows])
        np.testing.assert_array_equal(
            scales[:, p, :rows], np.asarray(pkt.scales)[:, p, :rows])
    # wire accounting covers payload + scales + ikeys + hidden
    assert pkt.wire_bytes == cmp.wire_nbytes(
        pkt.pages, pkt.scales, pkt.hidden, *pkt.ikeys)


# ---------------------------------------------------------------------------
# lifecycle edges: abort mid-handoff, preempt on the decode worker
# ---------------------------------------------------------------------------

def test_abort_mid_handoff_frees_both_workers():
    cfg, params = _setup()
    clu = EssCluster(params, cfg, num_prefill=1, num_decode=1,
                     num_slots=2, max_seq=MAX_SEQ,
                     channel=InterNodeChannel(delay_steps=3))
    pa = clu.prefill[0].session.allocator
    da = clu.decode[0].session.allocator
    total_p, total_d = pa.free_pages, da.free_pages
    rid = clu.submit(11, SamplingParams(max_tokens=4))
    guard = 50
    while not clu.channel.in_flight and guard:
        clu.step()
        guard -= 1
    assert guard and clu.channel.in_flight
    assert pa.free_pages == total_p      # released at pack, not at abort
    assert clu.abort(rid)
    assert not clu.channel.in_flight
    assert clu.is_finished(rid) and clu.finish_reason(rid) == "abort"
    # both allocators whole again; the decode side never saw the request
    assert pa.free_pages == total_p and da.free_pages == total_d
    assert clu.decode[0].installed == 0
    assert not clu.has_work()
    evs = list(clu.stream(rid))
    assert evs and evs[-1].is_terminal
    assert clu.output(rid).finish_reason == "abort"
    assert clu.metrics()["aborted"] == 1


def test_preempt_on_decode_worker_replays_stream():
    """Preemption inside a decode worker re-queues and re-prefills
    *locally* (the worker has the cluster's prompt_fn); the regenerated
    stream replays from index 0 and still matches a single engine."""
    cfg, params = _setup()
    eng = EssEngine(params, cfg, num_slots=2, max_seq=MAX_SEQ)
    ref = eng.generate([11], [PARAMS[0]], max_rounds=300)[0]

    clu = EssCluster(params, cfg, num_prefill=1, num_decode=1,
                     num_slots=2, max_seq=MAX_SEQ)
    rid = clu.submit(11, PARAMS[0])
    guard = 50
    while len(clu._outputs.get(rid, [])) < 3 and guard:
        clu.step()
        guard -= 1
    assert guard and clu.decode[0].owns(rid)
    s = clu.decode[0].session
    slot = next(i for i, sl in enumerate(s.sched.slots)
                if sl.active and sl.rid == rid)
    s.preempt(slot)
    guard = 100
    while not clu.is_finished(rid) and guard:
        clu.step()
        guard -= 1
    assert guard
    out = clu.output(rid)
    assert (out.tokens, out.finish_reason) == (ref.tokens,
                                               ref.finish_reason)


# ---------------------------------------------------------------------------
# routing: byte-denominated placement, route-around, hold-and-retry
# ---------------------------------------------------------------------------

def test_pick_decode_worker_policy():
    L = SCH.WorkerLoad
    loads = [L(worker=0, free_host_bytes=100, free_slots=1, queued=0),
             L(worker=1, free_host_bytes=500, free_slots=1, queued=3),
             L(worker=2, free_host_bytes=500, free_slots=1, queued=1)]
    # most free bytes wins; byte tie breaks toward the lighter worker
    assert SCH.pick_decode_worker(loads, 50) == 2
    # byte-exhausted and slot-exhausted workers are filtered, not picked
    assert SCH.pick_decode_worker(
        [L(worker=0, free_host_bytes=10, free_slots=1, queued=0),
         L(worker=1, free_host_bytes=900, free_slots=0, queued=0)],
        50) is None
    assert SCH.pick_decode_worker([], 1) is None
    # full tie -> lowest index, deterministically
    even = [L(worker=0, free_host_bytes=64, free_slots=1, queued=2),
            L(worker=1, free_host_bytes=64, free_slots=1, queued=2)]
    assert SCH.pick_decode_worker(even, 1) == 0


def test_router_routes_around_full_worker():
    """A byte-exhausted decode worker is routed around — the request
    lands on the worker with headroom instead of being rejected."""
    cfg, params = _setup()
    clu = EssCluster(params, cfg, num_prefill=1, num_decode=2,
                     num_slots=2, max_seq=MAX_SEQ,
                     decode_overrides=[{"num_host_pages": 1}, None])
    outs = clu.generate([9, 10], SamplingParams(max_tokens=3),
                        max_rounds=300)
    assert all(o.finish_reason == "length" for o in outs)
    assert clu.decode[0].installed == 0
    assert clu.decode[1].installed == 2
    assert clu.metrics()["rejected"] == 0


# ---------------------------------------------------------------------------
# the simulated inter-node channel
# ---------------------------------------------------------------------------

class _FakePacket:
    def __init__(self, rid, nbytes):
        self.rid = rid
        self.wire_bytes = nbytes


def test_channel_delay_order_and_cancel():
    ch = InterNodeChannel(delay_steps=2)
    ch.send(_FakePacket(0, 10))
    ch.send(_FakePacket(1, 10))
    assert ch.tick() == []                       # step 1: still in flight
    assert [p.rid for p in ch.tick()] == [0, 1]  # step 2: send order
    ch.send(_FakePacket(5, 10))
    assert ch.cancel(5) and not ch.in_flight
    assert ch.tick() == [] and ch.tick() == []


def test_channel_costmodel_delay_quantizes_to_steps():
    model = CM.InterNodeModel(bandwidth=1e9, latency_s=0.0, row_bytes=1)
    ch = InterNodeChannel(model=model, step_time_s=1e-3)
    # 2 MB over 1 GB/s = 2 ms = 2 steps of 1 ms
    assert ch.delay_for(_FakePacket(0, 2_000_000)) == 2
    # latency floor: even a tiny packet takes at least one step
    assert ch.delay_for(_FakePacket(0, 1)) == 1
    ch.send(_FakePacket(0, 2_000_000))
    assert ch.sim_transfer_s == pytest.approx(2e-3)


def test_internode_costmodel_terms():
    from repro.simulator.hardware import H800_EP32 as hw
    m = CM.internode_model(hw)
    assert m.bandwidth > 0 and m.latency_s > 0
    t = CM.pd_migration_time_per_seq(hw, CM.ServeConfig())
    assert 0 < t < 1.0   # a handoff is sub-second on datacenter fabric


def test_wire_nbytes_skips_missing_planes():
    a = np.zeros((2, 3), np.int8)
    s = np.zeros((2, 1), np.float16)
    assert cmp.wire_nbytes(a, None, s) == a.nbytes + s.nbytes
