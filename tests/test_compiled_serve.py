"""Compiled serve round: donated StepPrograms over device-resident state.

Covers this PR's tentpole and satellites:

* **mode parity (the backbone)** — compiled and eager serve rounds emit
  bit-identical output streams: greedy and sampled, Q=1 and
  ``mtp_depth=2``, TBO on/off, paged and dense host tier;
* **in-device sampling** — ``sample_batch`` / ``sample_one`` (per-slot
  knob arrays, device-folded keys) draw the same tokens as the
  host-driven ``sample`` with static knobs;
* **one-fetch contract** — a compiled decode round performs exactly one
  ``jax.device_get`` (the packed ``RoundOut``);
* **recompile-count guard** — a mixed workload (admissions, preemption,
  ragged final prefill chunks, mtp on/off) traces each StepProgram
  exactly once per shape bucket;
* **donation** — the step consumes its input state: the previous round's
  ``host_latent`` buffer is deleted (no second copy retained) and no
  "donated buffers were not usable" warning fires;
* **charge/delivery alignment** — ``len(outputs[rid]) == generated + 1``
  at finish, including verify rounds clamped at the budget edge, and a
  ``max_new_tokens == 1`` request finishes at promotion.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving import step as SP
from repro.serving.sampling import request_key, sample, sample_batch
from repro.serving.scheduler import Request


def smoke_cfg(mtp_depth=None, **ess_overrides):
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    if ess_overrides:
        cfg = dataclasses.replace(
            cfg, ess=dataclasses.replace(cfg.ess, **ess_overrides))
    if mtp_depth is not None:
        cfg = dataclasses.replace(cfg, mtp_depth=mtp_depth)
    return cfg


def _requests():
    return [Request(rid=0, prompt_len=10, max_new_tokens=5),
            Request(rid=1, prompt_len=8, max_new_tokens=3),
            Request(rid=2, prompt_len=13, max_new_tokens=6),
            Request(rid=3, prompt_len=9, max_new_tokens=4,
                    temperature=0.8, top_k=64, top_p=0.95, seed=123)]


def _run(params, cfg, reqs, **kw):
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=32, **kw)
    report = session.run(reqs, max_rounds=120)
    assert sorted(report.finished_rids) == sorted(r.rid for r in reqs)
    return session, report


# ---------------------------------------------------------------------------
# Mode parity: compiled == eager, bit for bit (the refactor's backbone)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mtp_depth,tbo", [(0, False), (2, False),
                                           (0, True), (2, True)])
def test_compiled_eager_stream_parity(mtp_depth, tbo):
    """Greedy + sampled streams identical between compiled and eager
    modes at Q=1 and depth-2 speculative, TBO off and on (paged host
    tier); cache lens agree afterwards."""
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    c, rc = _run(params, cfg, _requests(), compiled=True,
                 mtp_depth=mtp_depth, tbo=tbo)
    e, re_ = _run(params, cfg, _requests(), compiled=False,
                  mtp_depth=mtp_depth, tbo=tbo)
    assert c.outputs == e.outputs
    assert rc.rounds == re_.rounds
    assert rc.decode_tokens == re_.decode_tokens
    np.testing.assert_array_equal(np.array(c.caches.lens),
                                  np.array(e.caches.lens))


def test_compiled_eager_parity_dense_host_tier():
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0, paged_host=False)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    c, _ = _run(params, cfg, _requests(), compiled=True, mtp_depth=2)
    e, _ = _run(params, cfg, _requests(), compiled=False, mtp_depth=2)
    assert not c.caches.paged
    assert c.outputs == e.outputs


def test_compiled_spec_equals_q1_baseline():
    """The fused speculative program preserves the PR-3 invariant:
    greedy + sampled streams are mode-invariant vs the Q=1 program."""
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    q1, _ = _run(params, cfg, _requests(), compiled=True)
    spec, rs = _run(params, cfg, _requests(), compiled=True, mtp_depth=2)
    assert q1.outputs == spec.outputs
    assert rs.spec_rounds == rs.rounds > 0


# ---------------------------------------------------------------------------
# In-device sampling == host-driven sampling
# ---------------------------------------------------------------------------

def test_sample_batch_matches_host_sample():
    logits = jax.random.normal(jax.random.key(0), (4, 64), jnp.float32)
    temps = [0.7, 1.3, 0.9, 1.0]
    ks = [8, None, 64, 3]            # 64 == V: no-op, like None
    ps = [None, 0.9, 0.6, None]
    seeds = [3, 11, 7, 5]
    idxs = [0, 4, 2, 9]
    ref = [int(sample(request_key(s, i), logits[r], t, k, p))
           for r, (s, i, t, k, p) in enumerate(zip(seeds, idxs, temps,
                                                   ks, ps))]
    got = sample_batch(
        jnp.asarray(seeds, jnp.int32), jnp.asarray(idxs, jnp.int32), logits,
        jnp.asarray(temps, jnp.float32),
        jnp.asarray([0 if k is None else k for k in ks], jnp.int32),
        jnp.asarray([1.0 if p is None else p for p in ps], jnp.float32))
    assert ref == [int(t) for t in got]
    # and identically under jit (the compiled round's actual context)
    got_j = jax.jit(sample_batch)(
        jnp.asarray(seeds, jnp.int32), jnp.asarray(idxs, jnp.int32), logits,
        jnp.asarray(temps, jnp.float32),
        jnp.asarray([0 if k is None else k for k in ks], jnp.int32),
        jnp.asarray([1.0 if p is None else p for p in ps], jnp.float32))
    assert ref == [int(t) for t in got_j]


# ---------------------------------------------------------------------------
# One fetch per round
# ---------------------------------------------------------------------------

def test_compiled_decode_round_single_device_get(monkeypatch):
    cfg = smoke_cfg(max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=32,
                             compiled=True)
    for r in [Request(rid=0, prompt_len=8, max_new_tokens=8),
              Request(rid=1, prompt_len=8, max_new_tokens=8)]:
        session.submit(r)
    session.step()                    # admit + prefill rid=0 (+1 fetch)
    session.step()                    # prefill rid=1 + first decode
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    for _ in range(3):                # steady-state decode-only rounds
        session.decode_round()
    assert len(calls) == 3            # exactly one packed fetch per round


# ---------------------------------------------------------------------------
# Recompile-count guard
# ---------------------------------------------------------------------------

def test_step_programs_compile_once_per_shape_bucket():
    """Mixed workload — admissions, a preemption, ragged final prefill
    chunks, mtp off and on — must trace each StepProgram exactly once
    per shape bucket.  Uses a max_seq unique to this test so the
    process-wide program cache starts cold for every key."""
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    MAXSEQ = 31                       # unique shape family for this test
    reqs = [Request(rid=0, prompt_len=11, max_new_tokens=5),  # ragged 3->C4
            Request(rid=1, prompt_len=8, max_new_tokens=4),   # exact bucket
            Request(rid=2, prompt_len=9, max_new_tokens=3,    # ragged 1->C1
                    temperature=0.9, seed=5),
            Request(rid=3, prompt_len=10, max_new_tokens=4)]  # ragged 2->C2
    SP.TRACE_COUNTS.clear()

    def drive(mtp_depth):
        s = E.ServeSession(params, cfg, num_slots=2, max_seq=MAXSEQ,
                           prefill_chunk=8, compiled=True,
                           mtp_depth=mtp_depth)
        for r in reqs:
            s.submit(dataclasses.replace(r))
        s.step(); s.step(); s.step()
        s.preempt(0)                  # mid-run preemption -> re-prefill
        rep = s.run(max_rounds=100)
        assert sorted(rep.finished_rids) == [0, 1, 2, 3]
        return s

    drive(0)
    drive(2)                          # same shapes, spec program added
    drive(0)                          # second Q=1 session: all cache hits
    sig = f"B2s{MAXSEQ}tbo0"
    mine = {k: v for k, v in SP.TRACE_COUNTS.items() if sig in k}
    assert mine, SP.TRACE_COUNTS
    assert all(v == 1 for v in mine.values()), mine
    # every round kind the workload exercised is present
    kinds = {k.split("/")[0] for k in mine}
    assert kinds == {"decode", "spec", "prefill"}
    # ragged chunks bucketed: prompt lens 11/8/9/12 at chunk 8 touch
    # buckets 8 (non-last and last) and the pow2 pads 1, 2, 4
    pre = {k for k in mine if k.startswith("prefill/")}
    assert any(f"prefill/C8last0/{sig}d0k0" in k for k in pre), pre
    for c in (1, 2, 4, 8):
        assert any(k.startswith(f"prefill/C{c}last1/") for k in pre), \
            (c, pre)


# ---------------------------------------------------------------------------
# Donation: the step consumes its input state
# ---------------------------------------------------------------------------

def _donation_supported() -> bool:
    a = jnp.arange(4.0)
    jax.jit(lambda x: x + 1, donate_argnums=0)(a)
    return a.is_deleted()


def test_step_donates_state_no_second_host_latent():
    if not _donation_supported():
        pytest.skip("backend does not support buffer donation")
    cfg = smoke_cfg(max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    session = E.ServeSession(params, cfg, num_slots=2, max_seq=32,
                             compiled=True)
    for r in [Request(rid=0, prompt_len=8, max_new_tokens=6),
              Request(rid=1, prompt_len=8, max_new_tokens=6)]:
        session.submit(r)
    session.step(); session.step()
    prev = session.state
    # donation-safe layout: no two state leaves share a device buffer
    from repro.cache import latent_cache as LC
    assert LC.buffers_distinct(prev)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        session.decode_round()
    # the donated input buffers are gone — XLA aliased the host tier in
    # place instead of keeping a second copy...
    assert prev.caches.host_latent.is_deleted()
    assert prev.tok.is_deleted()
    # ...and every donated leaf was actually usable (an unusable donation
    # would fall back to a copy and warn)
    assert not [x for x in w if "donated" in str(x.message).lower()], \
        [str(x.message) for x in w]
    # the session's live state is the program's output, not the donated input
    assert not session.state.caches.host_latent.is_deleted()


# ---------------------------------------------------------------------------
# Charge/delivery alignment at the budget edge
# ---------------------------------------------------------------------------

def test_emit_charge_equals_delivery_at_budget_edge():
    """Full-acceptance depth-2 rounds emit 3 tokens/round; a budget not
    ≡ 0 (mod 3) forces a clamped final round.  Every recorded token must
    be in the stream: len(outputs) == generated + 1 at finish."""
    cfg = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)
    params = jax.tree.map(jnp.zeros_like,
                          init_params(jax.random.key(0), T.model_def(cfg)))
    reqs = [Request(rid=0, prompt_len=8, max_new_tokens=6),   # 5 = 3+2 clamp
            Request(rid=1, prompt_len=8, max_new_tokens=8)]   # 7 = 3+3+1
    spec, _ = _run(params, cfg, reqs, compiled=True, mtp_depth=2)
    for req in spec.sched.finished:
        assert len(spec.outputs[req.rid]) == req.generated + 1
        assert len(spec.outputs[req.rid]) == req.max_new_tokens


def test_max_new_tokens_one_finishes_at_promotion():
    """The prefill first token is the whole budget: the request finishes
    at its first token with an empty decode charge.  The first token is
    fetched via the promotion round's single packed device_get (one-fetch
    contract), so exactly one decode round runs — and delivers nothing
    beyond the first token."""
    cfg = smoke_cfg(max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    session = E.ServeSession(params, cfg, num_slots=1, max_seq=32,
                             compiled=True)
    report = session.run([Request(rid=0, prompt_len=8, max_new_tokens=1)],
                         max_rounds=10)
    assert report.finished_rids == [0]
    assert session.outputs[0] and len(session.outputs[0]) == 1
    assert report.rounds == 1         # the t0-carrying round only
    assert report.decode_tokens == 0  # ...which delivered no decode token


def test_ttft_submit_stamp_unconditional():
    """ttft_s derives from the unconditional submit stamp — a missing
    rid raises instead of silently reporting ~0 TTFT."""
    cfg = smoke_cfg(max_miss_ratio=1.0)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    session = E.ServeSession(params, cfg, num_slots=1, max_seq=32)
    session.run([Request(rid=7, prompt_len=8, max_new_tokens=2)],
                max_rounds=20)
    assert 7 in session._submit_time
    assert session.report.ttft_s[7] > 0.0
    # bypassing submit() (no stamp) must surface at delivery, not as a
    # ~0-second TTFT
    s2 = E.ServeSession(params, cfg, num_slots=1, max_seq=32)
    s2.sched.submit(Request(rid=9, prompt_len=8, max_new_tokens=2))
    with pytest.raises(KeyError):
        s2.run(max_rounds=20)
