"""Simulator fidelity vs the paper's published numbers (the faithful
reproduction gate): Table 2, headline improvements, figure shapes."""

import numpy as np
import pytest

from repro.simulator import experiments as E
from repro.simulator import locality, lru_sim
from repro.simulator.costmodel import ServeConfig, max_feasible_batch
from repro.simulator.hardware import H800_EP32


def test_headline_improvements_within_band():
    h = E.headline_improvements()
    # 32K: paper +69.4 % — reproduce within ±15 points
    assert abs(h["improvement_32k_pct"] - 69.4) < 15.0, h
    # 128K: paper +123 % — qualitative: large, and larger than 32K
    assert h["improvement_128k_pct"] > 80.0, h
    assert h["improvement_128k_pct"] > h["improvement_32k_pct"]


def test_table2_rows_within_tolerance():
    rows = E.table2()
    assert len(rows) == 18
    devs = [abs(r["dev_pct"]) for r in rows]
    assert np.median(devs) < 10.0, devs      # actual: ~4.8 %
    assert max(devs) < 25.0, devs            # actual: ~20.3 % (MTP=4 rows)


def test_fig1_batch_ceiling_and_monotonic_growth():
    rows = E.fig1_throughput_vs_batch()
    cap_feasible = [r["batch"] for r in rows if r["feasible_on_gpu"]]
    # paper §2.1: ceiling ~52 on the H800 config
    assert 40 <= max(cap_feasible) <= 64, cap_feasible
    thr = [r["throughput"] for r in rows]
    # throughput increases with batch (allow small saturation wiggle)
    assert thr[-1] > 1.5 * thr[0]


def test_fig2_similarity_band():
    rows = E.fig2_similarity(ctx_list=(32768,), layers=(0, 8, 24, 48))
    sims = [r["similarity_mean"] for r in rows]
    assert all(0.55 <= s <= 1.0 for s in sims), sims
    assert np.mean(sims) > 0.85            # paper: "consistent and high"


def test_fig4_warmup_kills_cold_spike():
    w = E.fig4_warmup(steps=24)
    cold0 = w["before_warmup"][0]
    warm0 = w["after_warmup"][0]
    assert cold0 > 10 * max(warm0, 1)       # the Figure-4 spike
    # steady state comparable
    assert abs(np.mean(w["before_warmup"][8:]) -
               np.mean(w["after_warmup"][8:])) < 50


def test_fig5_layer_variability_range():
    rows = E.fig5_miss_by_layer(ratios=(0.2,))
    r = rows[0]
    # paper: 16.66 .. 605 at ratio 0.2 — reproduce the order of magnitude
    assert r["miss_min"] < 60
    assert r["miss_max"] > 150
    assert r["miss_max"] / max(r["miss_min"], 1e-9) > 5


def test_fig7_da_dba_crossover():
    rows = E.fig7_overlap_comparison()
    by_miss = {r["miss"]: r for r in rows}
    # low miss: DA <= DBA (no split overhead pays off)
    assert by_miss[32]["da_ms"] <= by_miss[32]["dba_ms"]
    # high miss (paper: 512): DBA wins
    assert by_miss[512]["dba_ms"] < by_miss[512]["da_ms"]
    # both beat no-overlap at high miss
    assert by_miss[512]["dba_ms"] < by_miss[512]["none_ms"]


def test_fig9_miss_decreases_with_context():
    rows = E.fig8_9_miss_vs_context(ratios=(0.2,),
                                    ctxs=(8192, 32768, 131072))
    miss = {r["context"]: r["miss_mean"] for r in rows}
    assert miss[131072] <= miss[32768] <= miss[8192] * 1.5


def test_flashtrans_bandwidth_effect():
    f = E.flashtrans_comparison()
    # paper: 0.79 GB/s -> 37 GB/s = ~47x on H2D
    assert 30 <= f["speedup"] <= 60, f


def test_memory_ceilings_match_paper_operating_points():
    m = E.memory_analysis()
    assert 40 <= m["ctx32768_ratio1.0"] <= 64          # paper: 52
    assert m["ctx32768_ratio0.2"] >= 128               # paper runs 160@0.21
    assert m["ctx131072_ratio0.1"] >= 50               # paper runs 54@0.1
    assert m["ctx131072_ratio1.0"] <= 16               # paper: 13


def test_lru_sim_warmup_monotone_in_ratio():
    m_small = lru_sim.miss_profile(32768, 0.1, layers=4, steps=16).mean()
    m_big = lru_sim.miss_profile(32768, 0.6, layers=4, steps=16).mean()
    assert m_big < m_small


def test_locality_trace_similarity_matches_churn():
    tr = locality.make_trace(32, 8192, layer=3)
    sim = locality.similarity_of_trace(tr)
    churn = locality.layer_churn(3)
    assert abs((1 - sim.mean()) - churn) < 0.12


def test_v5e_projection_ess_wins_more_on_smaller_hbm():
    """On the 16 GB deployment target the memory wall is harsher, so ESS
    must buy at least as much as on the paper's 80 GB H800s."""
    rows = E.v5e_projection()
    by_ctx = {r["context"]: r for r in rows}
    assert by_ctx[32768]["improvement_pct"] > 60
    assert by_ctx[131072]["improvement_pct"] > 100
    for r in rows:
        assert r["batch_ess"] > 2 * r["batch_base"]
