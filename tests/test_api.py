"""Public serving API: `EssEngine` front-end over the re-entrant engine
core.

Covers this PR's tentpole and satellites:

* **stream parity** — ``generate()`` over the PR-4 parity workload
  matrix (greedy + sampled requests, Q=1 and mtp2, TBO off/on, paged and
  dense host tier, compiled and eager) emits streams bit-identical to
  the compat ``ServeSession.run`` shim;
* **abort lifecycle** — abort mid-prefill and mid-decode (greedy and
  mtp2, paged) restores the allocator's free-page count and the
  pool-entry count to pre-admission values, and the recycled slot
  replays a fresh identical request bit-identically to a fresh engine;
* **stop-token truncation** — a stop inside a speculative round cuts
  the stream exactly at the stop position and rolls back the
  over-accepted suffix: the slot's lens/pool state at release equals a
  Q=1 run that never drafted past the stop (deterministic full
  acceptance via permutation-structured params);
* **rejected / budget terminals** — oversize and page-unservable
  requests surface as ``finish_reason="rejected"`` events + a
  ServeReport counter; ``run(max_rounds=...)`` exhaustion emits
  ``finish_reason="budget"`` for every stranded rid, and every
  submitted rid ends with exactly one terminal event;
* **priority admission** — higher priority admitted first, stable FIFO
  within a class, preempted requests re-enter ahead of their class;
* **stream() generator + metrics()** — incremental consumption ends at
  the terminal event; TokenEvent timestamps yield TTFT / inter-token
  percentiles.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving.api import EssEngine, SamplingParams, latency_stats
from repro.serving.scheduler import Request, Scheduler


def smoke_cfg(mtp_depth=None, **ess_overrides):
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    if ess_overrides:
        cfg = dataclasses.replace(
            cfg, ess=dataclasses.replace(cfg.ess, **ess_overrides))
    if mtp_depth is not None:
        cfg = dataclasses.replace(cfg, mtp_depth=mtp_depth)
    return cfg


@pytest.fixture(scope="module")
def cfg():
    return smoke_cfg(mtp_depth=2, max_miss_ratio=1.0)


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.key(0), T.model_def(cfg))


# the PR-4 parity workload: 3 greedy + 1 sampled request
_WORKLOAD = [(10, dict(max_tokens=5)),
             (8, dict(max_tokens=3)),
             (13, dict(max_tokens=6)),
             (9, dict(max_tokens=4, temperature=0.8, top_k=64,
                      top_p=0.95, seed=123))]


def _api_workload():
    return ([p for p, _ in _WORKLOAD],
            [SamplingParams(**kw) for _, kw in _WORKLOAD])


def _legacy_requests():
    return [Request(rid=i, prompt_len=p, max_new_tokens=kw["max_tokens"],
                    temperature=kw.get("temperature", 0.0),
                    top_k=kw.get("top_k"), top_p=kw.get("top_p"),
                    seed=kw.get("seed"))
            for i, (p, kw) in enumerate(_WORKLOAD)]


# ---------------------------------------------------------------------------
# Stream parity: generate() == ServeSession.run, bit for bit
# ---------------------------------------------------------------------------

def _check_parity(params, cfg, *, engine_kw=None, session_kw=None):
    prompts, sps = _api_workload()
    eng = EssEngine(params, cfg, num_slots=2, max_seq=32,
                    **(engine_kw or {}))
    outs = eng.generate(prompts, sps, max_rounds=120)
    ses = E.ServeSession(params, cfg, num_slots=2, max_seq=32,
                         **(session_kw or engine_kw or {}))
    rep = ses.run(_legacy_requests(), max_rounds=120)
    assert sorted(rep.finished_rids) == [0, 1, 2, 3]
    assert [o.tokens for o in outs] == [ses.outputs[i] for i in range(4)]
    assert [o.finish_reason for o in outs] == ["length"] * 4
    # exactly one terminal event per rid on both paths
    assert sorted(eng.session._terminal) == [0, 1, 2, 3]
    assert sorted(ses._terminal) == [0, 1, 2, 3]
    return eng, ses


@pytest.mark.parametrize("mtp_depth,tbo", [(0, False), (2, False),
                                           (0, True), (2, True)])
def test_generate_stream_parity_vs_run(cfg, params, mtp_depth, tbo):
    """Acceptance criterion: the front-end's ``generate()`` emits streams
    bit-identical to the compat ``ServeSession.run`` across the PR-4
    matrix cells (greedy + sampled requests in the workload)."""
    _check_parity(params, cfg,
                  engine_kw=dict(mtp_depth=mtp_depth, tbo=tbo))


def test_generate_stream_parity_eager(cfg, params):
    """Front-end over the eager (op-by-op) path vs the compiled compat
    shim — one comparison covers both facade parity and mode parity."""
    _check_parity(params, cfg,
                  engine_kw=dict(mtp_depth=2, compiled=False),
                  session_kw=dict(mtp_depth=2, compiled=True))


def test_generate_stream_parity_dense_host_tier(params):
    cfg_d = smoke_cfg(mtp_depth=2, max_miss_ratio=1.0, paged_host=False)
    eng, _ = _check_parity(params, cfg_d, engine_kw=dict(mtp_depth=2))
    assert not eng.session.caches.paged


# ---------------------------------------------------------------------------
# Abort: resource restoration + bit-identical recycled-slot replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mtp_depth", [0, 2])
def test_abort_restores_resources_and_recycled_slot_replays(
        cfg, params, mtp_depth):
    """Abort mid-prefill and mid-decode (greedy Q=1 and mtp2, paged):
    free pages and free pool entries return to pre-admission values, the
    aborted slot is fully unmapped/reset, and a fresh identical request
    on the recycled slot replays bit-identically to a fresh engine."""
    prompt_a = [int(t) for t in jax.random.randint(
        jax.random.key(21), (16,), 0, cfg.vocab_size)]
    prompt_b = [int(t) for t in jax.random.randint(
        jax.random.key(22), (8,), 0, cfg.vocab_size)]
    eng = EssEngine(params, cfg, num_slots=2, max_seq=32,
                    mtp_depth=mtp_depth, prefill_chunk=4)
    assert eng.session.paged
    free0 = eng.session.allocator.free_pages
    pool0 = eng.session.free_pool_entries

    # --- mid-prefill abort -------------------------------------------------
    r0 = eng.submit(prompt_a, SamplingParams(max_tokens=4))
    eng.step()                        # admit + first 4-token chunk of 16
    slot = eng.session.sched.running[r0].slot
    task = eng.session._prefill[slot]
    assert 0 < task.cursor < len(prompt_a)          # genuinely mid-prefill
    assert eng.session.allocator.free_pages < free0
    assert eng.abort(r0)
    assert eng.session.allocator.free_pages == free0
    assert eng.session.free_pool_entries == pool0
    assert slot not in eng.session._prefill
    assert (np.array(eng.session.caches.block_tables[slot]) == -1).all()
    assert int(eng.session.caches.lens[slot]) == 0
    assert eng.finish_reason(r0) == "abort"
    assert eng.output(r0).tokens == []

    # --- mid-decode abort --------------------------------------------------
    r1 = eng.submit(prompt_b, SamplingParams(max_tokens=20))
    for _ in range(40):
        eng.step()
        if len(eng.session.outputs.get(r1, [])) >= 3:
            break
    assert len(eng.session.outputs[r1]) >= 3        # decoding, mid-flight
    slot1 = eng.session.sched.running[r1].slot
    assert eng.abort(r1)
    assert eng.session.allocator.free_pages == free0
    assert eng.session.free_pool_entries == pool0
    for p in eng.session.caches.pools:
        assert (np.array(p.ids[slot1]) == -1).all()
        assert (np.array(p.slot_of[slot1]) == -1).all()
    assert eng.finish_reason(r1) == "abort"
    assert 3 <= eng.output(r1).n_generated < 20     # cut mid-generation

    # --- recycled slot replays bit-identically to a fresh engine ----------
    r2 = eng.submit(prompt_b, SamplingParams(max_tokens=6))
    for _ in range(60):
        if eng.is_finished(r2):
            break
        eng.step()
    fresh = EssEngine(params, cfg, num_slots=2, max_seq=32,
                      mtp_depth=mtp_depth, prefill_chunk=4)
    [o_fresh] = fresh.generate([prompt_b], SamplingParams(max_tokens=6),
                               max_rounds=60)
    assert eng.output(r2).tokens == o_fresh.tokens
    assert eng.output(r2).finish_reason == "length"


# ---------------------------------------------------------------------------
# Stop-token truncation inside a speculative round
# ---------------------------------------------------------------------------

def _permutation_params(cfg):
    """Zeroed params with a permutation head: every layer contributes
    exactly zero (zero projection weights), so the backbone maps token
    ``t`` to ``argmax(rmsnorm(E_t) @ U^T) = perm[t]`` — and the MTP
    draft modules (``proj`` = select-the-embedding-half) compute the
    *identical* function, so acceptance is deterministically full and
    the stream is a non-constant permutation walk.  This makes an MTP
    verify round provably draft past a chosen stop position."""
    base = jax.tree.map(jnp.zeros_like,
                        init_params(jax.random.key(0), T.model_def(cfg)))
    V, d = cfg.vocab_size, cfg.d_model
    emb = jax.random.normal(jax.random.key(1), (V, d), cfg.param_dtype)
    perm = jax.random.permutation(jax.random.key(2), V)
    base["embed"] = emb
    base["unembed"] = emb[jnp.argsort(perm)]
    proj = jnp.zeros((cfg.mtp_depth, 2 * d, d), cfg.param_dtype)
    proj = proj.at[:, d:, :].set(jnp.eye(d, dtype=cfg.param_dtype))
    base["mtp"]["proj"] = proj
    return base


def _run_with_release_snapshot(params, cfg, req, *, mtp_depth, snap):
    """Drive one request to completion, capturing the slot's lens and
    resident pool-id sets at the instant of release (post-truncation,
    pre-reset)."""
    s = E.ServeSession(params, cfg, num_slots=1, max_seq=48,
                       mtp_depth=mtp_depth)
    inner = s.sched.release_hook

    def capture(slot):
        snap["lens"] = int(np.array(s.caches.lens)[slot])
        snap["ids"] = [np.sort(ids[ids >= 0])
                       for ids in (np.array(p.ids[slot])
                                   for p in s.caches.pools)]
        inner(slot)

    s.sched.release_hook = capture
    r = s.run([req], max_rounds=60)
    return s, r


def test_stop_token_truncates_within_spec_round(cfg):
    """Acceptance criterion: a stop-token request's output ends exactly
    at the stop position, and its slot's lens/pool state (snapshotted at
    release) equals a Q=1 run that never drafted past the stop."""
    params = _permutation_params(cfg)
    sb, rb = _run_with_release_snapshot(
        params, cfg, Request(rid=0, prompt_len=10, max_new_tokens=9),
        mtp_depth=2, snap={})
    stream = sb.outputs[0]
    assert rb.accept_rate == 1.0                 # construction holds
    assert len(set(stream)) == len(stream)       # permutation walk
    # stream[0] = prefill token; the first verify round emits [1], [2],
    # [3] — stop at index 2 cuts that round after 2 of its 3 tokens
    stop = stream[2]

    snap_spec, snap_q1 = {}, {}
    sA, _ = _run_with_release_snapshot(
        params, cfg, Request(rid=0, prompt_len=10, max_new_tokens=9,
                             stop_token_ids=(stop,)),
        mtp_depth=2, snap=snap_spec)
    assert sA.outputs[0] == stream[:3]           # ends AT the stop
    assert sA.sched.finished[0].finish_reason == "stop"
    assert sA._terminal == {0: "stop"}
    term = [e for e in sA.token_events if e.is_terminal]
    assert len(term) == 1 and term[0].index == 3

    sB, _ = _run_with_release_snapshot(
        params, cfg, Request(rid=0, prompt_len=10, max_new_tokens=9,
                             stop_token_ids=(stop,)),
        mtp_depth=0, snap=snap_q1)
    assert sB.outputs[0] == stream[:3]
    # lens/pool state at release: the truncated speculative slot ==
    # the Q=1 slot that never drafted past the stop
    assert snap_spec["lens"] == snap_q1["lens"] == 10 + 2
    for a, b in zip(snap_spec["ids"], snap_q1["ids"]):
        np.testing.assert_array_equal(a, b)
        assert (a < snap_spec["lens"]).all()     # nothing beyond the stop

    # EOS on the prefill's first token finishes the stream at index 0;
    # the token rides the promotion round's packed fetch (one-fetch
    # contract), so that round is the only decode round and delivers
    # nothing beyond the first token
    sE = E.ServeSession(params, cfg, num_slots=1, max_seq=48, mtp_depth=2)
    rE = sE.run([Request(rid=0, prompt_len=10, max_new_tokens=9,
                         eos_token_ids=(stream[0],))], max_rounds=20)
    assert sE.outputs[0] == stream[:1]
    assert sE._terminal == {0: "stop"}
    assert rE.rounds == 1                        # the t0-carrying round
    assert rE.decode_tokens == 0


# ---------------------------------------------------------------------------
# Rejected + budget terminal records
# ---------------------------------------------------------------------------

def test_rejected_requests_surface_with_terminal_events(cfg, params):
    """Oversize (vs max_seq) and page-unservable requests end with a
    ``rejected`` terminal event and count in ServeReport.rejected —
    instead of silently vanishing from the scheduler."""
    eng = EssEngine(params, cfg, num_slots=1, max_seq=32, num_host_pages=1)
    # needs 2 pages (28 rows at 16 rows/page) > 1-page pool: submit-time
    r_pages = eng.submit(20, SamplingParams(max_tokens=8))
    assert eng.is_finished(r_pages)
    assert eng.finish_reason(r_pages) == "rejected"
    # prompt + max_tokens > max_seq: rejected at admission
    r_big = eng.submit(30, SamplingParams(max_tokens=8))
    r_ok = eng.submit(8, SamplingParams(max_tokens=2))
    for _ in range(40):
        if not eng.has_work():
            break
        eng.step()
    assert eng.finish_reason(r_big) == "rejected"
    assert eng.finish_reason(r_ok) == "length"
    assert eng.session.report.rejected == 2
    assert eng.output(r_big).tokens == []
    terms = [e for e in eng.session.token_events if e.is_terminal]
    assert sorted(e.rid for e in terms) == sorted([r_pages, r_big, r_ok])


def test_run_budget_exhaustion_emits_budget_terminals(cfg, params):
    """``ServeSession.run`` hitting max_rounds no longer strands
    unfinished requests: each one (running *and* still queued) gets a
    ``budget`` terminal, resources return, and every submitted rid ends
    with exactly one terminal event."""
    ses = E.ServeSession(params, cfg, num_slots=1, max_seq=32)
    reqs = [Request(rid=0, prompt_len=8, max_new_tokens=12),
            Request(rid=1, prompt_len=8, max_new_tokens=12)]  # stays queued
    rep = ses.run(reqs, max_rounds=4)
    assert ses._terminal == {0: "budget", 1: "budget"}
    assert rep.finish_reasons == {0: "budget", 1: "budget"}
    assert rep.aborted == 2
    assert 0 < len(ses.outputs[0]) < 12            # partial stream kept
    terms = [e for e in ses.token_events if e.is_terminal]
    assert sorted(e.rid for e in terms) == [0, 1]
    assert ses.allocator.free_pages == ses.num_pages   # pages reclaimed
    assert not ses.sched.running and not ses.sched.queue


# ---------------------------------------------------------------------------
# Priority-aware admission (host-only)
# ---------------------------------------------------------------------------

def _finish_running(s: Scheduler, slot: int) -> None:
    s.promote(slot)
    done = s.record_tokens({slot: 1})
    assert done


def test_priority_admission_fifo_within_class():
    """Higher priority admitted first; stable FIFO within a class; a
    preempted request re-enters ahead of its class (deterministic in
    (priority, submission order))."""
    s = Scheduler(num_slots=1, max_seq=64)
    s.submit(Request(rid=0, prompt_len=4, max_new_tokens=2))
    assert [r.rid for _, r in s.admit()] == [0]
    s.submit(Request(rid=1, prompt_len=4, max_new_tokens=2))
    s.submit(Request(rid=2, prompt_len=4, max_new_tokens=2, priority=5))
    s.submit(Request(rid=3, prompt_len=4, max_new_tokens=2, priority=5))
    _finish_running(s, 0)
    assert [r.rid for _, r in s.admit()] == [2]   # highest class first
    _finish_running(s, 0)
    assert [r.rid for _, r in s.admit()] == [3]   # FIFO within the class
    # a preempted request jumps its class's line
    s.submit(Request(rid=4, prompt_len=4, max_new_tokens=2))
    s.preempt(0)                                  # rid=3 back to the queue
    assert [r.rid for _, r in s.admit()] == [3]
    _finish_running(s, 0)
    assert [r.rid for _, r in s.admit()] == [1]   # class 0, FIFO: 1 then 4
    _finish_running(s, 0)
    assert [r.rid for _, r in s.admit()] == [4]


def test_scheduler_abort_queued_and_running():
    s = Scheduler(num_slots=1, max_seq=64)
    s.submit(Request(rid=0, prompt_len=4, max_new_tokens=4))
    s.submit(Request(rid=1, prompt_len=4, max_new_tokens=4))
    s.admit()
    assert s.abort(1)                             # queued: just removed
    assert s.abort(0)                             # running: slot released
    assert not s.abort(7)                         # unknown rid
    assert sorted(r.rid for r in s.finished) == [0, 1]
    assert all(r.finish_reason == "abort" for r in s.finished)
    assert not s.running and not s.queue
    assert not s.slots[0].active


# ---------------------------------------------------------------------------
# stream() generator + metrics()
# ---------------------------------------------------------------------------

def test_stream_generator_and_latency_metrics(cfg, params):
    eng = EssEngine(params, cfg, num_slots=2, max_seq=32)
    r0 = eng.submit(8, SamplingParams(max_tokens=4))
    r1 = eng.submit(8, SamplingParams(max_tokens=3))
    evs = list(eng.stream(r0))
    assert [e.token for e in evs[:-1]] == eng.output(r0).tokens
    assert [e.index for e in evs] == [0, 1, 2, 3, 4]
    assert evs[-1].is_terminal and evs[-1].finish_reason == "length"
    assert all(a.t <= b.t for a, b in zip(evs, evs[1:]))
    # a consumed stream yields nothing further
    assert list(eng.stream(r0)) == []
    for _ in range(20):
        if not eng.has_work():
            break
        eng.step()
    m = eng.metrics()
    assert m["finish_reasons"] == {r0: "length", r1: "length"}
    assert m["ttft_p50_s"] > 0 and m["ttft_p95_s"] >= m["ttft_p50_s"]
    assert m["itl_p50_s"] >= 0 and m["n_token_events"] == 7
    # latency_stats is pure over the event log
    again = latency_stats(eng.session.token_events,
                          eng.session._submit_time)
    assert again == {k: m[k] for k in again}
