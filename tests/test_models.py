"""Per-arch smoke tests: reduced configs of every assigned family run one
train forward + prefill/decode consistency on CPU, asserting shapes + no
NaNs (instructions: FULL configs are exercised only via the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.params import count_params, init_params

SMOKES = ["qwen3-0.6b-smoke", "gemma2-27b-smoke", "gemma3-27b-smoke",
          "qwen1.5-110b-smoke", "dbrx-132b-smoke", "deepseek-v3-671b-smoke",
          "mamba2-780m-smoke", "zamba2-7b-smoke", "qwen2-vl-7b-smoke",
          "whisper-large-v3-smoke", "deepseek-v32-exp-ess-smoke"]


def _inputs(cfg, B, S, key):
    kw = {}
    if cfg.embedding_inputs and cfg.family != "audio":
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "audio":
        kw["enc_inputs"] = jax.random.normal(
            jax.random.key(9), (B, cfg.encdec.encoder_seq, cfg.d_model),
            jnp.bfloat16)
    if cfg.mrope_sections is not None:
        kw["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3))
    return inputs, kw


@pytest.mark.parametrize("name", SMOKES)
def test_train_forward_shapes_no_nan(name):
    cfg = get_config(name)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S = 2, 32
    inputs, kw = _inputs(cfg, B, S, jax.random.key(1))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out = T.forward(params, cfg, inputs, pos, mode="train", **kw)
    assert out.logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(out.logits).any())


@pytest.mark.parametrize("name", [n for n in SMOKES
                                  if n != "deepseek-v32-exp-ess-smoke"])
def test_prefill_decode_consistent_with_train(name):
    cfg = get_config(name)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 16, 24
    inputs, kw = _inputs(cfg, B, S + 1, jax.random.key(1))
    pos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
    ref = T.forward(params, cfg, inputs, pos, mode="train", **kw).logits[:, -1]

    first = inputs[:, :S]
    if "mrope_positions" in kw:
        kw = dict(kw)
        kw["mrope_positions"] = kw["mrope_positions"][:, :S]
    pf = T.forward(params, cfg, first, pos[:, :S], mode="prefill", **kw)
    caches = pf.caches

    def pad_seq(x):
        if x.ndim >= 3 and x.shape[2] == S:
            padc = [(0, 0)] * x.ndim
            padc[2] = (0, Smax - S)
            return jnp.pad(x, padc)
        return x

    for k in ["kv", "mla", "shared_kv"]:
        if caches is not None and k in caches:
            caches[k] = jax.tree.map(pad_seq, caches[k])
    kw.pop("mrope_positions", None)
    dec = T.forward(params, cfg, inputs[:, S:S + 1], pos[:, S:S + 1],
                    mode="decode", caches=caches, **kw)
    err = float(jnp.max(jnp.abs(dec.logits[:, -1] - ref)))
    scale = float(jnp.max(jnp.abs(ref)))
    assert err < 2e-2 + 2e-2 * scale, (name, err, scale)


def test_full_config_param_counts():
    """Exact full configs instantiate abstractly with plausible sizes."""
    expect = {"qwen3-0.6b": (0.4e9, 1.2e9),
              "qwen1.5-110b": (95e9, 125e9),
              "gemma2-27b": (22e9, 32e9),
              "gemma3-27b": (22e9, 32e9),
              "dbrx-132b": (115e9, 145e9),
              "deepseek-v3-671b": (600e9, 720e9),
              "qwen2-vl-7b": (6e9, 9e9),
              "mamba2-780m": (0.6e9, 1.0e9),
              "zamba2-7b": (6e9, 9e9),
              "whisper-large-v3": (1.2e9, 2.2e9)}
    for name, (lo, hi) in expect.items():
        cfg = get_config(name)
        n = count_params(T.model_def(cfg))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_sliding_window_masks_differ():
    """gemma2 local layers must not attend beyond the window."""
    cfg = get_config("gemma2-27b-smoke")
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S = 1, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    base = T.forward(params, cfg, toks, pos, mode="train").logits
    # perturb a token far outside the window (w=16) of the last position
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab_size)
    pert = T.forward(params, cfg, toks2, pos, mode="train").logits
    # last-position logits DO change (global layers see everything)
    assert float(jnp.abs(pert[0, -1] - base[0, -1]).max()) > 0
    # but early positions before the perturbed token are identical (causal)
    np.testing.assert_allclose(np.array(pert[0, 1]), np.array(base[0, 1]))


def test_mamba2_chunked_matches_sequential():
    from repro.models import ssm as S
    b, s, h, p, n = 2, 64, 4, 8, 16
    x = jax.random.normal(jax.random.key(0), (b, s, h, p))
    a_dt = -jnp.abs(jax.random.normal(jax.random.key(1), (b, s, h))) * 0.1
    B_ = jax.random.normal(jax.random.key(2), (b, s, 1, n))
    C_ = jax.random.normal(jax.random.key(3), (b, s, 1, n))
    y1, h1 = S.ssd_chunked(x, a_dt, B_, C_, chunk=16)
    y2, h2 = S.ssd_sequential(x, a_dt, B_, C_)
    np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.array(h1), np.array(h2), rtol=1e-4,
                               atol=1e-4)


def test_moe_routing_invariants():
    from repro.models import moe as MoE
    cfg = get_config("dbrx-132b-smoke")
    p = init_params(jax.random.key(0), MoE.moe_def(cfg))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    y, aux = MoE.moe_apply(p, cfg, x, train=True)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    assert 0.0 <= float(aux.dropped_fraction) < 1.0
    # capacity 0 tokens would all drop; generous capacity drops none
    import dataclasses
    cfg_big = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    _, aux2 = MoE.moe_apply(p, cfg_big, x, train=True)
    assert float(aux2.dropped_fraction) == 0.0


def test_deepseek_router_bias_selection_only():
    """Aux-loss-free bias shifts selection but not combine weights."""
    from repro.models import moe as MoE
    cfg = get_config("deepseek-v3-671b-smoke")
    p = init_params(jax.random.key(0), MoE.moe_def(cfg))
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model)) * 0.5
    y1, _ = MoE.moe_apply(p, cfg, x)
    # huge bias on expert 0 -> it gets selected everywhere
    p2 = dict(p)
    p2["router_bias"] = p["router_bias"] + jnp.array(
        [1e3] + [0.0] * (cfg.moe.num_experts - 1))
    y2, _ = MoE.moe_apply(p2, cfg, x)
    assert float(jnp.abs(y1 - y2).max()) > 0   # selection changed
