"""Bounds-based parity for the quantized (int8/fp8) host latent tier.

Quantization breaks bitwise parity with the bf16 tier by construction, so
these tests pin *bounds* instead: the per-element roundtrip error is
scale-limited, greedy streams on the smoke workload match exactly (the
quantization noise is far below the model's decision margins), MTP
acceptance stays within 2% absolute of the bf16 run, and the donated
EngineState grows exactly the scale leaves and nothing else.  The ESS106
jaxpr audit proves the dequant is gather-sized in every StepProgram.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_audit as JA
from repro.configs import get_config
from repro.distributed import compression as cmp

QDTYPES = list(cmp.CACHE_QUANT_DTYPES.items())


def _cfgs():
    cfg = dataclasses.replace(get_config("deepseek-v32-exp-ess-smoke"),
                              mtp_depth=2)
    qcfg = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, host_cache_dtype="int8"))
    return cfg, qcfg


# ---------------------------------------------------------------------------
# roundtrip bounds (reference quantizer as used by the tier)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,dt", QDTYPES)
def test_roundtrip_error_is_scale_bounded(name, dt):
    x = jax.random.normal(jax.random.key(0), (6, 33, 40),
                          jnp.float32).astype(jnp.bfloat16)
    q, s = cmp.quantize_rows(x, dt)
    assert q.dtype == dt and s.dtype == cmp.SCALE_DTYPE
    assert s.shape == (6, 33, 1)
    deq = cmp.dequantize_rows(q, s, jnp.float32)
    err = np.abs(np.array(deq) - np.array(x, np.float32))
    sf = np.array(s, np.float32)
    if name == "int8":
        # |x - deq| <= scale/2 per element (round-to-nearest on the
        # stored-scale grid; the f16 scale rounding is inside the grid)
        bound = sf * 0.5 + 1e-6
    else:
        # e4m3: 3 mantissa bits -> relative error <= 2^-4 of the scaled
        # magnitude, plus the subnormal step at the bottom of the range
        bound = (np.abs(np.array(x, np.float32)) * 2.0 ** -4
                 + sf * 2.0 ** -9 + 1e-6)
    assert (err <= bound).all(), float((err - bound).max())


def test_roundtrip_bf16_rows_land_on_grid():
    # dequantizing to bf16 then re-quantizing with the *stored* scale is
    # idempotent — the quantize-once commit path relies on this grid
    x = jax.random.normal(jax.random.key(1), (4, 16), jnp.float32)
    q, s = cmp.quantize_rows(x.astype(jnp.bfloat16), jnp.int8)
    deq = cmp.dequantize_rows(q, s, jnp.float32)
    q2 = jnp.clip(jnp.round(deq / jnp.where(
        s.astype(jnp.float32) > 0, s.astype(jnp.float32), 1.0)),
        -127, 127).astype(jnp.int8)
    np.testing.assert_array_equal(np.array(q), np.array(q2))


# ---------------------------------------------------------------------------
# serve parity bounds (greedy streams + MTP acceptance)
# ---------------------------------------------------------------------------

def _run(cfg, mtp_depth=0, max_tokens=6):
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.api import EssEngine, SamplingParams
    params = init_params(jax.random.key(0), T.model_def(cfg))
    eng = EssEngine(params, cfg, num_slots=2, max_seq=32,
                    mtp_depth=mtp_depth)
    outs = eng.generate([10] * 4, SamplingParams(max_tokens=max_tokens),
                        max_rounds=200)
    assert all(o.finish_reason == "length" for o in outs)
    return [o.tokens for o in outs], eng.session

def test_greedy_streams_match_bf16():
    cfg, qcfg = _cfgs()
    toks_b, sess_b = _run(cfg)
    toks_q, sess_q = _run(qcfg)
    # documented drift bound for the smoke workload: exact match — the
    # int8 roundtrip error is far below the greedy decision margins
    assert toks_b == toks_q
    assert sess_b.report.rounds == sess_q.report.rounds
    # and the byte accounting reflects the tier dtype (42 vs 80 B/row)
    assert sess_q.report.host_bytes_per_row < sess_b.report.host_bytes_per_row


def test_mtp_acceptance_within_2pct_of_bf16():
    cfg, qcfg = _cfgs()
    toks_b, sess_b = _run(cfg, mtp_depth=2, max_tokens=8)
    toks_q, sess_q = _run(qcfg, mtp_depth=2, max_tokens=8)
    assert toks_b == toks_q          # greedy verify keeps streams equal
    ab, aq = sess_b.report.accept_rate, sess_q.report.accept_rate
    assert sess_b.report.spec_rounds > 0
    assert abs(ab - aq) <= 0.02, (ab, aq)


def test_host_tier_rows_drift_is_scale_bounded():
    """After a real serve run the quantized tier's dequantized rows sit
    within one quantization step (plus computational drift) of the bf16
    tier's rows — the cache-level form of the bounded-logit-drift story."""
    from repro.cache import latent_cache as LC
    cfg, qcfg = _cfgs()
    _, sess_b = _run(cfg)
    _, sess_q = _run(qcfg)
    rows_b = np.array(LC.slot_latents(sess_b.caches, 0), np.float32)
    rows_q = np.array(LC.slot_latents(sess_q.caches, 0), np.float32)
    amax = np.abs(rows_b).max(axis=-1, keepdims=True)
    err = np.abs(rows_b - rows_q)
    # one int8 step is amax/127; allow 2 steps for drift accumulated
    # through the layers plus bf16 output rounding
    assert (err <= amax * (2.0 / 127.0) + 1e-5).all(), \
        float((err / np.maximum(amax, 1e-9)).max())


# ---------------------------------------------------------------------------
# donated state shape: exactly the scale leaves join
# ---------------------------------------------------------------------------

def test_engine_state_gains_only_scale_leaves():
    cfg, qcfg = _cfgs()
    for prefetch, extra in ((0, 1), (4, 2)):   # host_scales, +staged_scales
        sb = JA._abstract_state(cfg, 2, 32, prefetch)
        sq = JA._abstract_state(qcfg, 2, 32, prefetch)
        assert (len(jax.tree.leaves(sq))
                == len(jax.tree.leaves(sb)) + extra)
    # the slab-rows positional contract survives the insertion
    from repro.analysis import contracts as C
    sq = JA._abstract_state(qcfg, 2, 32, 4)
    rows = jax.tree.leaves(sq)[C.ESS105_STAGED_ROWS_LEAF]
    assert rows.dtype == jnp.int8 and rows.ndim == 4


def test_quantized_programs_donate_all_leaves():
    _, qcfg = _cfgs()
    targets = JA.build_targets(qcfg, mtp_depth=0, prefill_chunk=1)
    assert JA.audit_donation(targets=targets) == []


# ---------------------------------------------------------------------------
# ESS106: dequant is gather-sized
# ---------------------------------------------------------------------------

def test_ess106_clean_on_quantized_programs():
    _, qcfg = _cfgs()
    targets = JA.build_targets(qcfg, mtp_depth=2, prefill_chunk=2)
    assert JA.audit_tier_dequant(targets=targets) == []


def test_ess106_flags_bf16_tier_as_unquantized():
    cfg, _ = _cfgs()
    targets = JA.build_targets(cfg, mtp_depth=0, prefill_chunk=1)
    fs = JA.audit_tier_dequant(targets=targets)
    assert fs and all(f.rule == "ESS106" for f in fs)
    assert "no quantized state leaf" in fs[0].message


def test_ess106_checker_flags_tier_sized_dequant():
    fs = JA.check_tier_dequants("decode", [(4096, "int8", "bfloat16")],
                                threshold=4096)
    assert [f.rule for f in fs] == ["ESS106"]
    assert "4096" in fs[0].message and fs[0].scope == "decode"
    assert JA.check_tier_dequants("decode", [], 4096) == []


def test_find_big_dequants_on_synthetic_jaxpr():
    big = jax.ShapeDtypeStruct((64, 64), jnp.int8)

    def widen(q):
        return q.astype(jnp.bfloat16) * 2.0

    jaxpr = jax.make_jaxpr(widen)(big)
    assert JA.find_big_dequants(jaxpr, 64 * 64) \
        == [(64 * 64, "int8", "bfloat16")]
    assert JA.find_big_dequants(jaxpr, 64 * 64 + 1) == []

    def stays_narrow(q):
        return q + jnp.int8(1)

    assert JA.find_big_dequants(
        jax.make_jaxpr(stays_narrow)(big), 1) == []


# ---------------------------------------------------------------------------
# byte-denominated admission (dtype-aware, not raw page counts)
# ---------------------------------------------------------------------------

def test_byte_budget_floors_pages_by_storage_dtype():
    from repro.cache import latent_cache as LC
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.engine import ServeSession
    cfg, qcfg = _cfgs()
    params = init_params(jax.random.key(0), T.model_def(cfg))
    budget = 4 * LC.host_page_bytes(qcfg, qcfg.param_dtype)
    sb = ServeSession(params, cfg, num_slots=2, max_seq=32,
                      host_byte_budget=budget)
    sq = ServeSession(params, qcfg, num_slots=2, max_seq=32,
                      host_byte_budget=budget)
    assert sb.num_pages == budget // LC.host_page_bytes(cfg, cfg.param_dtype)
    assert sq.num_pages == 4
    assert sq.num_pages >= 2 * sb.num_pages
    # same byte budget -> same byte ceiling, whatever the dtype
    assert (sq.num_pages * sq.host_page_bytes <= budget
            and sb.num_pages * sb.host_page_bytes <= budget)


def test_admission_blocks_on_bytes_not_pages():
    from repro.cache import latent_cache as LC
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.engine import ServeSession
    from repro.serving.scheduler import Request
    cfg, qcfg = _cfgs()
    params = init_params(jax.random.key(0), T.model_def(qcfg))
    budget = 2 * LC.host_page_bytes(qcfg, qcfg.param_dtype)
    # a third slot is free, so the *byte* gate is what must block rid=2
    s = ServeSession(params, qcfg, num_slots=3, max_seq=32,
                     host_byte_budget=budget)
    s.submit(Request(rid=0, prompt_len=6, max_new_tokens=4))   # 1 page
    s.submit(Request(rid=1, prompt_len=6, max_new_tokens=4))   # 1 page
    s.submit(Request(rid=2, prompt_len=6, max_new_tokens=4))   # blocked
    s.step_round()
    assert len(s.sched.running) == 2
    assert any("host bytes" in e for e in s.report.events)
    s.run(max_rounds=100)           # frees pages; rid=2 completes too
    assert not s.sched.running and not s.sched.queue
