"""End-to-end behaviour of the paper's system: ESS serving loop produces
the same greedy continuation as the monolithic model, with decreasing miss
counts (temporal locality, paper §2.2) — plus the layer-wise overlap
policy (paper §3.3)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.policy import (OverlapCosts, choose_layerwise, dba_threshold,
                               exposed_da, exposed_dba)
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving import engine as E
from repro.serving.sampling import greedy


def test_ess_greedy_continuation_matches_monolithic():
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    cfg = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax, NEW = 2, 20, 48, 5
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    # --- monolithic greedy continuation ------------------------------------
    pf = T.forward(params, cfg, toks, pos, mode="prefill")
    cm = pf.caches
    cm["mla"] = jax.tree.map(
        lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, Smax - S), (0, 0))),
        cm["mla"])
    tok_m = greedy(pf.logits[:, -1])
    mono = [np.array(tok_m)]
    caches_m = cm
    for i in range(NEW - 1):
        o = T.forward(params, cfg, tok_m[:, None],
                      caches_m["lens"][:, None], mode="decode",
                      caches=caches_m)
        caches_m = o.caches
        tok_m = greedy(o.logits[:, -1])
        mono.append(np.array(tok_m))

    # --- ESS greedy continuation -------------------------------------------
    lg, caches = E.ess_prefill(params, cfg, toks, pos, Smax, do_warmup=False)
    tok = greedy(lg[:, -1])
    ess = [np.array(tok)]
    miss_hist = []
    for i in range(NEW - 1):
        o = E.ess_decode(params, cfg, tok[:, None], caches.lens[:, None],
                         caches)
        caches = o.caches
        tok = greedy(o.logits[:, -1])
        ess.append(np.array(tok))
        miss_hist.append(int(np.array(o.stats["misses"]).sum()))

    np.testing.assert_array_equal(np.stack(mono), np.stack(ess))
    # temporal locality: later steps miss less than the first
    assert miss_hist[-1] <= miss_hist[0]


def test_layerwise_policy_picks_dba_for_heavy_layers():
    # block_bytes folds the per-GPU batch (160 seqs x 656 B per miss)
    c = OverlapCosts(t_attn0=3e-4, t_preattn=2e-4, t_indexer=8e-4,
                     t_split_overhead=5e-5, fetch_bw=37e9,
                     block_bytes=656 * 160)
    thr = dba_threshold(c)
    assert 0 < thr < 4096
    # below threshold DA, above DBA
    profile = np.array([thr // 2, thr * 2, 16, 4000])
    plan = choose_layerwise(profile, c)
    assert plan == ["da", "dba", "da", "dba"]
    # exposed time monotonicity
    assert exposed_da(c, 0) == 0.0
    assert exposed_dba(c, 4096) < exposed_da(c, 4096) + c.t_split_overhead


def test_ess_decode_with_kernels_matches_jnp_path():
    cfg = get_config("deepseek-v32-exp-ess-smoke")
    cfg = dataclasses.replace(
        cfg, ess=dataclasses.replace(cfg.ess, max_miss_ratio=1.0))
    params = init_params(jax.random.key(0), T.model_def(cfg))
    B, S, Smax = 2, 16, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, caches = E.ess_prefill(params, cfg, toks, pos, Smax, do_warmup=False)
    nxt = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)
    o_jnp = E.ess_decode(params, cfg, nxt, caches.lens[:, None], caches,
                         use_kernel=False)
    o_krn = E.ess_decode(params, cfg, nxt, caches.lens[:, None], caches,
                         use_kernel=True)
    np.testing.assert_allclose(np.array(o_krn.logits),
                               np.array(o_jnp.logits), atol=3e-2)
