"""Generalized ESS for GQA archs: Quest block selection + pooled attention."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lru_pool as LP
from repro.core import quest as Q


def _mk(B=2, S=64, KV=2, H=4, D=16, block=8, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    k = jax.random.normal(ks[0], (B, S, KV, D))
    v = jax.random.normal(ks[1], (B, S, KV, D))
    q = jax.random.normal(ks[2], (B, H, D))
    return q, k, v


def test_quest_upper_bound_is_sound():
    """ub(q, block) >= max true score inside the block (the Quest invariant)."""
    q, k, v = _mk()
    block = 8
    meta = Q.build_block_meta(k, block)
    valid = jnp.ones(meta.kmin.shape[:2], bool)
    sc = Q.quest_scores(q, meta, valid)                  # [B,NB]
    groups = q.shape[1] // k.shape[2]
    kk = jnp.repeat(k, groups, axis=2)
    true = jnp.einsum("bhd,bshd->bhs", q, kk)            # [B,H,S]
    B, NB = sc.shape
    tb = true.reshape(B, q.shape[1], NB, block).max(axis=(1, 3))
    assert bool((np.array(sc) >= np.array(tb) - 1e-4).all())


def test_quest_selection_captures_softmax_mass():
    q, k, v = _mk(S=128, seed=3)
    block, topb = 8, 8                                   # keep 1/2 of blocks
    lens = jnp.array([128, 96])
    meta = Q.build_block_meta(k, block)
    ids, bvalid = Q.quest_topk_blocks(q, meta, lens, block, topb)
    rec = Q.attention_recall(q, k, lens, ids, bvalid, block, 0.25)
    # gaussian keys are a worst case for Quest (scores nearly uniform);
    # still: selecting 1/2 of blocks must beat the 1/2 mass baseline even
    # on the worst head, and beat random selection on average
    assert float(rec.min()) > 0.35
    assert float(rec.mean()) > 0.5
    rids = jax.random.randint(jax.random.key(9), ids.shape, 0, 128 // block)
    rrec = Q.attention_recall(q, k, lens, rids, bvalid, block, 0.25)
    assert float(rec.mean()) > float(rrec.mean())


def test_quest_attention_exact_over_selection():
    """With ALL blocks selected, quest attention == full attention."""
    q, k, v = _mk(S=32)
    block = 8
    lens = jnp.array([32, 24])
    meta = Q.build_block_meta(k, block)
    ids, bvalid = Q.quest_topk_blocks(q, meta, lens, block, topb=4)
    out = Q.gqa_sparse_attention(q, k, v, ids, bvalid, lens, block, 0.25)
    groups = q.shape[1] // k.shape[2]
    kk = jnp.repeat(k, groups, axis=2)
    vv = jnp.repeat(v, groups, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q, kk) * 0.25
    valid = jnp.arange(32)[None] < lens[:, None]
    s = jnp.where(valid[:, None], s, -2e38)
    w = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bhs,bshd->bhd", w, vv)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=1e-4)


def test_quest_blocks_pool_roundtrip():
    """Selected blocks flow through the same LRU pool (page granularity)."""
    B, S, KV, D, block = 1, 64, 2, 16, 8
    q, k, v = _mk(B=B, S=S, KV=KV, D=D)
    lens = jnp.array([64])
    meta = Q.build_block_meta(k, block)
    ids, bvalid = Q.quest_topk_blocks(q, meta, lens, block, topb=4)
    pool = LP.init_pool(B, 6, S // block, block * KV * D * 2)
    pool, lk, st1 = LP.lookup(pool, ids, bvalid, max_misses=4, slot_mask=None)
    rows = jnp.zeros((B, 4, block * KV * D * 2))
    pool = LP.admit(pool, lk.miss_ids, rows, slot_mask=None)
    pool = LP.tick(pool)
    pool, lk2, st2 = LP.lookup(pool, ids, bvalid, max_misses=4, slot_mask=None)
    assert int(st1.misses[0]) > 0 and int(st2.misses[0]) == 0


def test_incremental_meta_update_matches_rebuild():
    q, k, v = _mk(S=32)
    block = 8
    meta = Q.build_block_meta(k, block)
    k_new = jax.random.normal(jax.random.key(7), (2, 2, 16))
    pos = jnp.array([32 - 8, 16])            # land inside existing blocks
    k2 = k.at[jnp.arange(2), pos].set(
        jnp.minimum(k[jnp.arange(2), pos], k_new))  # only extremes change
    upd = Q.update_block_meta(meta, k_new, pos, block)
    # updated min is <= rebuilt min (update only widens the envelope)
    reb = Q.build_block_meta(k.at[jnp.arange(2), pos].set(k_new), block)
    assert bool((np.array(upd.kmin) <= np.array(meta.kmin) + 1e-6).all())
    assert bool((np.array(upd.kmax) >= np.array(meta.kmax) - 1e-6).all())
    np.testing.assert_allclose(np.array(upd.kmin),
                               np.minimum(np.array(meta.kmin),
                                          np.array(reb.kmin)), atol=1e-6)
