"""Training substrate: optimizer, checkpoint fault tolerance, loop resume,
gradient accumulation equivalence, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import compression as C
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models.params import init_params
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, make_batch
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, lr_at)
from repro.training.train_loop import LoopConfig, train_loop


def _setup(steps=0):
    cfg = get_config("qwen3-0.6b-smoke", param_dtype=jnp.float32)
    params = init_params(jax.random.key(0), T.model_def(cfg))
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=50, warmup_steps=5)
    return cfg, params, opt_cfg


def test_adamw_decreases_loss():
    cfg, params, opt_cfg = _setup()
    step = jax.jit(make_train_step(cfg, opt_cfg))
    opt = init_opt_state(params)
    dc = DataConfig(cfg.vocab_size, global_batch=8, seq_len=64)
    losses = []
    for i in range(12):
        params, opt, m = step(params, opt, make_batch(dc, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_grad_accumulation_matches_full_batch():
    cfg, params, opt_cfg = _setup()
    dc = DataConfig(cfg.vocab_size, global_batch=8, seq_len=32)
    batch = make_batch(dc, 0)
    opt = init_opt_state(params)
    s1 = make_train_step(cfg, opt_cfg, accum_steps=1)
    s4 = make_train_step(cfg, opt_cfg, accum_steps=4)
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(diffs)) < 5e-3


def test_lr_schedule():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(lr_at(c, jnp.array(0))) < 0.2
    assert float(lr_at(c, jnp.array(10))) == pytest.approx(1.0, abs=0.1)
    assert float(lr_at(c, jnp.array(100))) == pytest.approx(0.1, abs=0.02)


def test_checkpoint_roundtrip_and_integrity(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}
    d = ckpt.save(str(tmp_path), 7, tree)
    assert d.endswith("step_00000007")
    back = ckpt.restore(str(tmp_path), None, tree)
    np.testing.assert_allclose(np.array(back["a"]), np.array(tree["a"]))
    # corruption detection
    import glob
    shard = glob.glob(os.path.join(d, "*.npy"))[0]
    arr = np.load(shard)
    np.save(shard, arr + 1)
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 7, tree)


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert len(kept) == 2


def test_train_loop_resumes_from_checkpoint(tmp_path):
    cfg, params, opt_cfg = _setup()
    step = jax.jit(make_train_step(cfg, opt_cfg))
    opt = init_opt_state(params)
    dc = DataConfig(cfg.vocab_size, global_batch=4, seq_len=32)
    loop1 = LoopConfig(total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
                       log_every=100)
    p1, o1, st1 = train_loop(step, params, opt, dc, loop1,
                             log=lambda *_: None)
    assert st1.step == 6
    # "crash" and resume: a fresh loop starting from scratch picks up step 6
    loop2 = LoopConfig(total_steps=10, ckpt_every=100,
                       ckpt_dir=str(tmp_path), log_every=100)
    p2, o2, st2 = train_loop(step, params, opt, dc, loop2,
                             log=lambda *_: None)
    assert st2.step == 10
    # deterministic data: running 10 steps in one go equals 6+4 resumed
    loopX = LoopConfig(total_steps=10, ckpt_every=100,
                       ckpt_dir=str(tmp_path) + "_x", log_every=100)
    pX, _, _ = train_loop(step, params, opt, dc, loopX, log=lambda *_: None)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p2, pX)
    assert max(jax.tree.leaves(d)) < 1e-5


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint saved unsharded restores with a per-leaf sharding_fn."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(str(tmp_path), 1, tree)
    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev)
    out = ckpt.restore(str(tmp_path), 1, tree,
                       sharding_fn=lambda key, shape: sh)
    assert out["w"].sharding == sh


def test_data_pipeline_determinism_and_host_sharding():
    dc = DataConfig(vocab_size=100, global_batch=8, seq_len=16)
    b1 = make_batch(dc, 3)
    b2 = make_batch(dc, 3)
    np.testing.assert_array_equal(np.array(b1["inputs"]),
                                  np.array(b2["inputs"]))
    h0 = make_batch(dc, 3, host_id=0, num_hosts=2)
    h1 = make_batch(dc, 3, host_id=1, num_hosts=2)
    assert h0["inputs"].shape[0] == 4
    assert not np.array_equal(np.array(h0["inputs"]),
                              np.array(h1["inputs"]))
    # labels are next-token shifted inputs
    np.testing.assert_array_equal(np.array(b1["inputs"][:, 1:]),
                                  np.array(b1["labels"][:, :-1]))


def test_int8_compression_error_feedback():
    g = {"w": jax.random.normal(jax.random.key(0), (64, 64))}
    ef = C.init_ef(g)
    err = float(C.compression_error(g, ef))
    assert err < 0.02                          # int8 per-tensor ~0.5 % rms
    # error feedback: the residual carries exactly the quantization error
    q, s, ef2 = C.compress_grads(g, ef)
    deq = C.decompress_grads(q, s)
    np.testing.assert_allclose(np.array(ef2.residual["w"]),
                               np.array(g["w"] - deq["w"]), rtol=1e-5,
                               atol=1e-6)
    # over rounds, accumulated transmitted mass approaches the true sum
    total = jnp.zeros_like(g["w"])
    ef = C.init_ef(g)
    for _ in range(8):
        q, s, ef = C.compress_grads(g, ef)
        total = total + C.decompress_grads(q, s)["w"]
    np.testing.assert_allclose(np.array(total / 8), np.array(g["w"]),
                               atol=0.02)
