"""esslint: golden contract audits + lint rule fixtures.

Two halves:

* **jaxpr audit goldens** — the real StepPrograms (paged + dense)
  satisfy the donation, dtype, one-fetch and retrace contracts; and the
  pure checkers flag synthetic violations (so a reintroduced bug turns
  the CI job red, not just a test here).
* **lint fixtures** — one snippet per rule triggering exactly one
  finding, the negative twin triggering none, suppression comments, the
  baseline mechanics, and the CLI's exit codes.
"""

import json
import pathlib
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import jaxpr_audit as JA
from repro.analysis import lint as L
from repro.analysis.findings import (Finding, findings_to_json,
                                     load_baseline,
                                     split_against_baseline,
                                     write_baseline)
from repro.analysis.__main__ import main as cli_main

REPO = pathlib.Path(__file__).resolve().parents[1]


def _lint(src, relpath="repro/serving/fixture.py", **cfg_overrides):
    return L.lint_source(textwrap.dedent(src), relpath,
                         L.fixture_config(**cfg_overrides))


def _rules(findings):
    return [f.rule for f in findings]


# ===========================================================================
# jaxpr audit: goldens over the real programs
# ===========================================================================

@pytest.fixture(scope="module")
def paged_targets():
    return JA.build_targets(JA._smoke_cfg(paged=True))


@pytest.fixture(scope="module")
def dense_targets():
    return JA.build_targets(JA._smoke_cfg(paged=False))


def test_targets_cover_all_round_kinds(paged_targets):
    kinds = {t.kind.split("/")[0] for t in paged_targets}
    assert kinds == {"decode", "spec", "prefill"}
    # ragged buckets: pow2 chunks up to prefill_chunk, mid + last each
    pre = [t.kind for t in paged_targets if t.kind.startswith("prefill/")]
    assert len(pre) == 2 * 4                      # C1,C2,C4,C8 x last0/1


def test_donation_golden_paged(paged_targets):
    assert JA.audit_donation(targets=paged_targets) == []


def test_donation_golden_dense(dense_targets):
    assert JA.audit_donation(targets=dense_targets) == []


def test_dtype_golden_paged(paged_targets):
    assert JA.audit_dtypes(targets=paged_targets) == []


def test_dtype_golden_dense(dense_targets):
    assert JA.audit_dtypes(targets=dense_targets) == []


def test_donation_detects_undonated_program():
    """A jit *without* donation over a state-shaped pytree lowers with
    zero aliasing attrs — the audit must flag it."""
    state = {"a": jax.ShapeDtypeStruct((8, 8), jnp.bfloat16),
             "b": jax.ShapeDtypeStruct((8,), jnp.int32)}
    fn = jax.jit(lambda p, s: ({"a": s["a"] + 1, "b": s["b"]}, p))
    text = fn.lower(jax.ShapeDtypeStruct((), jnp.float32),
                    state).as_text()
    n_aliased = text.count("tf.aliasing_output")
    assert n_aliased == 0
    findings = JA.check_donation("decode", n_aliased,
                                 len(jax.tree.leaves(state)), [])
    assert _rules(findings) == ["ESS101"]
    assert "2/2" not in findings[0].message      # 0/2 aliased


def test_donation_detects_unusable_warning():
    findings = JA.check_donation(
        "spec", 36, 36,
        ["Some donated buffers were not usable: f32[4]"])
    assert _rules(findings) == ["ESS101"]
    assert "unusable" in findings[0].message


def test_dtype_checker_flags_drift():
    fs = JA.check_state_dtypes("decode", ["bfloat16", "int32"],
                               ["float32", "int32"])
    assert _rules(fs) == ["ESS104"]
    assert "bfloat16 -> float32" in fs[0].message
    assert JA.check_state_dtypes("decode", ["bfloat16"], ["bfloat16"]) == []
    # leaf-count change is its own failure, not a zip truncation
    assert _rules(JA.check_state_dtypes("decode", ["bfloat16"],
                                        [])) == ["ESS104"]


def test_find_big_upcasts_positive_and_threshold():
    def f(x):
        return x.astype(jnp.float32) + 1.0

    big = jax.ShapeDtypeStruct((1024,), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(f)(big)
    assert JA.find_big_upcasts(jaxpr, threshold=1024) == [
        (1024, "bfloat16", "float32")]
    assert JA.find_big_upcasts(jaxpr, threshold=2048) == []


def test_fetch_checker_budget_and_total():
    assert JA.check_fetch_counts([1, 1, 0, 1], rounds=3) == []
    over = JA.check_fetch_counts([2, 1], rounds=3)
    assert "ESS102" in _rules(over)              # per-round budget blown
    mismatch = JA.check_fetch_counts([1, 1], rounds=1)
    assert _rules(mismatch) == ["ESS102"]        # total != rounds


def test_retrace_checker():
    assert JA.check_retrace(
        {"decode/x": 1, "spec/x": 1, "prefill/C8last1/x": 1}) == []
    fs = JA.check_retrace({"decode/x": 2, "spec/x": 1,
                           "prefill/C1last1/x": 1})
    assert _rules(fs) == ["ESS103"]
    assert "2x" in fs[0].message
    # a workload that never exercised a round kind is a coverage failure
    fs = JA.check_retrace({"decode/x": 1})
    assert any("never traced" in f.message for f in fs)
    # an empty delta map means the driver itself is broken
    assert _rules(JA.check_retrace({})) == ["ESS103"]


@pytest.mark.parametrize("paged,mtp_depth", [(True, 0), (False, 2)])
def test_fetch_golden_real_session(paged, mtp_depth):
    """The live serve loop holds the one-fetch contract end to end —
    Q=1 on the paged tier, fused-spec rounds on the dense tier (between
    them: decode, spec and prefill rounds on both host tiers)."""
    assert JA.audit_fetch_counts(JA._smoke_cfg(paged=paged),
                                 mtp_depth=mtp_depth) == []


def test_fetch_audit_catches_leaky_session():
    """A session sneaking a second device_get into its decode round is
    caught — this is the reintroduction guard for the per-chunk TTFT
    fetch this PR removed."""
    from repro.serving import engine as E

    class LeakySession(E.ServeSession):
        def decode_round(self):
            done = super().decode_round()
            jax.device_get(self.state.tok)       # the smuggled fetch
            return done

    findings = JA.audit_fetch_counts(JA._smoke_cfg(),
                                     session_cls=LeakySession)
    assert findings and all(f.rule == "ESS102" for f in findings)


@pytest.mark.parametrize("paged", [True, False])
def test_retrace_golden_real_workload(paged):
    """Admissions + preemption + ragged chunks + MTP on/off trace each
    program (decode, spec, every prefill bucket) exactly once in a
    fresh shape family, on both host tiers."""
    assert JA.audit_retrace(JA._smoke_cfg(paged=paged)) == []


def test_migration_pack_checker():
    clean = JA.check_migration_packs([1, 1], {0: 1, 1: 1}, [0, 0],
                                     [1, 1, 0], 2)
    assert clean == []
    over = JA.check_migration_packs([2], {0: 1}, [0], [1], 1)
    assert _rules(over) == ["ESS107"]        # pack budget blown
    assert "ONE packed fetch" in over[0].message
    twice = JA.check_migration_packs([1, 1], {0: 2}, [0], [1, 1], 2)
    assert _rules(twice) == ["ESS107"]       # a rid handed off twice
    leak = JA.check_migration_packs([1], {0: 1}, [2], [1], 1)
    assert _rules(leak) == ["ESS107"]        # non-pack prefill fetch
    smug = JA.check_migration_packs([1], {0: 1}, [0], [2], 2)
    assert _rules(smug) == ["ESS107"]        # decode round over budget
    stray = JA.check_migration_packs([1], {0: 1}, [0], [1], 1, stray=3)
    assert _rules(stray) == ["ESS107"]
    assert "outside any worker round" in stray[0].message


def test_migration_pack_golden_cluster():
    """The live PD handoff holds the one-pack contract end to end:
    exactly one fetch per migration, zero-fetch install, decode rounds
    within the ESS102 budget."""
    assert JA.audit_migration_packs() == []


def test_migration_pack_audit_catches_smuggled_fetch():
    """A decode-worker session sneaking a second device_get into its
    round is caught — the reintroduction guard for per-round host syncs
    on the decode side of a PD split."""
    from repro.serving import engine as E

    class SmugglerSession(E.ServeSession):
        def step_round(self):
            evs = super().step_round()
            jax.device_get(self.state.tok)       # the smuggled fetch
            return evs

    findings = JA.audit_migration_packs(
        decode_session_cls=SmugglerSession)
    assert findings and all(f.rule == "ESS107" for f in findings)


# ===========================================================================
# ESS001: explicit gating argument
# ===========================================================================

def test_ess001_missing_slot_mask():
    fs = _lint("""
        from repro.core import lru_pool as LP
        pool, lk, stats = LP.lookup(pool, ids, valid, 4)
    """)
    assert _rules(fs) == ["ESS001"]
    assert "slot_mask" in fs[0].message


def test_ess001_explicit_none_is_ok():
    fs = _lint("""
        from repro.core import lru_pool as LP
        pool, lk, stats = LP.lookup(pool, ids, valid, 4, slot_mask=None)
        pool = LP.admit(pool, miss, rows, slot_mask=mask)
    """)
    assert fs == []


def test_ess001_direct_import_and_engine_target():
    fs = _lint("""
        from repro.core.offload import host_scatter_rows
        from repro.serving.engine import ess_prefill_chunk
        host_scatter_rows(cache, ids, rows)
        ess_prefill_chunk(params, cfg, toks, pos, caches)
    """)
    assert _rules(fs) == ["ESS001", "ESS001"]
    assert "n_valid" in fs[1].message


def test_ess001_opaque_kwargs_stays_silent():
    fs = _lint("""
        from repro.core import lru_pool as LP
        LP.lookup(pool, ids, valid, 4, **kw)
    """)
    assert fs == []


# ===========================================================================
# ESS002: hidden host syncs
# ===========================================================================

def test_ess002_device_get_outside_fetch_site():
    fs = _lint("""
        import jax
        def poll(state):
            return jax.device_get(state.tok)
    """)
    assert _rules(fs) == ["ESS002"]


def test_ess002_allowlisted_fetch_site():
    fs = _lint("""
        import jax
        class ServeSession:
            def decode_round(self):
                return jax.device_get(self.out)
    """, fetch_sites=frozenset(
        {"repro/serving/fixture.py::ServeSession.decode_round"}))
    assert fs == []


def test_ess002_cluster_scope_and_pack_site():
    """The cluster package is ESS002-scoped; the real pack site is the
    only allowlisted fetch in it."""
    src = """
        import jax
        def pack_migration(session, slot, req, t0):
            return jax.device_get(session.caches)
    """
    assert _rules(_lint(src, relpath="repro/cluster/fixture.py")) \
        == ["ESS002"]
    from repro.analysis import contracts as C
    fs = L.lint_source(
        textwrap.dedent(src), "repro/cluster/kv_transfer.py",
        L.fixture_config(fetch_sites=C.FETCH_SITES))
    assert fs == []


def test_ess002_item_and_casts():
    fs = _lint("""
        def f(arr, logits, model, x):
            a = arr.item()
            b = int(model(x))
            c = int(arr[0])                  # already host data: fine
            d = int(round(0.5 * len(x)))     # host math: fine
            return a, b, c, d
    """)
    assert _rules(fs) == ["ESS002", "ESS002"]
    assert {f.line for f in fs} == {3, 4}


def test_ess002_out_of_scope_module():
    fs = L.lint_source("import jax\njax.device_get(x)\n",
                       "repro/training/checkpoint.py")
    assert fs == []


# ===========================================================================
# ESS003: traced-value branching
# ===========================================================================

def test_ess003_if_on_traced_value():
    fs = _lint("""
        import jax.numpy as jnp
        def body(mask, x):
            if jnp.any(mask):
                return x + 1
            return x
    """)
    assert _rules(fs) == ["ESS003"]
    assert "jnp" in fs[0].message or "jax" in fs[0].message


def test_ess003_while_and_ifexp():
    fs = _lint("""
        import jax.numpy as jnp
        def body(x):
            while jnp.sum(x) > 0:
                x = x - 1
            return x if x.any() else -x
    """)
    assert _rules(fs) == ["ESS003", "ESS003"]


def test_ess003_host_conditions_fine():
    fs = _lint("""
        def body(slot_mask, x, cfg):
            if slot_mask is None:
                return x
            if cfg.use_mtp:
                return x + 1
            return x
    """)
    assert fs == []


def test_ess003_host_function_exempt():
    fs = _lint("""
        import numpy as np
        def check_consistent(pool):
            if np.any(np.asarray(pool.ids) < 0):
                return False
            return True
    """)
    # np.any is a Call but numpy isn't a traced root — and even a jnp
    # call inside check_consistent would be exempt
    assert fs == []
    fs2 = _lint("""
        import jax.numpy as jnp
        def check_consistent(pool):
            if jnp.any(pool.ids < 0):
                return False
            return True
    """)
    assert fs2 == []


def test_ess003_scoped_functions_only():
    src = """
        import jax.numpy as jnp
        def traced(x):
            if jnp.any(x):
                return x
        def host(x):
            if jnp.any(x):
                return x
    """
    cfg = L.LintConfig(ess003_scopes={"repro/serving/fixture.py":
                                      {"traced"}})
    fs = L.lint_source(textwrap.dedent(src), "repro/serving/fixture.py",
                       cfg)
    assert _rules(fs) == ["ESS003"]
    assert fs[0].scope == "traced"


# ===========================================================================
# ESS004: undeclared donation
# ===========================================================================

def test_ess004_jit_over_state_fn():
    fs = _lint("""
        import jax
        def round_fn(params, state):
            return state
        prog = jax.jit(round_fn)
    """)
    assert _rules(fs) == ["ESS004"]


def test_ess004_donation_declared_ok():
    fs = _lint("""
        import jax
        def round_fn(params, state):
            return state
        prog = jax.jit(round_fn, donate_argnums=(1,))
        prog2 = jax.jit(round_fn, donate_argnames=("state",))
    """)
    assert fs == []


def test_ess004_decorator_and_annotation():
    fs = _lint("""
        import jax
        import functools

        @jax.jit
        def step(params, engine_state):
            return engine_state

        @functools.partial(jax.jit, static_argnums=(2,))
        def step2(params, s: "EngineState", n):
            return s
    """)
    assert _rules(fs) == ["ESS004", "ESS004"]


def test_ess004_non_state_fn_silent():
    fs = _lint("""
        import jax
        def kernel(q, keys, valid):
            return q @ keys.T
        prog = jax.jit(kernel)
    """)
    assert fs == []


# ===========================================================================
# suppression + baseline + CLI
# ===========================================================================

def test_inline_disable_suppresses():
    fs = _lint("""
        from repro.core import lru_pool as LP
        LP.lookup(pool, ids, valid, 4)  # esslint: disable=ESS001
    """)
    assert fs == []
    # the comment only silences the named rule
    fs2 = _lint("""
        from repro.core import lru_pool as LP
        LP.lookup(pool, ids, valid, 4)  # esslint: disable=ESS002
    """)
    assert _rules(fs2) == ["ESS001"]


def test_disable_on_multiline_call_span():
    fs = _lint("""
        from repro.core import lru_pool as LP
        LP.lookup(pool, ids,
                  valid,  # esslint: disable=ESS001
                  4)
    """)
    assert fs == []


def test_fingerprint_ignores_line_numbers():
    a = Finding("ESS001", "repro/x.py", 10, "f", "m", "LP.lookup(a)")
    b = Finding("ESS001", "repro/x.py", 99, "f", "m", "LP.lookup(a)")
    assert a.fingerprint == b.fingerprint
    assert a != b


def test_baseline_roundtrip_and_split(tmp_path):
    f1 = Finding("ESS001", "repro/a.py", 3, "f", "m", "x()")
    f2 = Finding("ESS002", "repro/b.py", 7, "g", "m", "y()")
    bl = tmp_path / "baseline.json"
    write_baseline(bl, [f1])
    assert load_baseline(bl) == {f1.fingerprint}
    new, known, stale = split_against_baseline([f1, f2],
                                               load_baseline(bl))
    assert new == [f2] and known == [f1] and stale == set()
    # fixing f1 leaves a stale entry
    new, known, stale = split_against_baseline([f2], load_baseline(bl))
    assert stale == {f1.fingerprint}
    assert load_baseline(tmp_path / "missing.json") == set()


def test_findings_json_shape():
    data = json.loads(findings_to_json(
        [Finding("ESS003", "repro/a.py", 3, "f", "m", "if jnp.any(x):")]))
    assert data["count"] == 1
    assert data["findings"][0]["rule"] == "ESS003"


def _mini_repo(tmp_path, body):
    (tmp_path / "src" / "repro" / "serving").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "serving" / "mod.py").write_text(
        textwrap.dedent(body))
    return tmp_path


def test_cli_exit_codes(tmp_path, capsys):
    """New finding -> 1; baselined -> 0; fixed (stale) -> 0 with a
    prune hint; reintroduced after fix -> 1 again."""
    root = _mini_repo(tmp_path, """
        import jax
        def poll(state):
            return jax.device_get(state)
    """)
    bl = str(tmp_path / "bl.json")
    argv = ["--skip-audit", "--root", str(root), "--baseline", bl]
    assert cli_main(argv) == 1                       # new finding
    assert cli_main(argv + ["--update-baseline"]) == 0
    assert cli_main(argv) == 0                       # baselined
    fixed = root / "src" / "repro" / "serving" / "mod.py"
    fixed.write_text("def poll(state):\n    return state\n")
    assert cli_main(argv) == 0                       # clean + stale entry
    assert cli_main(argv + ["--strict-stale"]) == 1  # stale fails strict
    capsys.readouterr()
    # reintroducing the violation with different spelling isn't baselined
    fixed.write_text("import jax\n\n"
                     "def poll(state):\n"
                     "    t = jax.device_get(state.tok)\n"
                     "    return t\n")
    assert cli_main(argv) == 1
    assert "ESS002" in capsys.readouterr().out
    assert cli_main(["--skip-audit", "--skip-lint"]) == 2


def test_repo_tree_is_clean_minus_suppressions():
    """The shipped tree lints clean; stripping the inline disables
    resurfaces the acknowledged host syncs (the suppressions are
    load-bearing, not decorative)."""
    assert L.lint_tree(REPO) == []
    eng = (REPO / "src/repro/serving/engine.py").read_text()
    stripped = eng.replace("# esslint: disable=ESS002", "#")
    fs = L.lint_source(stripped, "src/repro/serving/engine.py")
    assert _rules(fs) == ["ESS002", "ESS002"]
    assert {f.scope for f in fs} == {"ServeSession._prefill_chunk_warmup"}
